"""Validate the simulator against the paper's illustrative example (§III-E).

Taskset (Table I): tau1 (C=2, P=10, 2 threads, cores 0,1, high prio),
tau2 (C=4, P=10, 2 threads, cores 2,3, low prio), tau3^BE (4 threads).

Expected:
(a) co-sched, no interference: tau1 done @2, tau2 done @4, slack 28 in [0,10)
(b) RT-Gang: tau1 @2, tau2 @6 (blocked 0..2), slack 28
(c) co-sched, tau1 10x slowed by tau2: tau1 @5.6, tau2 @4, slack 20.8
"""
from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference

t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2, mem_budget=1e9)
t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1, mem_budget=1e9)
be = [BETask("tau3", cores=(0, 1, 2, 3), mem_rate=0.0)]


def run(enabled, interference=None, be_tasks=()):
    sim = Simulator(4, [t1, t2], be_tasks=list(be_tasks),
                    interference=interference or (lambda v, a: 1.0),
                    rt_gang_enabled=enabled, dt=0.05)
    return sim.run(10.0)


# (a) co-sched no interference
r = run(False, be_tasks=be)
print("(a) tau1 RT:", r.response_times["tau1"], "tau2 RT:",
      r.response_times["tau2"])
print("    slack (idle+BE core-ms):", round(r.slack_time, 2), "expect 28")

# (b) RT-Gang
r = run(True, be_tasks=be)
print("(b) tau1 RT:", r.response_times["tau1"], "tau2 RT:",
      r.response_times["tau2"], "expect [2], [6]")
print("    slack:", round(r.slack_time, 2), "expect 28")

# (c) co-sched with 10x interference on tau1 from tau2
intf = matrix_interference({("tau1", "tau2"): 10.0})
r = run(False, interference=intf, be_tasks=be)
print("(c) tau1 RT:", r.response_times["tau1"], "expect [5.6]",
      " tau2 RT:", r.response_times["tau2"], "expect [4]")
print("    slack:", round(r.slack_time, 2), "expect 20.8")

# (c') RT-Gang unchanged under interference
r = run(True, interference=intf, be_tasks=be)
print("(c') RT-Gang under interference: tau1", r.response_times["tau1"],
      "tau2", r.response_times["tau2"], "expect [2], [6]")
