"""Quick dev smoke: one tiny train/prefill/decode step per family on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.step import make_train_state, make_train_step

ARCHS = sys.argv[1:] or ["qwen2-7b"]

for arch in ARCHS:
    cfg = reduced(get_config(arch))
    mesh = make_local_mesh(1, 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              remat="block", q_block=8, kv_block=8)
    api = build_model(cfg, parallel, mesh)
    rng = jax.random.key(0)
    params = api.init(rng)
    B, S = 2, 32
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.n_vision_tokens, cfg.d_model),
                                    jnp.float32) * 0.01
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.n_encoder_frames, cfg.d_model),
                                   jnp.float32) * 0.01
    opt = Optimizer(OptConfig(name="adamw"))
    state = make_train_state(api, opt, rng)
    step = jax.jit(make_train_step(api, opt))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)

    # prefill + decode
    pbatch = {k: v for k, v in batch.items() if k != "labels"}
    logits, caches = jax.jit(api.prefill_fn)(state["params"], pbatch)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits2, caches2 = jax.jit(api.decode_fn)(state["params"], caches, tok, pos)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch
    print(f"{arch}: OK loss={loss:.4f} params={api.n_params()}")
