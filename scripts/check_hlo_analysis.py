"""Validate hlo_analysis against hand-computable cases."""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.roofline.hlo_analysis import analyze, xla_cost_analysis

# case 1: single matmul
m, k, n = 128, 256, 512
f = jax.jit(lambda a, b: a @ b)
c = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
             jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
r = analyze(c.as_text())
exp = 2 * m * k * n
print("matmul flops", r["flops"], "expected", exp, "ok", r["flops"] == exp)

# case 2: scan of 7 matmuls
L = 7
def scanned(x, ws):
    def body(c, w):
        return c @ w, None
    y, _ = jax.lax.scan(body, x, ws)
    return y
c2 = jax.jit(scanned).lower(
    jax.ShapeDtypeStruct((m, m), jnp.float32),
    jax.ShapeDtypeStruct((L, m, m), jnp.float32)).compile()
r2 = analyze(c2.as_text())
exp2 = L * 2 * m * m * m
print("scan flops", r2["flops"], "expected", exp2, "ok", r2["flops"] == exp2)
print("xla cost_analysis flops:", xla_cost_analysis(c2).get("flops"))

# case 3: collective bytes under shard_map (needs >1 device? skip if 1)
print("bytes case1:", r["bytes"], ">=", (m*k + k*n + m*n) * 4)
