"""Per-architecture smoke tests (brief requirement): a REDUCED same-family
config runs one forward/train step on CPU with finite outputs and correct
shapes, plus prefill/decode consistency for the cheap families."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.step import make_train_state, make_train_step


def _api(arch):
    cfg = reduced(get_config(arch))
    mesh = make_local_mesh(1, 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=8, kv_block=8)
    return cfg, build_model(cfg, parallel, mesh)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.full((B, cfg.n_vision_tokens, cfg.d_model),
                                    0.01, jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.full((B, cfg.n_encoder_frames, cfg.d_model),
                                   0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step(arch):
    cfg, api = _api(arch)
    opt = Optimizer(OptConfig(name="adamw", lr=1e-3))
    state = make_train_state(api, opt, jax.random.key(0))
    step = jax.jit(make_train_step(api, opt))
    batch = _batch(cfg)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # params changed
    p0 = jax.tree.leaves(state["params"])[0] if False else None
    lead0 = jax.tree.leaves(api.init(jax.random.key(0)))[0]
    lead1 = jax.tree.leaves(state2["params"])[0]
    assert lead0.shape == lead1.shape
    assert int(state2["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_shapes(arch):
    cfg, api = _api(arch)
    params = api.init(jax.random.key(1))
    B, S = 2, 32
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    logits, cache = jax.jit(api.prefill_fn)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits2, cache2 = jax.jit(api.decode_fn)(params, cache, tok, pos)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


def _pad_kv(cache, extra=8):
    """Grow attention caches (leaves named k/v) along the seq dim so decode
    has room to append; state caches (ssm/rglru) are untouched."""
    def f(path, x):
        names = [str(getattr(p, "key", "")) for p in path]
        if names and names[-1] in ("k", "v"):
            pad_z = jnp.zeros(x.shape[:2] + (extra,) + x.shape[3:], x.dtype)
            return jnp.concatenate([x, pad_z], axis=2)
        return x
    return jax.tree_util.tree_map_with_path(f, cache)


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-1.3b",
                                  "recurrentgemma-9b", "olmoe-1b-7b",
                                  "whisper-base", "internvl2-1b"])
def test_decode_matches_prefill(arch):
    """Teacher-forcing consistency: decode(t_{S}) after prefill(t_0..S-1)
    must equal prefill(t_0..S) logits at the last position. For MoE a
    no-drop capacity factor is used — capacity dropping is the one intended
    prefill/decode asymmetry (GShard semantics)."""
    import dataclasses
    from repro.configs.base import MoEConfig, ParallelConfig
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))
    mesh = make_local_mesh(1, 1)
    api = build_model(cfg, ParallelConfig(param_dtype="float32",
                                          compute_dtype="float32",
                                          q_block=8, kv_block=8), mesh)
    params = api.init(jax.random.key(2))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(B, S + 1)),
                       jnp.int32)
    batch = {"tokens": toks[:, :S]}
    batch_full = {"tokens": toks}
    if cfg.family == "vlm":
        patches = jnp.full((B, cfg.n_vision_tokens, cfg.d_model), 0.01,
                           jnp.float32)
        batch["patches"] = patches
        batch_full["patches"] = patches
    if cfg.family == "audio":
        frames = jnp.full((B, cfg.n_encoder_frames, cfg.d_model), 0.01,
                          jnp.float32)
        batch["frames"] = frames
        batch_full["frames"] = frames
    logits_a, cache = jax.jit(api.prefill_fn)(params, batch)
    pos = jnp.full((B,), S, jnp.int32)
    logits_b, _ = jax.jit(api.decode_fn)(params, _pad_kv(cache),
                                         toks[:, S:S + 1], pos)
    logits_full, _ = jax.jit(api.prefill_fn)(params, batch_full)
    a = np.asarray(logits_b[:, -1], np.float32)
    b = np.asarray(logits_full[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


def test_vlm_patches_affect_logits():
    cfg, api = _api("internvl2-1b")
    params = api.init(jax.random.key(3))
    b1 = _batch(cfg)
    b2 = {**b1, "patches": b1["patches"] * -5.0}
    l1, _ = api.loss_fn(params, b1)
    l2, _ = api.loss_fn(params, b2)
    assert abs(float(l1) - float(l2)) > 1e-6


def test_hybrid_layer_pattern():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 38
    assert kinds[:6] == ("rec", "rec", "attn", "rec", "rec", "attn")
    assert kinds[-2:] == ("rec", "rec")          # tail


def test_param_counts_match_analytic():
    """defs-based count tracks the analytic n_params within 2%."""
    for arch in ("qwen2-7b", "olmoe-1b-7b", "mamba2-1.3b"):
        cfg = get_config(arch)
        from repro.models.model import build_defs
        from repro.models import layers as L
        defs_n = sum(int(np.prod(d.shape)) for d in
                     jax.tree.leaves(build_defs(cfg), is_leaf=L.is_def))
        ana = cfg.n_params()
        assert abs(defs_n - ana) / ana < 0.02, (arch, defs_n, ana)
