"""Data pipeline determinism + optimizer correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DataConfig, TokenSource
from repro.training.optimizer import (OptConfig, Optimizer,
                                      clip_by_global_norm, lr_at)
from repro.training.step import compress_grads


def test_data_deterministic_and_resumable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=1000, seed=3)
    src = TokenSource(cfg)
    b1 = src.train_batch(5)
    b2 = TokenSource(cfg).train_batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["tokens"] != src.train_batch(6)["tokens"]).any()


def test_memmap_source(tmp_path):
    toks = np.arange(10_000, dtype=np.int32) % 97
    path = tmp_path / "toks.bin"
    toks.tofile(path)
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=97,
                     path=str(path))
    b = TokenSource(cfg).train_batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_descends_quadratic(name):
    opt = Optimizer(OptConfig(name=name, lr=0.1, warmup=1, decay_steps=1000,
                              weight_decay=0.0, grad_clip=0.0))
    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_lr_schedule_warmup_cosine():
    c = OptConfig(lr=1.0, warmup=10, decay_steps=100, min_lr_frac=0.1)
    assert float(lr_at(c, jnp.int32(0))) < 0.2
    peak = float(lr_at(c, jnp.int32(10)))
    assert peak == pytest.approx(1.0, abs=0.05)
    assert float(lr_at(c, jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, 1e-5)


def test_int8_compress_error_feedback():
    """Quantization residual is carried, so the *running sum* of compressed
    grads tracks the true sum (error feedback property)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros((32,), np.float32)
    comp_sum = np.zeros((32,), np.float32)
    ef = {"g": jnp.zeros((32,), jnp.float32)}
    for _ in range(30):
        g = rng.normal(size=(32,)).astype(np.float32)
        true_sum += g
        cg, ef_new = compress_grads({"g": jnp.asarray(g)}, ef)
        ef = ef_new
        comp_sum += np.asarray(cg["g"])
    resid = np.abs(true_sum - comp_sum).max()
    scale = np.abs(true_sum).max()
    assert resid < 0.05 * scale + 0.1, (resid, scale)


def test_adafactor_state_is_factored():
    opt = Optimizer(OptConfig(name="adafactor"))
    params = {"w": jnp.ones((16, 8)), "b": jnp.ones((8,))}
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (16,)
    assert st["f"]["w"]["vc"].shape == (8,)
    assert st["f"]["b"]["v"].shape == (8,)
