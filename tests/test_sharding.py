"""Distributed-correctness tests: run a subprocess with 8 forced host
devices and check (a) sharded loss == single-device loss for dense and MoE
(exercising FSDP gathers, TP constraints, the shard_map MoE all-to-all path),
and (b) the trip-count-aware collective accounting sees real collectives.

A subprocess is required because jax fixes the device count at first init.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig, MoEConfig
from repro.models.model import build_model
from repro.roofline.hlo_analysis import analyze

out = {}
for arch in ["qwen2-7b", "olmoe-1b-7b"]:
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=MoEConfig(n_experts=8, top_k=2,
                                                     capacity_factor=8.0))
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                                size=(B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                size=(B, S)), jnp.int32)}

    losses = {}
    hlo_stats = {}
    for name, (d, m) in {"single": (1, 1), "dist": (2, 4)}.items():
        mesh = jax.make_mesh((d, m), ("data", "model"))
        par = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                             q_block=8, kv_block=8,
                             sequence_parallel=(name == "dist"))
        api = build_model(cfg, par, mesh)
        params = api.init(jax.random.key(0))
        with mesh:
            c = jax.jit(lambda p, b: api.loss_fn(p, b)[0]).lower(
                params, batch).compile()
            losses[name] = float(c(params, batch))
            hlo_stats[name] = analyze(c.as_text())
    out[arch] = {
        "single": losses["single"], "dist": losses["dist"],
        "dist_collective_bytes": hlo_stats["dist"]["collective_total"],
        "single_collective_bytes": hlo_stats["single"]["collective_total"],
    }
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_dense_distributed_matches_single(dist_result):
    r = dist_result["qwen2-7b"]
    assert abs(r["dist"] - r["single"]) < 2e-3 * max(1.0, abs(r["single"]))


def test_moe_distributed_matches_single(dist_result):
    """shard_map EP all-to-all path == dense fallback (no drops)."""
    r = dist_result["olmoe-1b-7b"]
    assert abs(r["dist"] - r["single"]) < 5e-3 * max(1.0, abs(r["single"]))


def test_distributed_run_has_collectives(dist_result):
    for arch in ("qwen2-7b", "olmoe-1b-7b"):
        r = dist_result[arch]
        assert r["dist_collective_bytes"] > 0
        assert r["single_collective_bytes"] == 0
