"""Bandwidth-regulator invariants (hypothesis property tests) plus the
continuous-time interface: multi-window ``charge_span`` accounting and
fractional quantum admission (``charge_partial``)."""
import pytest
from _hyp import given, settings, st

from repro.core.throttle import BandwidthRegulator


@settings(max_examples=100, deadline=None)
@given(st.floats(0.5, 10.0),
       st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.01, 0.5)),
                min_size=1, max_size=50))
def test_admission_never_exceeds_budget(budget, charges):
    """admission mode: accepted traffic per window <= budget, always."""
    reg = BandwidthRegulator(1, interval=1.0, mode="admission")
    reg.set_gang_budget(budget)
    now = 0.0
    window_used = {}
    for amount, dt in charges:
        ok = reg.charge(0, amount, now)
        w = int(now)  # interval = 1.0
        if ok:
            window_used[w] = window_used.get(w, 0.0) + amount
        now += dt
    for w, used in window_used.items():
        assert used <= budget + 1e-9, (w, used, budget)


@settings(max_examples=100, deadline=None)
@given(st.floats(0.5, 10.0),
       st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.01, 0.5)),
                min_size=1, max_size=50))
def test_reactive_overshoot_at_most_one_quantum(budget, charges):
    """reactive mode (paper-faithful): overshoot bounded by one quantum."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(budget)
    now = 0.0
    window_used = {}
    max_q = 0.0
    for amount, dt in charges:
        if not reg.is_stalled(0, now):
            ok = reg.charge(0, amount, now)
            w = int(now)
            window_used[w] = window_used.get(w, 0.0) + amount
            max_q = max(max_q, amount)
        now += dt
    for w, used in window_used.items():
        assert used <= budget + max_q + 1e-9


def test_stall_clears_next_interval():
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(1.0)
    assert reg.charge(0, 2.0, 0.1) is False         # overshoot -> stall
    assert reg.is_stalled(0, 0.5)
    assert not reg.is_stalled(0, 1.05)              # next window
    assert reg.charge(0, 0.5, 1.1) is True


def test_charge_span_within_window():
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(10.0)
    reg.charge_span(0, 2.0, 0.1, 0.6)
    st = reg.cores[0]
    assert st.used == pytest.approx(1.0)
    assert st.window_start == 0.0


def test_charge_span_across_multiple_windows():
    """A span crossing window boundaries carries into the final window
    exactly the traffic generated since that window opened; total_used
    accounts the whole span."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(10.0)
    reg.charge_span(0, 2.0, 0.2, 3.5)          # crosses 3 boundaries
    st = reg.cores[0]
    assert st.window_start == pytest.approx(3.0)
    assert st.used == pytest.approx(2.0 * 0.5)
    assert st.total_used == pytest.approx(2.0 * 3.3)


def test_charge_span_ending_on_boundary_resets_usage():
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(10.0)
    reg.charge_span(0, 3.0, 1.25, 2.0)         # ends exactly at t=2.0
    st = reg.cores[0]
    assert st.window_start == pytest.approx(2.0)
    assert st.used == pytest.approx(0.0)
    assert st.total_used == pytest.approx(3.0 * 0.75)


def test_charge_span_accumulates_within_one_window():
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(10.0)
    reg.charge_span(0, 1.0, 0.0, 0.25)
    reg.charge_span(0, 4.0, 0.25, 0.75)
    st = reg.cores[0]
    assert st.used == pytest.approx(0.25 + 2.0)
    # a rate whose whole-window traffic fits the budget never trips...
    assert reg.next_trip_time(0, 4.0, 0.75) == float("inf")
    # ...a fast one trips inside this window, at the exact closed form
    assert reg.next_trip_time(0, 100.0, 0.75) == pytest.approx(
        0.75 + (10.0 - 2.25) / 100.0)


def test_charge_span_sequential_spans_landing_in_one_window():
    """Several sequential spans whose tails land in the same regulation
    window: the window carries exactly the traffic generated since it
    opened, regardless of how many spans delivered it."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(100.0)
    reg.charge_span(0, 2.0, 0.2, 1.3)     # crosses into window [1, 2)
    reg.charge_span(0, 4.0, 1.3, 1.6)     # stays inside [1, 2)
    reg.charge_span(0, 1.0, 1.6, 1.9)     # stays inside [1, 2)
    st = reg.cores[0]
    assert st.window_start == pytest.approx(1.0)
    # in-window usage: 2.0*0.3 + 4.0*0.3 + 1.0*0.3
    assert st.used == pytest.approx(0.6 + 1.2 + 0.3)
    assert st.total_used == pytest.approx(2.0 * 1.1 + 4.0 * 0.3
                                          + 1.0 * 0.3)
    # the closed-form trip reflects the accumulated in-window usage
    assert reg.next_trip_time(0, 1000.0, 1.9) == pytest.approx(
        1.9 + (100.0 - 2.1) / 1000.0)


def test_next_trip_time_after_long_idle_gap():
    """A trip prediction right after a long idle stretch must jump the
    window to the one containing ``now`` (stale usage forgotten) and
    price the budget against a fresh window."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(1.0)
    reg.charge_span(0, 0.9, 0.0, 1.0)     # old usage, long ago
    t = reg.next_trip_time(0, 10.0, 57.3)
    st = reg.cores[0]
    assert st.window_start == pytest.approx(57.0)
    assert st.used == pytest.approx(0.0)
    assert t == pytest.approx(57.3 + 1.0 / 10.0)
    # a slow rate that cannot exhaust a full window never trips
    assert reg.next_trip_time(0, 0.5, 57.3) == float("inf")


def test_admission_set_core_budgets_stall_lift():
    """Admission mode: a denial stalls the core to the window end; a
    per-core budget *raise* lifts the stall immediately (the executor's
    leave/acquire hand-off path), while a lower or equal budget keeps
    it. Usage within the window is preserved across the change."""
    reg = BandwidthRegulator(2, interval=1.0, mode="admission")
    reg.set_core_budgets({0: 1.0, 1: 1.0})
    assert reg.charge(0, 0.8, 0.1)
    assert reg.charge(0, 0.8, 0.15) is False        # denied -> stalled
    assert reg.is_stalled(0, 0.2)
    assert reg.charge(1, 0.8, 0.1)
    assert reg.charge(1, 0.8, 0.15) is False
    changed = reg.set_core_budgets({0: 5.0, 1: 0.5})
    assert changed == {0, 1}
    assert not reg.is_stalled(0, 0.2)               # raise lifts stall
    assert reg.is_stalled(1, 0.2)                   # cut keeps stall
    # usage carried: 0.8 already used, 4.2 headroom left this window
    assert reg.charge(0, 4.0, 0.25)
    assert reg.charge(0, 0.5, 0.3) is False
    # the stalled core frees at the window boundary as usual
    assert not reg.is_stalled(1, 1.05)


def test_charge_partial_admits_fraction_then_stalls():
    """Reactive fractional admission: the counter takes the whole
    quantum (hardware overshoot), the caller learns which fraction ran
    before the trip, and the core stalls until the window ends."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(1.0)
    assert reg.charge_partial(0, 0.8, 0.1) == pytest.approx(1.0)
    frac = reg.charge_partial(0, 0.8, 0.2)
    assert frac == pytest.approx(0.25)          # 0.2 of 0.8 fit
    assert reg.is_stalled(0, 0.3)
    assert reg.charge_partial(0, 0.5, 0.4) == 0.0   # stalled: denied
    assert not reg.is_stalled(0, 1.05)              # next window
    assert reg.cores[0].throttle_events == 1


def test_charge_partial_admission_mode_is_all_or_nothing():
    reg = BandwidthRegulator(1, interval=1.0, mode="admission")
    reg.set_gang_budget(1.0)
    assert reg.charge_partial(0, 0.9, 0.0) == 1.0
    assert reg.charge_partial(0, 0.2, 0.1) == 0.0
    assert reg.cores[0].used == pytest.approx(0.9)


def test_lowering_budget_below_used_trips_immediately():
    """Mid-window budget cut below the already-consumed usage must stall
    the core at once — not let it overrun until the next window roll."""
    reg = BandwidthRegulator(2, interval=1.0, mode="reactive")
    reg.set_core_budgets({0: 10.0, 1: 10.0})
    assert reg.charge(0, 6.0, 0.2)
    changed = reg.set_core_budgets({0: 4.0, 1: 10.0})
    assert changed == {0}
    assert reg.is_stalled(0, 0.3)
    assert reg.cores[0].throttle_events == 1
    assert reg.charge_partial(0, 1.0, 0.4) == 0.0    # denied while stalled
    assert not reg.is_stalled(0, 1.05)               # frees at window end
    # an equal-usage cut does not trip (usage never *exceeds* the limit)
    assert reg.charge(1, 5.0, 0.2)
    reg.set_core_budgets({0: 4.0, 1: 5.0})
    assert not reg.is_stalled(1, 0.3)


def test_lowering_budget_with_stale_window_is_harmless():
    """The immediate-trip rule pins the stall to the end of the window
    the usage belongs to; if that window is long past, the stall instant
    is already behind ``now`` and the fresh window starts clean."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_core_budgets({0: 10.0})
    assert reg.charge(0, 6.0, 0.2)        # usage in window [0, 1)
    reg.set_core_budgets({0: 4.0})        # cut long after that window
    assert not reg.is_stalled(0, 7.3)
    assert reg.charge(0, 3.0, 7.4)


def test_reclaim_draw_and_donate_accounting():
    """Pull-based donation: a draw marks the donors' ``donated`` (core
    order, never handed out twice), credits the drawer's ``drawn``, and
    both reset at the window roll."""
    reg = BandwidthRegulator(3, interval=1.0, mode="reactive",
                             reclaim=True)
    reg.set_core_budgets({0: 2.0, 1: 3.0, 2: 4.0})
    assert reg.charge(1, 1.0, 0.1)                    # donor 1: 2.0 left
    assert reg.donatable(1, 0.1) == pytest.approx(2.0)
    assert reg.donatable(0, 0.1) == pytest.approx(2.0)
    got = reg.draw_from(2, (0, 1), 3.0, 0.2)
    assert got == pytest.approx(3.0)
    assert reg.cores[0].donated == pytest.approx(2.0)  # core order first
    assert reg.cores[1].donated == pytest.approx(1.0)
    assert reg.cores[2].drawn == pytest.approx(3.0)
    assert reg.cores[2].limit == pytest.approx(7.0)
    # the donated quota is gone from the donors' windows
    assert reg.donatable(0, 0.2) == 0.0
    assert reg.charge(1, 1.5, 0.3) is False            # 3 - 1 - 1 = 1 left
    # ...and the drawer's window really is extended
    assert reg.charge(2, 6.5, 0.3)
    assert reg.charge(2, 1.0, 0.35) is False
    # everything resets at the (lazy, per-core) roll
    assert reg.donatable(0, 1.1) == pytest.approx(2.0)
    assert not reg.is_stalled(2, 1.1)
    assert reg.cores[2].drawn == 0.0
    assert reg.total_reclaimed == pytest.approx(3.0)


def test_reclaim_disabled_draws_nothing():
    reg = BandwidthRegulator(2, interval=1.0, mode="reactive")
    reg.set_core_budgets({0: 5.0, 1: 5.0})
    assert reg.draw_from(1, (0,), 2.0, 0.1) == 0.0
    assert reg.cores[0].donated == 0.0


def test_budget_decrease_revokes_unspent_drawn_quota():
    """A stricter incoming regime wins over quota granted under the old
    one: lowering a core's budget clears its reclaimed grant and stalls
    it if usage already exceeds the new limit."""
    reg = BandwidthRegulator(2, interval=1.0, mode="admission",
                             reclaim=True)
    reg.set_core_budgets({0: 5.0, 1: 5.0})
    assert reg.charge(1, 4.0, 0.1)
    assert reg.draw_from(1, (0,), 3.0, 0.1) == pytest.approx(3.0)
    assert reg.charge(1, 3.5, 0.15)                   # runs on the grant
    reg.set_core_budgets({1: 4.0})                    # preemptor's regime
    assert reg.cores[1].drawn == 0.0
    assert reg.is_stalled(1, 0.2)                     # 7.5 used > 4.0
    # infinite-budget donors have nothing to give
    reg2 = BandwidthRegulator(2, interval=1.0, reclaim=True)
    reg2.set_core_budgets({1: 1.0})
    assert reg2.draw_from(1, (0,), 2.0, 0.0) == 0.0


def test_budget_follows_gang():
    """Budget switches with gang-lock ownership (paper §IV-F)."""
    reg = BandwidthRegulator(2, interval=1.0, mode="admission")
    reg.set_gang_budget(5.0)
    assert reg.charge(0, 4.0, 0.0)
    reg.set_gang_budget(0.0)        # max-isolation gang arrives
    assert reg.charge(1, 0.1, 0.1) is False
    reg.set_gang_budget(None)       # no gang -> unthrottled
    assert reg.charge(1, 100.0, 0.2)
