"""Bandwidth-regulator invariants (hypothesis property tests)."""
from _hyp import given, settings, st

from repro.core.throttle import BandwidthRegulator


@settings(max_examples=100, deadline=None)
@given(st.floats(0.5, 10.0),
       st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.01, 0.5)),
                min_size=1, max_size=50))
def test_admission_never_exceeds_budget(budget, charges):
    """admission mode: accepted traffic per window <= budget, always."""
    reg = BandwidthRegulator(1, interval=1.0, mode="admission")
    reg.set_gang_budget(budget)
    now = 0.0
    window_used = {}
    for amount, dt in charges:
        ok = reg.charge(0, amount, now)
        w = int(now)  # interval = 1.0
        if ok:
            window_used[w] = window_used.get(w, 0.0) + amount
        now += dt
    for w, used in window_used.items():
        assert used <= budget + 1e-9, (w, used, budget)


@settings(max_examples=100, deadline=None)
@given(st.floats(0.5, 10.0),
       st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.01, 0.5)),
                min_size=1, max_size=50))
def test_reactive_overshoot_at_most_one_quantum(budget, charges):
    """reactive mode (paper-faithful): overshoot bounded by one quantum."""
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(budget)
    now = 0.0
    window_used = {}
    max_q = 0.0
    for amount, dt in charges:
        if not reg.is_stalled(0, now):
            ok = reg.charge(0, amount, now)
            w = int(now)
            window_used[w] = window_used.get(w, 0.0) + amount
            max_q = max(max_q, amount)
        now += dt
    for w, used in window_used.items():
        assert used <= budget + max_q + 1e-9


def test_stall_clears_next_interval():
    reg = BandwidthRegulator(1, interval=1.0, mode="reactive")
    reg.set_gang_budget(1.0)
    assert reg.charge(0, 2.0, 0.1) is False         # overshoot -> stall
    assert reg.is_stalled(0, 0.5)
    assert not reg.is_stalled(0, 1.05)              # next window
    assert reg.charge(0, 0.5, 1.1) is True


def test_budget_follows_gang():
    """Budget switches with gang-lock ownership (paper §IV-F)."""
    reg = BandwidthRegulator(2, interval=1.0, mode="admission")
    reg.set_gang_budget(5.0)
    assert reg.charge(0, 4.0, 0.0)
    reg.set_gang_budget(0.0)        # max-isolation gang arrives
    assert reg.charge(1, 0.1, 0.1) is False
    reg.set_gang_budget(None)       # no gang -> unthrottled
    assert reg.charge(1, 100.0, 0.2)
