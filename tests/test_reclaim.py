"""Dynamic reclaiming (DESIGN.md §7.5): quantum-vs-event engine parity
on donation accounting, soundness of the reclaim RTA bound against both
engines, and the rtgT+dr acceptance lift.

The byte-parity scenario is constructed so every event — releases, job
completions, budget exhaustions, donations, window boundaries — lands on
an exact binary multiple of the quantum (dt = 1/32 ms, rates and budgets
chosen so all charges are exact in binary floating point). Both engines
must then agree *exactly*: same response times, same trip times/counts,
same donated/drawn totals, same best-effort progress.
"""
import random

import pytest

from repro.core.gang import BETask, RTTask
from repro.core.sim import matrix_interference
from repro.vgang.formation import (VirtualGang, critical_member,
                                   intensity_interference)
from repro.vgang.rta import (reclaim_wcet, rtg_throttle_wcet,
                             accepts_rtg_throttle, schedulable_rtg_throttle)
from repro.vgang.sched import VirtualGangPolicy

DT = 0.03125                       # 1/32: exact in binary


def exact_vgang():
    """crit a (no traffic, cap 0.75), early donor b, drawer s — all
    trips/donations/completions on dt multiples (see module docstring):
    b completes 0.25; s runs [0, .375) on its own quota, [.375, .625)
    on b's leftover donation in window 0, then a full 0.75-grant per
    window, completing at 2.625; a completes exactly at 3.125. The
    drawer does not slow the crit (intf(a, s) = 1), so no running
    victim's slowdown changes at a trip instant — which would re-open
    the quantum engine's one-step co-runner bias at trips."""
    a = RTTask("a", wcet=3.0, period=8.0, cores=(0,), prio=5,
               mem_budget=0.75, n_jobs=1)
    b = RTTask("b", wcet=0.25, period=8.0, cores=(1,), prio=5,
               mem_rate=1.0, mem_budget=8.0, n_jobs=1)
    s = RTTask("s", wcet=1.0, period=8.0, cores=(2,), prio=5,
               mem_rate=2.0, mem_budget=8.0, n_jobs=1)
    intf = matrix_interference({("a", "b"): 2.0, ("s", "a"): 2.0})
    return VirtualGang("abs", [a, b, s], prio=5), intf


def run_exact(dt):
    vg, intf = exact_vgang()
    pol = VirtualGangPolicy([vg], 4, intf, auto_prio=False,
                            rtg_throttle=True, reclaim=True)
    be = [BETask("be", cores=(3,), mem_rate=1.0)]
    sim = pol.build_simulator(be_tasks=be, dt=dt)
    return sim.run(6.0), sim


def test_reclaim_engines_byte_identical():
    """Donation accounting parity: the two engines claim the same
    amounts from the same donors at the same instants — response times,
    trip counts, reclaimed totals and be_progress are all *exactly*
    equal (not within a tolerance)."""
    e, esim = run_exact(None)
    q, qsim = run_exact(DT)
    assert e.engine == "event" and q.engine == "quantum"
    assert q.response_times == e.response_times
    assert q.throttle_events == e.throttle_events
    assert q.reclaimed == e.reclaimed
    assert q.be_progress == e.be_progress
    assert q.deadline_misses == e.deadline_misses
    for c in range(4):
        qs, es = qsim.reg.cores[c], esim.reg.cores[c]
        assert qs.throttle_events == es.throttle_events, c

    # ...and both match the hand-derived schedule
    assert e.response_times["b"][0] == pytest.approx(0.25)
    assert e.response_times["s"][0] == pytest.approx(2.625)
    assert e.response_times["a"][0] == pytest.approx(3.125)
    assert e.reclaimed == pytest.approx(0.5 + 0.75 + 0.75)
    assert e.throttle_events == 5        # s: .625, 1.75; be: w0..w2
    assert e.be_progress["be"] == pytest.approx(5.25)


def test_reclaim_lifts_drawer_without_hurting_static_bounds():
    """Reclaiming strictly improves the drawer and never pushes any
    member past the *static* duty-cycle bound (the exchange gate's
    guarantee): with it off, s idles out every window tail."""
    vg, intf = exact_vgang()
    off = VirtualGangPolicy([vg], 4, intf, auto_prio=False,
                            rtg_throttle=True, reclaim=False)
    r_off = off.build_simulator(dt=None).run(6.0)
    r_on, _ = run_exact(None)
    assert r_on.response_times["s"][0] < r_off.response_times["s"][0]
    static = rtg_throttle_wcet(vg, intf)
    for m in vg.members:
        assert r_on.response_times[m.name][0] <= static + 1e-9


def test_reclaim_messy_taskset_amounts_still_agree():
    """On a taskset whose events do not align to the quantum, response
    times differ by O(dt) as usual — but the donated/drawn totals are
    still identical (claims happen at the same exhaustion instants)."""
    a = RTTask("a", wcet=6.0, period=20.0, cores=(0,), prio=5,
               mem_intensity=0.2, n_jobs=1)
    b = RTTask("b", wcet=0.5, period=20.0, cores=(1,), prio=5,
               mem_rate=1.0, n_jobs=1)
    s = RTTask("s", wcet=3.0, period=20.0, cores=(2,), prio=5,
               mem_rate=2.0, n_jobs=1)
    intf = matrix_interference({("a", "b"): 1.5, ("a", "s"): 1.3,
                                ("s", "a"): 1.25})
    vg = VirtualGang("abs", [a, b, s], prio=5)
    runs = {}
    for dt in (None, 0.0125):
        pol = VirtualGangPolicy([vg], 3, intf, auto_prio=False,
                                rtg_throttle=True, reclaim=True)
        runs[dt] = pol.simulate(20.0, dt=dt)
    assert runs[None].reclaimed == runs[0.0125].reclaimed
    assert runs[None].reclaimed == pytest.approx(3.5)
    for name in ("a", "b", "s"):
        assert abs(runs[None].response_times[name][0] -
                   runs[0.0125].response_times[name][0]) <= 4 * 0.0125


# ---------------------------------------------------------------------
# the reclaim RTA bound (vgang/rta.py)
# ---------------------------------------------------------------------

def test_reclaim_wcet_tighter_and_sound_on_exact_vgang():
    vg, intf = exact_vgang()
    static = rtg_throttle_wcet(vg, intf)
    dr = reclaim_wcet(vg, intf)
    assert dr < static
    r, _ = run_exact(None)
    makespan = max(rs[0] for rs in r.response_times.values())
    assert makespan <= dr + 1e-9


def test_reclaim_acceptance_dominates_rtgT():
    """min(static, reclaim) pricing: a set the static bound rejects but
    the reclaim bound accepts — and never the other way around."""
    a = RTTask("a", wcet=6.0, period=9.0, cores=(0,), prio=5,
               mem_intensity=0.2, n_jobs=1)
    b = RTTask("b", wcet=0.5, period=9.0, cores=(1,), prio=5,
               mem_rate=1.0, n_jobs=1)
    s = RTTask("s", wcet=3.0, period=9.0, cores=(2,), prio=5,
               mem_rate=2.0, n_jobs=1)
    intf = matrix_interference({("a", "b"): 1.5, ("a", "s"): 1.3,
                                ("s", "a"): 1.25})
    vgs = [VirtualGang("abs", [a, b, s], prio=5)]
    assert not accepts_rtg_throttle(vgs, intf)
    assert accepts_rtg_throttle(vgs, intf, reclaim=True)
    res = schedulable_rtg_throttle(vgs, intf, reclaim=True)
    assert res["abs"]["wcrt"] <= 9.0


def test_reclaim_bound_sound_against_engines_randomized():
    """Property sweep: random window-aligned vgangs simulated under the
    reclaiming dispatch never finish later than min(static, reclaim) —
    the bound the rtgT+dr grid column prices admission with."""
    rng = random.Random(7)
    checked = 0
    for trial in range(30):
        n = rng.randint(2, 4)
        members = []
        for i in range(n):
            members.append(RTTask(
                f"m{trial}_{i}", wcet=round(rng.uniform(0.5, 4.0), 3),
                period=40.0, cores=(i,), prio=5,
                mem_intensity=round(rng.uniform(0.05, 0.9), 3),
                n_jobs=1))
        intf = intensity_interference(members, gamma=0.8)
        vg = VirtualGang(f"vg{trial}", members, prio=5)
        static = rtg_throttle_wcet(vg, intf)
        dr = reclaim_wcet(vg, intf)
        bound = min(static, dr)
        if bound == float("inf") or bound > 40.0:
            continue
        pol = VirtualGangPolicy([vg], n, intf, auto_prio=False,
                                rtg_throttle=True, reclaim=True)
        r = pol.simulate(40.0, dt=None)
        for m in members:
            assert r.response_times[m.name], m.name
            assert r.response_times[m.name][0] <= bound + 1e-6, \
                (trial, m.name, r.response_times[m.name][0], static, dr)
        checked += 1
    assert checked >= 10


def test_donors_are_gang_scoped():
    """A core left idle by a *previously scheduled* gang must not fund
    another gang's drawer: its leftover grant was never priced as a
    co-runner by the drawer's static bound."""
    from repro.core.memmodel import MemoryModel
    from repro.core.throttle import BandwidthRegulator

    reg = BandwidthRegulator(3, interval=1.0, mode="reactive",
                             reclaim=True)
    mm = MemoryModel(3, lambda v, a: 1.0, reg)
    old = RTTask("old", wcet=1.0, period=10.0, cores=(0,), prio=3,
                 mem_rate=1.0)
    peer = RTTask("peer", wcet=1.0, period=10.0, cores=(1,), prio=7,
                  mem_rate=1.0)
    cur = RTTask("cur", wcet=1.0, period=10.0, cores=(2,), prio=7,
                 mem_rate=2.0)
    reg.set_core_budgets({0: 5.0, 1: 5.0, 2: 1.0})
    mm.set_rt(0, old)
    mm.clear(0)                      # gang at prio 3 departed; quota left
    mm.set_rt(1, peer)
    mm.clear(1)                      # same-gang member retired
    mm.set_rt(2, cur)
    got = mm.claim(2, "cur", 2.0, 0.5)
    assert got == pytest.approx(1.0)             # only peer's window tail
    assert reg.cores[0].donated == 0.0           # foreign gang untouched
    assert reg.cores[1].donated == pytest.approx(1.0)


def test_boundary_straddling_quantum_still_trips():
    """A quantum whose exhaustion instant lands on the window boundary
    must not pre-claim: rolling the drawer's window to the future t_x
    would erase the current window's usage and admit traffic that the
    regulator should throttle. With reclaiming on, the straddling
    quantum behaves exactly as with it off."""
    from repro.core.memmodel import MemoryModel
    from repro.core.throttle import BandwidthRegulator

    outcomes = {}
    for reclaim in (False, True):
        reg = BandwidthRegulator(1, interval=1.0, mode="reactive",
                                 reclaim=reclaim)
        reg.set_core_budgets({0: 10.0})
        mm = MemoryModel(1, lambda v, a: 1.0, reg)
        t = RTTask("t", wcet=5.0, period=10.0, cores=(0,), prio=1,
                   mem_rate=10.0)
        mm.set_rt(0, t)
        assert reg.charge(0, 9.5, 0.5)
        frac = mm.charge_quantum(0, 0.2, 0.95)   # t_x = exactly 1.0
        st = reg.cores[0]
        outcomes[reclaim] = (frac, st.used, st.throttle_events,
                             st.window_start)
    assert outcomes[True] == outcomes[False]
    assert outcomes[True][0] == pytest.approx(0.5 / 2.0)    # head/amount
    assert outcomes[True][2] == 1                           # tripped


def test_draw_from_require_full_is_all_or_nothing():
    from repro.core.throttle import BandwidthRegulator
    reg = BandwidthRegulator(3, interval=1.0, mode="admission",
                             reclaim=True)
    reg.set_core_budgets({0: 1.0, 1: 1.0, 2: 5.0})
    assert reg.draw_from(2, (0, 1), 3.0, 0.1, require_full=True) == 0.0
    assert reg.cores[0].donated == 0.0           # nothing stranded
    assert reg.draw_from(2, (0, 1), 2.0, 0.1,
                         require_full=True) == pytest.approx(2.0)


def test_gang_acquire_voids_prior_grants():
    """A gang taking the lock must not inherit the previous regime's
    donation state — even when its budget values coincide, so
    set_core_budgets' value diff cannot see the change (the executor's
    acquire hook calls reset_reclaim; both engines wire the same glock
    event)."""
    from repro.core.executor import GangExecutor, RTJob
    import time as _time

    ex = GangExecutor(n_lanes=2, regulation_interval_s=1.0, reclaim=True)
    a = RTJob("A", lambda lane, idx: None, lanes=(0,), prio=1,
              budget_bytes=2.0, n_jobs=1)
    b = RTJob("B", lambda lane, idx: None, lanes=(0,), prio=9,
              budget_bytes=2.0, n_jobs=1)
    ex.submit_rt(a)
    ex.submit_rt(b)
    ex._t0 = _time.monotonic()
    ex._release_jobs()
    ex.sched.pick_next_task_rt(0, None, ex._threads[(a.uid, 0)])
    # a grant issued while A leads (lane 1 is the capped free lane)...
    assert ex.reg.draw_from(0, (1,), 1.5, ex._now()) == pytest.approx(1.5)
    assert ex.reg.cores[0].drawn == pytest.approx(1.5)
    assert ex.reg.cores[1].donated == pytest.approx(1.5)
    # ...is voided by B's acquire although the budget values are equal
    ex.sched.pick_next_task_rt(0, None, ex._threads[(b.uid, 0)])
    assert ex.sched.g.leader is ex._tasks[b.uid]
    assert ex.reg.cores[0].drawn == 0.0
    assert ex.reg.cores[1].donated == 0.0


def test_claim_lift_requires_covering_grant():
    """A grant too small to cover the trip overshoot must not lift the
    stall: a false lift would immediately re-trip, double-counting the
    stall, while the quota is already spent."""
    from repro.core.memmodel import MemoryModel
    from repro.core.throttle import BandwidthRegulator

    reg = BandwidthRegulator(2, interval=1.0, mode="reactive",
                             reclaim=True)
    reg.set_core_budgets({0: 1.0, 1: 0.3})
    mm = MemoryModel(2, lambda v, a: 1.0, reg)
    s = RTTask("s", wcet=5.0, period=10.0, cores=(0,), prio=1,
               mem_rate=2.0)
    d = RTTask("d", wcet=1.0, period=10.0, cores=(1,), prio=1,
               mem_rate=0.3)
    mm.set_rt(1, d)
    reg.charge(1, 0.1, 0.1)
    mm.clear(1)                         # donor idle: 0.2 donatable
    mm.set_rt(0, s)
    assert reg.charge(0, 1.0, 0.2)
    assert reg.charge(0, 0.5, 0.3) is False     # overshoot: used 1.5
    assert reg.cores[0].throttle_events == 1
    assert mm.claim_lift(0, s, 0.5) is False    # 0.2 < 0.5 deficit
    assert reg.is_stalled(0, 0.6)
    assert reg.cores[0].throttle_events == 1    # no double count


def test_reclaim_wcet_single_member_matches_inflated():
    t = RTTask("solo", wcet=2.0, period=10.0, cores=(0, 1), prio=3)
    vg = VirtualGang("solo", [t], prio=3)
    assert reclaim_wcet(vg) == vg.inflated_wcet()


def test_reclaim_wcet_starved_sibling_rescued_by_donation():
    """A sibling with zero static headroom (cap exhausted instantly) is
    inf under the static bound; with a donating co-sibling that finishes
    early, the reclaim bound is finite."""
    a = RTTask("a", wcet=4.0, period=50.0, cores=(0,), prio=5,
               mem_budget=1.0, mem_intensity=0.1)
    d = RTTask("d", wcet=0.5, period=50.0, cores=(1,), prio=5,
               mem_rate=1.0)
    z = RTTask("z", wcet=1.0, period=50.0, cores=(2,), prio=5,
               mem_rate=1000.0)      # q = cap/1000: effectively starved
    intf = matrix_interference({("a", "d"): 1.5, ("a", "z"): 1.2})
    vg = VirtualGang("adz", [a, d, z], prio=5)
    assert critical_member(vg, intf).name == "a"
    static = rtg_throttle_wcet(vg, intf)
    dr = reclaim_wcet(vg, intf)
    assert dr < static
