"""Trace-optional simulation (``Simulator(trace=False)``, DESIGN.md
§13.4): on the fig4/fig5 parity workloads, both engines must produce a
SimResult that is byte-for-byte identical with tracing on or off —
counters, response times, miss times, margins and the metrics
registry's parity snapshot all come from engine state, never from the
timeline. The only difference trace=False may make is an empty
timeline."""
import dataclasses
import json

import pytest

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference
from repro.core.tracing import NullTrace, Trace
from repro.obs.metrics import MetricsRegistry


def fig4_taskset():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2,
                mem_budget=1e9)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1,
                mem_budget=1e9)
    be = [BETask("tau3", cores=(0, 1, 2, 3))]
    intf = matrix_interference({("tau1", "tau2"): 10.0})
    return [t1, t2], be, intf


def fig5_taskset():
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return [t1, t2], [bem, bec], intf


WORKLOADS = {"fig4": fig4_taskset, "fig5": fig5_taskset}


def _run(workload, dt, trace, horizon=200.0, metrics=False):
    rts, bes, intf = WORKLOADS[workload]()
    reg = MetricsRegistry() if metrics else None
    sim = Simulator(4, rts, be_tasks=bes, interference=intf,
                    rt_gang_enabled=True, dt=dt,
                    throttle_mode="reactive", trace=trace,
                    record_counters=True, metrics=reg,
                    rta_bounds={t.name: 3.0 * t.period for t in rts})
    return sim.run(horizon)


def _payload(r):
    """Everything in the SimResult except the timeline itself,
    serialized canonically for a byte-for-byte comparison."""
    d = dataclasses.asdict(r)
    d.pop("trace")
    return json.dumps(d, sort_keys=True, default=repr)


@pytest.mark.parametrize("workload", ["fig4", "fig5"])
@pytest.mark.parametrize("dt", [None, 0.05])
def test_trace_off_byte_identical(workload, dt):
    on = _run(workload, dt, True, metrics=True)
    off = _run(workload, dt, False, metrics=True)
    assert _payload(on) == _payload(off)
    # the pieces the grid/sweep sim-checks actually consume, spelled out
    assert off.deadline_misses == on.deadline_misses
    assert off.miss_times == on.miss_times
    assert off.response_times == on.response_times
    assert off.rta_margins == on.rta_margins
    assert off.parity_metrics == on.parity_metrics
    assert off.metrics == on.metrics
    for name in on.response_times:
        assert off.percentiles(name) == on.percentiles(name)
    # trace=False really did skip the timeline
    assert isinstance(off.trace, NullTrace)
    assert off.trace.segments == [] and not off.trace._open
    assert isinstance(on.trace, Trace) and on.trace.segments


def test_null_trace_queries_work_on_empty_timeline():
    tr = NullTrace(4)
    tr.record(0, "x", 0.0, 1.0)
    tr.finish()
    assert tr.segments == []
    assert tr.busy("x") == 0.0
    assert tr.intervals("x") == []
    assert tr.to_csv() == "core,label,t0,t1"
    assert tr.render_ascii() == "(empty trace)"


def test_trace_default_is_on():
    rts, bes, intf = WORKLOADS["fig4"]()
    sim = Simulator(4, rts, be_tasks=bes, interference=intf, dt=None)
    r = sim.run(50.0)
    assert isinstance(r.trace, Trace) and not isinstance(r.trace, NullTrace)
    assert r.trace.segments
