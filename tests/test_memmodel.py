"""MemoryModel layer (core/memmodel.py, DESIGN.md §10): incremental
co-runner maintenance, RT-thread bandwidth charging in both engines, and
RTG-throttle stall semantics (critical member protected, siblings paused
mid-job)."""
import math

import pytest

from repro.core.gang import BETask, RTTask
from repro.core.memmodel import (BE, IDLE, RT, MemoryModel,
                                 distance_interference)
from repro.core.sim import Simulator, matrix_interference
from repro.core.throttle import BandwidthRegulator
from repro.vgang.formation import (VirtualGang, critical_member,
                                   rtg_sibling_budget)
from repro.vgang.rta import rtg_throttle_wcet
from repro.vgang.sched import VirtualGangPolicy

DT = 0.0125


# ---------------------------------------------------------------------
# incremental maintenance invariants
# ---------------------------------------------------------------------

def _mk(name, core=0):
    return RTTask(name, wcet=1.0, period=10.0, cores=(core,), prio=1)


def test_memmodel_epoch_moves_only_on_presence_transitions():
    """The distinct-name-set epoch — the slowdown cache key — bumps
    exactly on 0<->1 presence transitions, so steady-state occupancy
    churn (a name present elsewhere) keeps every cached aggregate."""
    intf = matrix_interference({("a", "b"): 2.0, ("a", "c"): 3.0})
    mm = MemoryModel(4, intf, BandwidthRegulator(4))
    mm.set_rt(0, _mk("a"))
    mm.set_rt(1, _mk("b", 1))
    assert mm.slowdown("a") == 2.0
    e = mm.epoch
    mm.set_be(2, ("b",), 0.5)        # b now on two cores: no transition
    assert mm.epoch == e
    assert mm.slowdown("a") == 2.0
    mm.clear(1)                      # b still present via core 2
    assert mm.epoch == e
    assert mm.slowdown("a") == 2.0
    mm.clear(2)                      # b 1 -> 0: transition
    assert mm.epoch != e
    assert mm.slowdown("a") == 1.0
    mm.set_be(3, ("c",), 1.0)
    assert mm.slowdown("a") == 3.0
    assert mm.slowdown("b") == 1.0   # no (b, c) entry
    assert mm.slowdown("c") == 1.0   # own name never interferes


def test_memmodel_reassign_same_occupant_is_noop():
    mm = MemoryModel(2, lambda v, a: 1.0, BandwidthRegulator(2))
    t = _mk("a")
    mm.set_rt(0, t)
    e = mm.epoch
    mm.set_rt(0, t)
    assert mm.epoch == e
    assert mm.kind[0] == RT and mm.names[0] == ("a",)
    mm.clear(0)
    assert mm.kind[0] == IDLE and mm.names[0] == ()


def test_memmodel_be_fractional_rate():
    mm = MemoryModel(1, lambda v, a: 1.0, BandwidthRegulator(1))
    mm.set_be(0, ("x", "y"), 0.75)
    assert mm.kind[0] == BE
    assert mm.rates[0] == 0.75
    assert mm.next_trip_time(0, 0.0) == float("inf")   # budget inf


def test_memmodel_slowdown_matches_bruteforce():
    """The epoch-memoized aggregate equals a from-scratch max over the
    present occupant names after any update sequence."""
    table = {("a", "b"): 2.0, ("b", "a"): 1.5, ("a", "c"): 4.0,
             ("c", "b"): 2.5}
    intf = matrix_interference(table)
    mm = MemoryModel(3, intf, BandwidthRegulator(3))
    seq = [("rt", 0, "a"), ("be", 1, ("b", "c")), ("clear", 0, None),
           ("rt", 0, "b"), ("clear", 1, None), ("be", 2, ("a",)),
           ("rt", 1, "c"), ("clear", 2, None)]
    for op, core, arg in seq:
        if op == "rt":
            mm.set_rt(core, _mk(arg, core))
        elif op == "be":
            mm.set_be(core, arg, 0.0)
        else:
            mm.clear(core)
        present = {nm for names in mm.names for nm in names}
        for victim in ("a", "b", "c", "zz"):
            want = max([1.0] + [intf(victim, nm) for nm in present
                                if nm != victim])
            assert mm.slowdown(victim) == want, (op, core, victim)


# ---------------------------------------------------------------------
# location-dependent interference (ROADMAP: formation under per-core
# locality) — the slowdown memo must key on (victim, core), versioned by
# the location epoch, not on the victim name alone
# ---------------------------------------------------------------------

def _near_far_intf(victim, aggressor, dist):
    """Heterogeneous per-core interference: a neighbour (shared cache
    slice) slows the victim 3x, a distant core only 1.5x."""
    return 3.0 if dist <= 1 else 1.5


def test_distance_aware_slowdown_tracks_corunner_location():
    """A co-runner moving cores changes no 0<->1 name presence — the old
    name-keyed memo would return the stale aggregate. The (victim, core)
    memo keyed on the location epoch must see the move."""
    intf = distance_interference(_near_far_intf)
    mm = MemoryModel(4, intf, BandwidthRegulator(4))
    mm.set_rt(0, _mk("a"))
    mm.set_be(1, ("b",), 0.0)               # neighbour: 3x
    assert mm.slowdown("a", 0) == 3.0
    mm.set_be(3, ("b",), 0.0)               # b appears far too
    mm.clear(1)                             # ...and leaves the nearby core
    # name multiset never saw a 0<->1 transition for "b", yet the only
    # remaining b sits at distance 3
    assert mm.slowdown("a", 0) == 1.5
    mm.set_be(1, ("b",), 0.0)
    assert mm.slowdown("a", 0) == 3.0
    # the aggregate is per *victim core* as well
    mm.clear(1)
    mm.set_rt(2, _mk("a", 2))
    assert mm.slowdown("a", 2) == 3.0       # core 3 is its neighbour
    assert mm.slowdown("a", 0) == 1.5


def test_distance_aware_engines_agree():
    """Both engines drive the same distance-aware model: a victim gang
    co-running with a near aggressor is slower than with a far one, and
    the quantum/event engines agree on every response time."""
    def build(far, dt):
        agg_core = 3 if far else 1
        t1 = RTTask("vic", wcet=2.0, period=10.0, cores=(0,), prio=2,
                    n_jobs=1)
        t2 = RTTask("agg", wcet=8.0, period=10.0, cores=(agg_core,),
                    prio=2, n_jobs=1)
        return Simulator(4, [t1, t2],
                         interference=distance_interference(_near_far_intf),
                         rt_gang_enabled=True, dt=dt)

    for far, want in ((False, 6.0), (True, 3.0)):
        q = build(far, DT).run(10.0)
        e = build(far, None).run(10.0)
        assert e.response_times["vic"][0] == pytest.approx(want)
        assert abs(q.response_times["vic"][0] -
                   e.response_times["vic"][0]) <= 2 * DT + 1e-9


# ---------------------------------------------------------------------
# RT-thread charging: quantum-vs-event equivalence (the ISSUE's
# acceptance criterion — Fig.4/Fig.5 tasksets with charging enabled)
# ---------------------------------------------------------------------

class CapPolicy:
    """Budget policy capping every core — including RT-occupied ones —
    so RT threads trip budgets (what RTG-throttle does selectively)."""

    def __init__(self, budget):
        self.budget = budget

    def apply(self, g, reg):
        return reg.set_gang_budget(self.budget)


def fig4_charged(dt):
    t1 = RTTask("tau1", wcet=2.0, period=10, cores=(0, 1), prio=2,
                mem_intensity=0.8)
    t2 = RTTask("tau2", wcet=4.0, period=10, cores=(2, 3), prio=1,
                mem_intensity=0.3)
    be = [BETask("tau3", cores=(0, 1, 2, 3), mem_rate=1.0)]
    intf = matrix_interference({("tau1", "tau2"): 1.5,
                                ("tau2", "tau1"): 1.2})
    return Simulator(4, [t1, t2], be_tasks=be, interference=intf,
                     rt_gang_enabled=True, dt=dt,
                     budget_policy=CapPolicy(0.4))


def fig5_charged(dt):
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1, mem_intensity=0.6)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1, mem_intensity=0.2)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return Simulator(4, [t1, t2], be_tasks=[bem, bec], interference=intf,
                     rt_gang_enabled=True, dt=dt,
                     throttle_mode="reactive",
                     budget_policy=CapPolicy(0.5))


def test_fig4_rt_charging_exact_stall_pattern():
    """Single-gang arithmetic: tau1 (rate 0.8, cap 0.4) runs 0.5 ms per
    1 ms window then pauses mid-job — 2 ms of work completes at 3.5."""
    r = fig4_charged(None).run(10.0)
    assert r.response_times["tau1"][0] == pytest.approx(3.5)
    assert r.throttle_events > 0


@pytest.mark.parametrize("builder", [fig4_charged, fig5_charged])
def test_rt_charging_equivalence(builder):
    """Quantum and event engines agree — response times, misses,
    throttle trips and best-effort progress — with RT-thread charging
    enabled (dt-bias tolerance; the fractional quantum admission keeps
    per-window progress aligned, so the residual gap is O(dt))."""
    horizon = 60.0
    q = builder(DT).run(horizon)
    e = builder(None).run(horizon)
    assert q.engine == "quantum" and e.engine == "event"
    windows = horizon / 1.0
    for name in ("tau1", "tau2"):
        assert len(q.response_times[name]) == len(e.response_times[name])
        for rq, re_ in zip(q.response_times[name], e.response_times[name]):
            assert abs(rq - re_) <= 4 * DT + 1e-9, name
    assert q.deadline_misses == e.deadline_misses
    assert q.throttle_events == e.throttle_events
    for b in q.be_progress:
        assert q.be_progress[b] == pytest.approx(
            e.be_progress[b], abs=windows * DT + 1e-6), b


# ---------------------------------------------------------------------
# RTG-throttle: critical member protected, sibling paused mid-job
# ---------------------------------------------------------------------

def rtg_pair():
    a = RTTask("a", wcet=3.0, period=20.0, cores=(0,), prio=5,
               mem_intensity=0.2, n_jobs=1)
    b = RTTask("b", wcet=3.0, period=20.0, cores=(1,), prio=5,
               mem_rate=2.0, n_jobs=1)
    intf = matrix_interference({("a", "b"): 2.0, ("b", "a"): 1.25})
    return VirtualGang("ab", [a, b], prio=5), intf


def test_rtg_throttle_protects_critical_member():
    """With sibling b capped at the critical member's headroom (0.8
    units/window; b runs 0.4 ms then stalls), a's per-window work is
    0.4/2 + 0.6/1 = 0.8 -> a finishes at 3.8. Unthrottled, b interferes
    the whole window and a finishes at 4.875. Once a completes, the
    surviving sibling runs unthrottled and interference-free."""
    vg, intf = rtg_pair()
    assert critical_member(vg, intf).name == "a"
    assert rtg_sibling_budget(vg, intf) == pytest.approx(0.8)

    pol = VirtualGangPolicy([vg], 2, intf, auto_prio=False,
                            rtg_throttle=True)
    r = pol.simulate(20.0)
    assert r.response_times["a"][0] == pytest.approx(3.8)
    # b: 0.32 work/window while a lives (done 1.28 by t=3.4, stalled
    # until a finishes at 3.8), then unthrottled and alone: 3.8 + 1.72
    assert r.response_times["b"][0] == pytest.approx(5.52)
    assert r.throttle_events > 0

    base = VirtualGangPolicy([vg, ][:], 2, intf, auto_prio=False,
                             rtg_throttle=False)
    r0 = base.simulate(20.0)
    assert r0.response_times["a"][0] == pytest.approx(4.875)
    assert r0.throttle_events == 0

    # the duty-cycle RTA bound is sound (it ignores the post-critical
    # unthrottling, so it upper-bounds the simulated completion)
    bound = rtg_throttle_wcet(vg, intf)
    assert bound == pytest.approx(9.15)
    assert bound >= r.response_times["b"][0] - 1e-9


def test_rtg_throttle_engines_agree():
    vg, intf = rtg_pair()
    q = VirtualGangPolicy([vg], 2, intf, auto_prio=False,
                          rtg_throttle=True).build_simulator(dt=DT)
    e = VirtualGangPolicy([vg], 2, intf, auto_prio=False,
                          rtg_throttle=True).build_simulator(dt=None)
    rq, re_ = q.run(20.0), e.run(20.0)
    for name in ("a", "b"):
        assert abs(rq.response_times[name][0] -
                   re_.response_times[name][0]) <= 4 * DT + 1e-9
    assert rq.throttle_events == re_.throttle_events


def test_starved_sibling_rta_rejects():
    """A zero-headroom critical member (intensity 1.0) starves any
    traffic-generating sibling: the bound is inf, never a hang."""
    a = RTTask("a", wcet=1.0, period=20.0, cores=(0,), prio=5,
               mem_intensity=1.0)
    b = RTTask("b", wcet=1.0, period=20.0, cores=(1,), prio=5,
               mem_intensity=0.5)
    vg = VirtualGang("ab", [a, b], prio=5)
    assert rtg_sibling_budget(vg) == 0.0
    assert rtg_throttle_wcet(vg) == float("inf")


def test_traffic_rate_derivation():
    t = RTTask("t", wcet=1, period=10, cores=(0,), prio=1,
               mem_intensity=0.6)
    assert t.traffic_rate == 0.6
    t2 = RTTask("t2", wcet=1, period=10, cores=(0,), prio=1,
                mem_intensity=0.6, mem_rate=2.5)
    assert t2.traffic_rate == 2.5
