"""Observability layer (src/repro/obs/, DESIGN.md §12): metrics
registry semantics, the engine-parity contract on the fig4/fig5
workloads, RTA-margin accounting, timeline agreement between the two
engines, and the Perfetto export round-trip."""
import json

import pytest

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference
from repro.core.tracing import Trace
from repro.obs.margins import margin_summary, merge_margins, overall
from repro.obs.metrics import MetricsRegistry, series_key
from repro.obs.perfetto import (export_sim, export_trace,
                                segments_from_json, validate_chrome_trace)

DT = 0.05


def fig4_taskset():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2,
                mem_budget=1e9)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1,
                mem_budget=1e9)
    be = [BETask("tau3", cores=(0, 1, 2, 3))]
    return [t1, t2], be, None


def fig5_taskset():
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return [t1, t2], [bem, bec], intf


def run(taskset, dt, horizon=120.0, **kw):
    rts, bes, intf = taskset()
    if intf is not None:
        kw["interference"] = intf
    sim = Simulator(4, rts, be_tasks=bes, rt_gang_enabled=True, dt=dt,
                    throttle_mode="reactive", **kw)
    return sim, sim.run(horizon)


# ---------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------

def test_registry_get_or_create_shares_instruments():
    reg = MetricsRegistry()
    a = reg.counter("x", gang="g0")
    b = reg.counter("x", gang="g0")
    c = reg.counter("x", gang="g1")
    assert a is b and a is not c
    a.value += 3
    assert reg.snapshot() == {"x{gang=g0}": 3, "x{gang=g1}": 0}


def test_series_key_sorts_labels():
    assert series_key("n", {}) == "n"
    assert series_key("n", {"b": 2, "a": 1}) == "n{a=1,b=2}"


def test_common_labels_fold_into_every_series():
    reg = MetricsRegistry(common_labels={"policy": "rtgT"})
    reg.counter("trips", core=0).value += 1
    assert reg.snapshot() == {"trips{core=0,policy=rtgT}": 1}


def test_disabled_registry_hands_out_working_detached_instruments():
    reg = MetricsRegistry(enabled=False)
    a = reg.counter("x")
    b = reg.counter("x")
    assert a is not b           # nothing is indexed or shared
    a.inc(2)
    assert a.value == 2         # the caller's accounting still works
    assert reg.snapshot() == {}
    assert reg.parity_snapshot() == {}


def test_histogram_buckets_count_and_summary():
    reg = MetricsRegistry()
    h = reg.histogram("lat", bounds=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0, 0.7):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["min"] == 0.5 and s["max"] == 50.0
    assert s["buckets"] == {"1.0": 2, "10.0": 1, "+inf": 1}


def test_parity_snapshot_rejects_non_integer():
    reg = MetricsRegistry()
    reg.counter("bad", parity=True).value = 1.5
    with pytest.raises(ValueError):
        reg.parity_snapshot()


# ---------------------------------------------------------------------
# margins
# ---------------------------------------------------------------------

def test_margin_summary_flags_negative_margins():
    resp = {"a": [1.0, 2.0, 4.5], "b": []}
    out = margin_summary(resp, {"a": 4.0, "b": 7.0})
    assert out["a"]["jobs"] == 3
    assert out["a"]["worst_margin"] == pytest.approx(-0.5)
    assert out["a"]["negative"] == 1
    assert out["b"] == {"bound": 7.0, "jobs": 0, "worst_margin": None,
                        "mean_margin": None, "negative": 0}


def test_merge_margins_pools_jobs_and_mins_worst():
    a = margin_summary({"t": [1.0]}, {"t": 5.0})
    b = margin_summary({"t": [3.0, 4.0]}, {"t": 5.0})
    merged = merge_margins(dict(a), b)
    assert merged["t"]["jobs"] == 3
    assert merged["t"]["worst_margin"] == pytest.approx(1.0)
    assert merged["t"]["mean_margin"] == pytest.approx((4 + 2 + 1) / 3)
    assert overall(merged) == {
        "tasks": 1, "jobs": 3,
        "worst_margin": pytest.approx(1.0), "negative": 0}


def test_sim_result_carries_margins_and_metrics():
    reg = MetricsRegistry()
    _, r = run(fig5_taskset, None, metrics=reg,
               rta_bounds={"tau1": 5.25, "tau2": 15.0})
    assert r.rta_margins["tau1"]["jobs"] > 0
    assert r.rta_margins["tau1"]["negative"] == 0
    assert r.rta_margins["tau2"]["negative"] == 0
    assert r.metrics is not None and r.parity_metrics is not None
    # the histogram flowed into the shared registry too
    assert "rta.margin{gang=tau1}" in r.metrics
    assert r.parity_metrics["glock.acquisitions"] > 0


# ---------------------------------------------------------------------
# engine parity: byte-identical parity counters on fig4/fig5
# ---------------------------------------------------------------------

@pytest.mark.parametrize("taskset", [fig4_taskset, fig5_taskset],
                         ids=["fig4", "fig5"])
def test_engine_parity_metrics(taskset):
    regs = {}
    snaps = {}
    for engine, dt in (("quantum", DT), ("event", None)):
        regs[engine] = MetricsRegistry()
        _, r = run(taskset, dt, metrics=regs[engine])
        snaps[engine] = r.parity_metrics
    assert snaps["quantum"] == snaps["event"]
    # byte-identical, not merely equal-as-dicts
    assert json.dumps(snaps["quantum"], sort_keys=True) == \
        json.dumps(snaps["event"], sort_keys=True)
    # and non-vacuous: the scheduler and task series actually counted
    s = snaps["event"]
    assert s["glock.acquisitions"] > 0
    assert s["task.completions{gang=tau1}"] > 0
    assert any(k.startswith("task.releases") for k in s)


def test_parity_includes_fault_counters():
    from repro.core.faults import Enforcement, FaultPlan, WcetOverrun
    plan = FaultPlan(faults=(WcetOverrun("tau2", factor=3.0, prob=1.0),),
                     seed=7)
    enf = Enforcement(action="abort", factor=1.2)
    snaps = {}
    for engine, dt in (("quantum", DT), ("event", None)):
        reg = MetricsRegistry()
        rts, bes, intf = fig5_taskset()
        sim = Simulator(4, rts, be_tasks=bes, interference=intf,
                        rt_gang_enabled=True, dt=dt, fault_plan=plan,
                        enforcement=enf, metrics=reg)
        snaps[engine] = sim.run(120.0).parity_metrics
    assert snaps["quantum"] == snaps["event"]
    assert snaps["event"]["faults.injected{kind=overrun}"] > 0
    assert snaps["event"]["faults.enforced{action=abort}"] > 0


# ---------------------------------------------------------------------
# timeline agreement: Trace.intervals across engines on fig5
# ---------------------------------------------------------------------

def test_intervals_agree_across_engines_fig5():
    # the quantum engine emits dt-sized touching segments, the event
    # engine long exact ones; merged per-task intervals must agree to
    # within the quantum discretization envelope
    _, q = run(fig5_taskset, 0.025)
    _, e = run(fig5_taskset, None)
    for name in ("tau1", "tau2"):
        qi = q.trace.intervals(name, tol=0.026)
        ei = e.trace.intervals(name)
        assert len(qi) == len(ei), name
        for (q0, q1), (e0, e1) in zip(qi, ei):
            assert q0 == pytest.approx(e0, abs=0.06)
            assert q1 == pytest.approx(e1, abs=0.06)


# ---------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------

def test_perfetto_roundtrip_exact():
    sim, r = run(fig5_taskset, None, record_counters=True)
    data = export_sim(sim, r, title="fig5")
    assert validate_chrome_trace(data) == []
    # through an actual JSON serialization, as a viewer would read it
    parsed = json.loads(json.dumps(data))
    got = segments_from_json(parsed)
    want = sorted(((s.core, s.label, s.t0, s.t1)
                   for s in r.trace.segments if s.label is not None),
                  key=lambda t: (t[0], t[2]))
    assert got == want


def test_perfetto_span_classification_and_counter_tracks():
    sim, r = run(fig5_taskset, None, record_counters=True)
    data = export_sim(sim, r, title="fig5")
    evs = data["traceEvents"]
    cats = {e["cat"] for e in evs if e["ph"] == "X"}
    assert "gang" in cats and "be" in cats
    # fig5's regulator stalls BE cores: throttled spans colored apart
    assert "throttle" in cats
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert any(c.startswith("bw core") for c in counters)
    assert "glock held" in counters
    # per-core thread metadata for the viewer
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {f"core {c}" for c in range(4)}


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "Z"}]}) != []
    bad_counter = {"traceEvents": [
        {"ph": "C", "pid": 2, "tid": 0, "name": "c", "ts": 1.0,
         "args": {"v": "high"}}]}
    assert validate_chrome_trace(bad_counter) != []


def test_export_trace_skips_idle_and_classifies_pathology():
    tr = Trace(2)
    tr.record(0, "g0", 0.0, 1.0)
    tr.record(0, None, 1.0, 2.0)
    tr.record(1, "throttled:be", 0.0, 0.5)
    tr.record(1, "dem:g1", 0.5, 1.0)
    tr.record(1, "aborted:g1#3", 1.0, 1.5)
    data = export_trace(tr, rt_names=["g0"])
    xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"g0", "throttled:be", "dem:g1",
                                       "aborted:g1#3"}
    by_name = {e["name"]: e["cat"] for e in xs}
    assert by_name == {"g0": "gang", "throttled:be": "throttle",
                       "dem:g1": "dem", "aborted:g1#3": "aborted"}


# ---------------------------------------------------------------------
# tracing satellites: CSV round-trip, zero-span render
# ---------------------------------------------------------------------

def test_trace_csv_roundtrip_with_pathological_labels():
    tr = Trace(2)
    tr.record(0, "tau1", 0.0, 2.5)
    tr.record(0, None, 2.5, 3.0)            # idle -> empty field
    tr.record(1, "throttled:be_mem", 0.0, 1.0)
    tr.record(1, 'odd,"label"', 1.0, 2.0)   # quoting stress
    text = tr.to_csv()
    back = Trace.from_csv(text)
    assert back.n_cores == 2
    assert [(s.core, s.label, s.t0, s.t1) for s in back.segments] == \
        [(s.core, s.label, s.t0, s.t1) for s in tr.segments]


def test_render_ascii_zero_span_does_not_divide():
    tr = Trace(1)
    tr.record(0, "t", 5.0, 5.1)
    out = tr.render_ascii(t_start=5.0, t_end=5.0)
    assert "core0" in out       # renders the instant instead of raising
