"""HLO analyzer validation against hand-computable compiled artifacts."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_analysis import analyze, shape_bytes, xla_cost_analysis


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert shape_bytes("pred[]") == 1


def test_single_matmul_flops_exact():
    m, k, n = 128, 256, 512
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 2 * m * k * n
    assert r["bytes"] >= (m * k + k * n + m * n) * 4


def test_scan_trip_count_multiplies():
    m, L = 64, 7

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((L, m, m), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == L * 2 * m ** 3
    # XLA's own cost_analysis counts the body once — the whole reason this
    # module exists:
    xla = xla_cost_analysis(c)
    assert xla["flops"] < r["flops"]


def test_nested_tuple_carry_and_nested_scans():
    m = 32

    def nested(x, ws):
        def outer(carry, w):
            def inner(ci, _):
                return ci["v"] @ w, None
            y, _ = jax.lax.scan(lambda c, _: ({"v": c["v"] @ w}, None),
                                carry, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y["v"]

    c = jax.jit(nested).lower(
        {"v": jax.ShapeDtypeStruct((m, m), jnp.float32)},
        jax.ShapeDtypeStruct((5, m, m), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 5 * 3 * 2 * m ** 3


def test_no_collectives_single_device():
    c = jax.jit(lambda a: (a @ a).sum()).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["collective_total"] == 0
