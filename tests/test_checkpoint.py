"""Checkpoint: exact roundtrip, atomic publication, retention, async save,
deterministic restart (fault tolerance), elastic re-shard path."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.runner import (RunnerConfig, SimulatedFailure,
                                   TrainRunner)


def state_tree(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": jnp.ones((8, 8)) * 0.5,
                    "count": jnp.int32(7)},
            "step": jnp.int32(7)}


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = state_tree()
    mgr.save(st, 7, extra={"data_step": 7}, blocking=True)
    restored, extra = mgr.restore(st)
    assert extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = state_tree()
    for step in (1, 2, 3, 4):
        mgr.save(st, step, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    st = state_tree()
    mgr.save(st, 5, blocking=True)
    # a torn checkpoint without .done marker must be invisible
    os.makedirs(tmp_path / "step_9", exist_ok=True)
    assert mgr.latest_step() == 5


def test_restart_is_deterministic(tmp_path):
    """Train 12 steps straight vs fail-at-8 + restart: identical final loss
    (checkpoint + step-indexed data resume)."""
    cfg = reduced(get_config("qwen2-7b"))
    mesh = make_local_mesh(1, 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=8, kv_block=8)
    api = build_model(cfg, parallel, mesh)
    data_cfg = DataConfig(seq_len=32, global_batch=2,
                          vocab_size=cfg.vocab_size)

    def make_runner(d, **kw):
        return TrainRunner(api, Optimizer(OptConfig(lr=1e-3, warmup=2,
                                                    decay_steps=12)),
                           data_cfg,
                           RunnerConfig(total_steps=12, ckpt_every=4,
                                        ckpt_dir=str(d), **kw))

    r_straight = make_runner(tmp_path / "a")
    r_straight.run()
    straight = [m["loss"] for m in r_straight.metrics_log]

    r_fail = make_runner(tmp_path / "b", fail_at_step=8)
    with pytest.raises(SimulatedFailure):
        r_fail.run()
    r_resume = make_runner(tmp_path / "b")
    r_resume.run()
    resumed = {m["step"]: m["loss"] for m in
               r_fail.metrics_log + r_resume.metrics_log}
    for i, loss in enumerate(straight):
        assert loss == pytest.approx(resumed[i], rel=1e-5), (i, loss,
                                                             resumed[i])


def test_elastic_restore_with_different_sharding(tmp_path):
    """A checkpoint restores under a different sharding spec (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_local_mesh(1, 1)
    mgr = CheckpointManager(str(tmp_path))
    st = state_tree()
    mgr.save(st, 1, blocking=True)
    sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), st)
    restored, _ = mgr.restore(st, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
