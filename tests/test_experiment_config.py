"""Declarative ExperimentConfig layer (src/repro/experiment/,
DESIGN.md §14): serialization round-trips, nested hydration, unknown-key
rejection with field paths, cross-field validation, CLI override
precedence (base preset < --config file < explicit flags), provenance
digests, the checked-in canonical configs, and a grid smoke asserting a
--config run produces rows identical to the legacy-flag spelling."""
import argparse
import glob
import json
import os

import pytest

from repro.experiment import (ConfigurationError, ExperimentConfig,
                              GRID_SMOKE_OVERRIDES, UNSET, add_flags,
                              default_bench_faults_config,
                              default_grid_config, default_sweep_config,
                              derive_flags, resolve_config)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CONFIG_DIR = os.path.join(ROOT, "configs", "experiments")


# ---- serialization ---------------------------------------------------

def test_round_trip_json():
    cfg = default_grid_config()
    again = ExperimentConfig.from_json(cfg.to_json())
    assert again == cfg
    assert again.to_json() == cfg.to_json()


def test_stable_field_order():
    cfg = default_grid_config()
    keys = list(json.loads(cfg.to_json()))
    # declaration order, not alphabetical — stable across dumps
    assert keys == list(json.loads(cfg.to_json()))
    assert keys[0] == "kind"


def test_save_load(tmp_path):
    path = str(tmp_path / "exp.json")
    cfg = default_sweep_config()
    cfg.save(path)
    assert ExperimentConfig.load(path) == cfg


def test_nested_hydration_coerces_sequences():
    cfg = ExperimentConfig.from_dict({
        "kind": "grid", "name": "t",
        "taskset": {"cores": [4, 8], "utils": [1, 2]},
    })
    assert cfg.taskset.cores == (4, 8)
    assert cfg.taskset.utils == (1.0, 2.0)
    assert isinstance(cfg.taskset.utils[0], float)


# ---- validation ------------------------------------------------------

def test_unknown_top_level_key():
    with pytest.raises(ConfigurationError) as ei:
        ExperimentConfig.from_dict({"kind": "grid", "name": "t",
                                    "tasksetx": {}})
    assert "tasksetx" in str(ei.value)


def test_unknown_nested_key_carries_field_path():
    with pytest.raises(ConfigurationError) as ei:
        ExperimentConfig.from_dict({
            "kind": "grid", "name": "t",
            "taskset": {"coresx": [4]},
        })
    msg = str(ei.value)
    assert "taskset" in msg and "coresx" in msg


def test_bad_kind_rejected():
    with pytest.raises(ConfigurationError) as ei:
        ExperimentConfig.from_dict({"kind": "nope", "name": "t"})
    assert "kind" in str(ei.value)


def test_reclaim_requires_rtg_throttle():
    with pytest.raises(ConfigurationError) as ei:
        default_bench_faults_config().merged(
            {"policy": {"reclaim": True, "rtg_throttle": False}})
    assert "reclaim" in str(ei.value)


def test_type_mismatch_carries_field_path():
    with pytest.raises(ConfigurationError) as ei:
        default_grid_config().merged({"engine": {"cycles": "fast"}})
    assert "engine.cycles" in str(ei.value)


# ---- functional updates ---------------------------------------------

def test_merged_is_deep_and_non_destructive():
    base = default_grid_config()
    new = base.merged({"taskset": {"n_per_point": 7}})
    assert new.taskset.n_per_point == 7
    assert base.taskset.n_per_point != 7
    assert new.taskset.cores == base.taskset.cores  # untouched siblings


def test_with_value_and_value_at():
    cfg = default_grid_config().with_value("engine.sim_check", 3)
    assert cfg.value_at("engine.sim_check") == 3
    with pytest.raises(ConfigurationError):
        cfg.with_value("engine.nope", 1)


def test_content_digest_tracks_content():
    a = default_grid_config()
    b = a.merged({"taskset": {"seed": 1}})
    assert a.content_digest() != b.content_digest()
    assert a.content_digest() == default_grid_config().content_digest()


# ---- CLI resolution --------------------------------------------------

def _grid_cli(argv, tmp_path=None, config=None):
    base = default_grid_config()
    flags = derive_flags(ExperimentConfig,
                         ("taskset.seed", "taskset.n_per_point",
                          "engine.sim_check", "policy.heuristics"),
                         aliases={"taskset.n_per_point": "--n"})
    ap = argparse.ArgumentParser()
    add_flags(ap, flags, base)
    if config is not None:
        path = str(tmp_path / "c.json")
        config.save(path)
        argv = ["--config", path] + argv
    args = ap.parse_args(argv)
    return resolve_config(base, args, flags, expected_kind="grid")


def test_cli_flag_overrides_base():
    cfg = _grid_cli(["--seed", "5", "--n", "3"])
    assert cfg.taskset.seed == 5 and cfg.taskset.n_per_point == 3


def test_cli_file_overrides_base_flag_overrides_file(tmp_path):
    filecfg = default_grid_config().merged(
        {"taskset": {"seed": 9, "n_per_point": 11}})
    cfg = _grid_cli(["--seed", "5"], tmp_path, filecfg)
    assert cfg.taskset.seed == 5          # explicit flag wins
    assert cfg.taskset.n_per_point == 11  # file overlay survives
    # and untouched fields still come from the base preset
    assert cfg.engine.cycles == default_grid_config().engine.cycles


def test_cli_tuple_flag_parses_comma_list():
    cfg = _grid_cli(["--heuristics", "ffd,intfaware"])
    assert cfg.policy.heuristics == ("ffd", "intfaware")


def test_cli_wrong_kind_rejected(tmp_path):
    with pytest.raises(ConfigurationError) as ei:
        _grid_cli([], tmp_path, default_sweep_config())
    assert "kind" in str(ei.value)


def test_unset_sentinel_means_not_passed():
    base = default_grid_config()
    flags = derive_flags(ExperimentConfig, ("taskset.seed",))
    ap = argparse.ArgumentParser()
    add_flags(ap, flags, base)
    args = ap.parse_args([])
    assert getattr(args, flags[0].dest) is UNSET
    assert resolve_config(base, args, flags) == base


# ---- checked-in canonical configs -----------------------------------

def test_checked_in_configs_parse_and_match_kind():
    files = sorted(glob.glob(os.path.join(CONFIG_DIR, "*.json")))
    assert len(files) >= 7
    for path in files:
        cfg = ExperimentConfig.load(path)
        assert cfg.kind in ("grid", "sweep", "bench_sim",
                            "bench_executor", "bench_faults"), path
        # digest is pure content: reload -> identical digest
        assert cfg.content_digest() == \
            ExperimentConfig.load(path).content_digest()


def test_grid_smoke_config_equals_smoke_expansion():
    """configs/experiments/grid_smoke.json is the --smoke expansion
    written out explicitly (modulo name/output.out), so a --smoke run
    and a config-file run resolve to the same experiment."""
    path = os.path.join(CONFIG_DIR, "grid_smoke.json")
    filecfg = ExperimentConfig.load(path)
    expanded = default_grid_config().merged(GRID_SMOKE_OVERRIDES).merged(
        {"smoke": False, "name": filecfg.name,
         "output": {"out": filecfg.output.out}})
    assert filecfg == expanded
    assert filecfg.content_digest() == expanded.content_digest()


# ---- end-to-end: grid --config == legacy flags ----------------------

def test_grid_config_run_matches_legacy_flags(tmp_path):
    from repro.vgang.grid import main as grid_main

    def rows(out_dir):
        out = {}
        for p in sorted(glob.glob(os.path.join(out_dir, "grid_*.json"))):
            with open(p) as fh:
                data = json.load(fh)
            out[os.path.basename(p)] = [
                {k: v for k, v in r.items() if not k.startswith("wall")}
                for r in data["rows"]]
        return out

    legacy = str(tmp_path / "legacy")
    conf = str(tmp_path / "conf")
    argv = ["--cores", "4", "--dists", "mixed", "--utils", "0.8",
            "--n", "4", "--heuristics", "ffd,intfaware",
            "--sim-check", "1"]
    grid_main(argv + ["--out", legacy])

    cfgpath = str(tmp_path / "grid.json")
    default_grid_config().merged({
        "taskset": {"cores": [4], "dists": ["mixed"], "utils": [0.8],
                    "n_per_point": 4},
        "policy": {"heuristics": ["ffd", "intfaware"]},
        "engine": {"sim_check": 1},
        "output": {"out": conf},
    }).save(cfgpath)
    grid_main(["--config", cfgpath])

    assert rows(legacy) == rows(conf)
    with open(os.path.join(conf, "summary.json")) as fh:
        summary = json.load(fh)
    assert summary["config_digest"] == \
        ExperimentConfig.load(cfgpath).content_digest()
    assert summary["config"]["taskset"]["n_per_point"] == 4


# ---- unknown-key rejection at the engine boundary (satellite) -------

def test_simulator_rejects_unknown_kwargs():
    from repro.core.gang import RTTask
    from repro.core.sim import Simulator
    t = RTTask("t", wcet=1.0, period=10.0, cores=(0,), prio=1)
    with pytest.raises(TypeError) as ei:
        Simulator(1, [t], typo_option=True)
    msg = str(ei.value)
    assert "typo_option" in msg and "valid options" in msg


def test_vgang_policy_rejects_unknown_kwargs():
    from repro.core.gang import RTTask
    from repro.vgang.formation import singleton_vgangs
    from repro.vgang.sched import VirtualGangPolicy
    t = RTTask("t", wcet=1.0, period=10.0, cores=(0,), prio=1)
    with pytest.raises(TypeError) as ei:
        VirtualGangPolicy(1, singleton_vgangs([t]), reclam=True)
    msg = str(ei.value)
    assert "reclam" in msg and "valid options" in msg


def test_grid_cell_payload_rejects_unknown_fields():
    from repro.vgang.grid import GridCell
    with pytest.raises(TypeError):
        GridCell(seed=0, n_cores=4, dist="mixed", util=0.8, n_sets=1,
                 columns=("rtgang", "ffd"),
                 sim_check=0, gamma=0.5, cycles=20.0, bogus=1)
