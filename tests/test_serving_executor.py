"""Serving engine + gang executor integration tests."""
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced(get_config("qwen2-7b"))
    mesh = make_local_mesh(1, 1)
    api = build_model(cfg, ParallelConfig(param_dtype="float32",
                                          compute_dtype="float32",
                                          q_block=8, kv_block=8), mesh)
    params = api.init(jax.random.key(0))
    return cfg, api, params


def test_engine_matches_stepwise_greedy(tiny_lm):
    """Engine generation == naive greedy rollout via repeated prefill."""
    cfg, api, params = tiny_lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, size=(12,)).astype(np.int32)
    n_new = 5

    engine = ServingEngine(api, params, max_batch=2, max_seq=64)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    engine.run_until_done([req], max_steps=50)
    assert req.done and len(req.out) == n_new

    # oracle: repeated full prefill argmax
    toks = list(prompt)
    oracle = []
    for _ in range(n_new):
        logits, _ = jax.jit(api.prefill_fn)(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        oracle.append(nxt)
        toks.append(nxt)
    assert req.out == oracle, (req.out, oracle)


def test_engine_concurrent_slots(tiny_lm):
    cfg, api, params = tiny_lm
    rng = np.random.default_rng(2)
    engine = ServingEngine(api, params, max_batch=2, max_seq=64)
    reqs = [Request(rid=i, prompt=rng.integers(
        1, cfg.vocab_size, size=(8,)).astype(np.int32), max_new=4)
        for i in range(4)]
    engine.run_until_done(reqs, max_steps=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)


def test_executor_one_gang_at_a_time():
    """Two RT jobs at different priorities never hold lanes concurrently."""
    ex = GangExecutor(n_lanes=4, regulation_interval_s=0.01)
    overlap = []

    running = set()

    def mk_fn(name, dur):
        def fn(lane, idx):
            running.add(name)
            if len({n for n in running}) > 1:
                overlap.append(tuple(running))
            time.sleep(dur)
            running.discard(name)
        return fn

    ex.submit_rt(RTJob("hi", mk_fn("hi", 0.002), lanes=(0, 1), prio=9,
                       period_s=0.02, n_jobs=20))
    ex.submit_rt(RTJob("lo", mk_fn("lo", 0.004), lanes=(2, 3), prio=1,
                       period_s=0.03, n_jobs=15))
    stats = ex.run(1.2)
    # the gang-isolation barrier drains other gangs' in-flight quanta before
    # a new gang's quantum starts, so no cross-gang overlap is observable
    assert len(overlap) == 0, overlap
    assert len(stats["response_times"]["hi"]) >= 10
    assert ex.sched.check_invariant()


def test_executor_throttles_best_effort():
    """BE quanta admitted only within the running gang's byte budget."""
    def busy(lane, idx):
        time.sleep(0.004)

    def be_quantum(lane):
        time.sleep(0.0005)

    results = {}
    for budget in (0.0, 1e9):
        ex = GangExecutor(n_lanes=2, regulation_interval_s=0.01)
        ex.submit_rt(RTJob("rt", busy, lanes=(0,), prio=5, period_s=0.005,
                           budget_bytes=budget, n_jobs=100))
        ex.submit_be(BEJob("be", be_quantum, lanes=(1,),
                           bytes_per_quantum=1000.0))
        stats = ex.run(0.8)
        results[budget] = stats["be_quanta"]["be"]
    assert results[0.0] < results[1e9] * 0.2, results


def test_executor_records_stragglers():
    slow = {"n": 0}

    def fn(lane, idx):
        slow["n"] += 1
        time.sleep(0.05 if slow["n"] == 10 else 0.001)

    ex = GangExecutor(n_lanes=1, straggler_factor=5.0)
    ex.submit_rt(RTJob("j", fn, lanes=(0,), prio=5, period_s=0.005,
                       n_jobs=20))
    ex.run(0.6)
    assert any(s[0] == "j" for s in ex.stragglers)
