"""The simulator must reproduce the paper's §III-E illustrative example and
the Fig.2/Fig.3 scheduling behaviors exactly."""
import pytest

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference


def taskset():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2,
                mem_budget=1e9)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1,
                mem_budget=1e9)
    return t1, t2


def run(enabled, interference=None, be=()):
    t1, t2 = taskset()
    sim = Simulator(4, [t1, t2], be_tasks=list(be),
                    interference=interference or (lambda v, a: 1.0),
                    rt_gang_enabled=enabled, dt=0.05)
    return sim.run(10.0)


def test_cosched_no_interference_fig4a():
    r = run(False, be=[BETask("tau3", cores=(0, 1, 2, 3))])
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(4.0)]
    assert r.slack_time == pytest.approx(28.0)


def test_rtgang_fig4b():
    r = run(True, be=[BETask("tau3", cores=(0, 1, 2, 3))])
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(6.0)]   # blocked 0..2
    assert r.slack_time == pytest.approx(28.0)


def test_cosched_with_interference_fig4c():
    intf = matrix_interference({("tau1", "tau2"): 10.0})
    r = run(False, interference=intf, be=[BETask("tau3", cores=(0, 1, 2, 3))])
    assert r.response_times["tau1"] == [pytest.approx(5.6, abs=1e-6)]
    assert r.response_times["tau2"] == [pytest.approx(4.0)]
    assert r.slack_time == pytest.approx(20.8)


def test_rtgang_immune_to_interference():
    """Paper: 'regardless of task and hardware characteristics, real-time
    tasks' execution times would remain the same'."""
    intf = matrix_interference({("tau1", "tau2"): 10.0,
                                ("tau2", "tau1"): 100.0})
    r = run(True, interference=intf)
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(6.0)]


def test_fig2_single_thread_idles_all_other_cores():
    """Fig.2: when single-threaded t3 (highest prio) runs, every other core
    must be idle even though t1/t2 threads are ready."""
    t1 = RTTask("t1", wcet=4, period=100, cores=(0, 1, 2, 3), prio=1)
    t2 = RTTask("t2", wcet=2, period=100, cores=(0, 1, 2), prio=2,
                release_offset=1.0)
    t3 = RTTask("t3", wcet=1, period=100, cores=(2,), prio=3,
                release_offset=2.0)
    sim = Simulator(4, [t1, t2, t3], dt=0.05)
    r = sim.run(20.0)
    r.trace.finish_view()
    # while t3 runs (2..3), no other RT task may run on any core
    for seg in r.trace.segments:
        if seg.label in ("t1", "t2"):
            assert not (seg.t0 < 3.0 - 1e-9 and seg.t1 > 2.0 + 1e-9), \
                f"{seg.label} overlaps t3 on core {seg.core}: " \
                f"[{seg.t0},{seg.t1}]"
    assert r.response_times["t3"] == [pytest.approx(1.0)]


def test_fig3_virtual_gang_blocks_then_preempted():
    """Fig.3: virtual gang tg = {t1,t2,t3} at one prio. (a) lower-prio t4
    waits for tg's last thread; (b) higher-prio t4 preempts tg."""
    def vgang():
        return [RTTask("g1", wcet=3, period=100, cores=(0,), prio=5),
                RTTask("g2", wcet=2, period=100, cores=(1,), prio=5),
                RTTask("g3", wcet=1, period=100, cores=(2, 3), prio=5)]

    # (a) t4 lower prio: starts only after the longest member (3ms) finishes
    t4 = RTTask("t4", wcet=1, period=100, cores=(1,), prio=4,
                release_offset=1.0)
    sim = Simulator(4, vgang() + [t4], dt=0.05)
    r = sim.run(20.0)
    assert r.response_times["t4"] == [pytest.approx(3.0)]  # 1.0 -> 4.0

    # (b) t4 higher prio: preempts all members immediately
    t4h = RTTask("t4", wcet=1, period=100, cores=(1,), prio=9,
                 release_offset=1.0)
    sim = Simulator(4, vgang() + [t4h], dt=0.05)
    r = sim.run(20.0)
    assert r.response_times["t4"] == [pytest.approx(1.0)]
    # g1 was preempted for 1ms -> finishes at 3+1 = 4
    assert r.response_times["g1"] == [pytest.approx(4.0)]


def test_throttling_bounds_be_progress():
    """BE memory task runs only within the gang's budget per interval."""
    t1 = RTTask("rt", wcet=5, period=10, cores=(0, 1), prio=5,
                mem_budget=0.2)                     # 0.2 units per 1ms window
    bem = BETask("be_mem", cores=(2, 3), mem_rate=1.0)  # wants 1 unit/ms
    sim = Simulator(4, [t1], be_tasks=[bem], dt=0.05,
                    throttle_mode="reactive")
    r = sim.run(10.0)
    # while the gang runs (0..5ms), be_mem gets ~0.2ms of each 1ms window
    # per core; off-gang windows are unthrottled.
    assert r.throttle_events > 0
    assert r.be_progress["be_mem"] < 2 * 5 * 0.35 + 2 * 5 * 1.0 + 1.0


def test_wcrt_over_many_periods_deterministic():
    t1, t2 = taskset()
    sim = Simulator(4, [t1, t2], rt_gang_enabled=True, dt=0.05)
    r = sim.run(100.0)
    assert len(r.response_times["tau1"]) == 10
    assert max(r.response_times["tau1"]) == pytest.approx(2.0)
    assert max(r.response_times["tau2"]) == pytest.approx(6.0)
    assert r.deadline_misses["tau1"] == 0 and r.deadline_misses["tau2"] == 0
