"""Strict partitioning (vgang/formation.strict_partition + the
partition-local RTA) and the PolicyFamily registry (vgang/family.py):
single-partition collapse to core/rta.py bit-for-bit, batched==scalar
verdicts, placement-aware pair_factor, event-engine soundness of the
``part`` column, and byte-identity of the six legacy grid columns
against the pre-refactor fixture."""
import dataclasses
import json
import os
import random

import pytest

from repro.core import rta as core_rta
from repro.core.gang import RTTask
from repro.core.memmodel import distance_interference
from repro.core.rta import gang_wcet
from repro.vgang.family import (BASELINE_COLUMN, FAMILIES, PART_COLUMN,
                                RECLAIM_COLUMN, RTG_COLUMN, PolicyFamily,
                                family_names, get_family, grid_columns,
                                register_family)
from repro.vgang.formation import (intensity_interference, pair_factor,
                                   strict_partition)
from repro.vgang.grid import GridCell, _grid_cell, random_vgang_taskset
from repro.vgang.rta import (accepts_partitioned,
                             batched_accepts_partitioned,
                             schedulable_partitions)
from repro.vgang.sched import StrictPartitionPolicy

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "grid_prerefactor_fixture.json")


def _random_case(seed, n_cores=4, n_tasks=5, util=1.0, dist="mixed"):
    rng = random.Random(seed)
    tasks = random_vgang_taskset(rng, n_cores, n_tasks, util, dist)
    return tasks, intensity_interference(tasks, 0.5)


# ---------------------------------------------------------------------
# strict_partition formation invariants
# ---------------------------------------------------------------------

def test_partitioning_is_disjoint_consecutive_and_complete():
    for seed in range(8):
        tasks, intf = _random_case(seed, n_cores=8, n_tasks=7, util=1.4)
        pg = strict_partition(tasks, 8, intf)
        names = [g.name for g in pg.gangs]
        assert sorted(names) == sorted(t.name for t in tasks)
        cursor = 0
        for p in pg.partitions:
            assert p.cores == tuple(range(cursor, cursor + p.size))
            cursor += p.size
            # every gang fits its partition
            assert all(g.n_threads <= p.size for g in p.gangs)
        assert cursor <= 8
        # global RM priorities: distinct, shorter period -> higher prio
        prios = {g.name: g.prio for g in pg.gangs}
        assert len(set(prios.values())) == len(prios)
        by_rm = sorted(pg.gangs, key=lambda g: (g.period, g.name))
        assert [g.prio for g in by_rm] == sorted(
            (g.prio for g in pg.gangs), reverse=True)


def test_strict_partition_rejects_too_wide_gang():
    t = RTTask("wide", wcet=1.0, period=10.0, cores=tuple(range(8)),
               prio=1)
    with pytest.raises(ValueError, match="wider"):
        strict_partition([t], 4)


# ---------------------------------------------------------------------
# partition RTA: single-partition collapse + batched == scalar
# ---------------------------------------------------------------------

def test_single_partition_rta_equals_core_rta_bit_for_bit():
    """A machine-wide first gang forces every later gang into the same
    partition; with no co-running partition the inflation factor is
    exactly 1.0 and the partition RTA must reproduce core/rta.py
    bit-for-bit (C * 1.0 == C in IEEE floats)."""
    for seed in range(6):
        rng = random.Random(1000 + seed)
        tasks = random_vgang_taskset(rng, 4, 5, 1.0, "mixed")
        # widen the first gang to the full machine -> one partition
        tasks[0] = dataclasses.replace(tasks[0], cores=tuple(range(4)))
        intf = intensity_interference(tasks, 0.5)
        pg = strict_partition(tasks, 4, intf)
        assert len(pg.partitions) == 1
        res = schedulable_partitions(pg, intf, blocking=0.5)
        eq = [RTTask(name=g.name, wcet=gang_wcet(g), period=g.period,
                     cores=(0,), prio=g.prio) for g in pg.gangs]
        ref = core_rta.schedulable(eq, blocking=0.5)
        assert set(res) == set(ref)
        for n, v in ref.items():
            assert res[n]["ok"] == v["ok"]
            assert res[n]["wcrt"] == v["wcrt"]       # bitwise, no tol
            assert res[n]["partition"] == "P0"


def test_batched_partitioned_matches_scalar_over_many_tasksets():
    """~300 random tasksets: the shard-batched partition verdict equals
    the scalar loop exactly."""
    pgs, intfs = [], []
    for seed in range(300):
        n_cores = (4, 8)[seed % 2]
        dist = ("light", "mixed", "heavy")[seed % 3]
        util = 0.5 + (seed % 7) * 0.25
        tasks, intf = _random_case(seed, n_cores=n_cores, util=util,
                                   dist=dist)
        pgs.append(strict_partition(tasks, n_cores, intf))
        intfs.append(intf)
    scalar = [accepts_partitioned(pg, i) for pg, i in zip(pgs, intfs)]
    batched = batched_accepts_partitioned(pgs, intfs)
    assert batched == scalar
    assert 0 < sum(scalar) < len(scalar)    # both verdicts exercised


# ---------------------------------------------------------------------
# placement-aware interference pricing
# ---------------------------------------------------------------------

def _near_far(victim, aggressor, dist):
    return 3.0 if dist <= 1 else 1.5


def test_pair_factor_location_free_is_plain_call():
    tasks, intf = _random_case(0)
    a, b = tasks[0].name, tasks[1].name
    assert pair_factor(intf, a, b) == intf(a, b)
    # placements are ignored for a location-free model
    assert pair_factor(intf, a, b, (0,), (3,)) == intf(a, b)


def test_pair_factor_distance_aware_prices_worst_core_pair():
    intf = distance_interference(_near_far)
    # adjacent blocks share a border pair at distance 1 -> 3.0
    assert pair_factor(intf, "a", "b", (0, 1), (2, 3)) == 3.0
    # separated blocks only see distant pairs -> 1.5
    assert pair_factor(intf, "a", "b", (0,), (3,)) == 1.5
    with pytest.raises(ValueError, match="placements"):
        pair_factor(intf, "a", "b")


def test_partition_rta_prices_distance_aware_cross_partition():
    """Two single-gang partitions: the inflated WCET uses the worst
    core-pair factor between the two blocks."""
    t1 = RTTask("a", wcet=2.0, period=10.0, cores=(0, 1), prio=2)
    t2 = RTTask("b", wcet=2.0, period=10.0, cores=(0, 1), prio=1)
    pg = strict_partition([t1, t2], 4)
    assert [p.cores for p in pg.partitions] == [(0, 1), (2, 3)]
    intf = distance_interference(_near_far)
    res = schedulable_partitions(pg, intf)
    # blocks (0,1) vs (2,3) touch at distance 1 -> factor 3.0
    assert res["a"]["wcrt"] == pytest.approx(6.0)
    assert res["b"]["wcrt"] == pytest.approx(6.0)


def test_strict_partition_policy_rejects_distance_aware_model():
    tasks, _ = _random_case(0)
    pg = strict_partition(tasks, 4)
    with pytest.raises(ValueError, match="distance-aware"):
        StrictPartitionPolicy(pg, distance_interference(_near_far))
    with pytest.raises(TypeError, match="valid options"):
        StrictPartitionPolicy(pg, reclam=True)


# ---------------------------------------------------------------------
# event-engine soundness of the part column
# ---------------------------------------------------------------------

def test_part_rta_accept_implies_simulated_missfree():
    """RTA-accepted partitionings must simulate miss-free on the exact
    event engine (the soundness direction the grid cross-checks)."""
    fam = get_family(PART_COLUMN)
    accepted = 0
    for seed in range(12):
        n_cores = (4, 8)[seed % 2]
        tasks, intf = _random_case(seed, n_cores=n_cores,
                                   util=0.8 + 0.1 * (seed % 4))
        pg = fam.assign(fam.form(tasks, n_cores, intf))
        if not fam.verdict(pg, intf):
            continue
        accepted += 1
        policy = fam.make_policy(pg, n_cores, intf)
        horizon = 20.0 * max(t.period for t in tasks)
        r = policy.simulate(horizon, rta_bounds=policy.member_bounds(),
                            trace=False)
        assert sum(r.deadline_misses.values()) == 0, seed
        # measured response never exceeds the analytic bound
        assert all(m["negative"] == 0 for m in r.rta_margins.values())
    assert accepted >= 3


# ---------------------------------------------------------------------
# PolicyFamily registry
# ---------------------------------------------------------------------

def test_registry_has_all_builtin_columns():
    assert set(family_names()) >= {BASELINE_COLUMN, "ffd", "bestfit",
                                   "intfaware", RTG_COLUMN,
                                   RECLAIM_COLUMN, PART_COLUMN}
    # the rtgT columns share the intfaware formation object key
    assert get_family(RTG_COLUMN).form_key == "intfaware"
    assert get_family(RECLAIM_COLUMN).form_key == "intfaware"
    assert get_family(PART_COLUMN).kind == "partition"
    assert get_family(PART_COLUMN).utilization is None


def test_unknown_family_raises_with_known_names():
    with pytest.raises(ValueError, match="unknown policy family"):
        get_family("nope")
    with pytest.raises(ValueError, match="rtgang"):
        get_family("nope")


def test_duplicate_registration_rejected():
    fam = FAMILIES[BASELINE_COLUMN]
    with pytest.raises(ValueError, match="already registered"):
        register_family(fam)


def test_grid_columns_canonical_order():
    cols = grid_columns(("intfaware", "ffd", PART_COLUMN, RTG_COLUMN))
    assert cols == (BASELINE_COLUMN, "intfaware", "ffd", RTG_COLUMN,
                    PART_COLUMN)
    # the baseline is not duplicated when requested explicitly
    assert grid_columns((BASELINE_COLUMN, "ffd")) == (BASELINE_COLUMN,
                                                      "ffd")
    with pytest.raises(ValueError, match="unknown policy family"):
        grid_columns(("ffd", "bogus"))


def test_family_scalar_and_batched_verdicts_agree():
    """Every registered family's batched verdict equals its scalar one
    over a shared pool of random tasksets."""
    cases = [_random_case(s, util=0.7 + 0.2 * (s % 4)) for s in range(8)]
    for name in family_names():
        fam = get_family(name)
        formed = [fam.assign(fam.form(t, 4, i)) for t, i in cases]
        intfs = [i for _, i in cases]
        scalar = [bool(fam.verdict(v, i)) for v, i in zip(formed, intfs)]
        batched = [bool(b) for b in
                   fam.batched_verdict(formed, intfs, wcet_cache={})]
        assert batched == scalar, name


# ---------------------------------------------------------------------
# refactor bit-identity: the six legacy grid columns
# ---------------------------------------------------------------------

def test_legacy_grid_columns_byte_identical_to_prerefactor_fixture():
    """The registry refactor must not perturb the six pre-existing grid
    columns: re-running the captured cells reproduces the fixture (rng
    draw order, formation, verdicts, sim counters) byte for byte."""
    columns = grid_columns(("ffd", "bestfit", "intfaware", RTG_COLUMN,
                            RECLAIM_COLUMN))
    rows = []
    for util in (0.8, 1.1, 1.6):
        cell = GridCell(seed=0, n_cores=4, dist="mixed", util=util,
                        n_sets=10, columns=columns, sim_check=1,
                        gamma=0.5, cycles=20.0)
        row = _grid_cell(cell)
        row.pop("wall_s"), row.pop("wall_rta_s")
        rows.append(row)
    got = json.dumps(rows, indent=1, sort_keys=True)
    with open(FIXTURE) as f:
        assert got == f.read()
