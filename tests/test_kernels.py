"""Per-kernel allclose vs pure-jnp oracles, sweeping shapes/dtypes
(interpret mode executes the kernel body on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import naive_attention
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_reference
from repro.kernels.moe_gmm.ops import grouped_matmul
from repro.kernels.moe_gmm.ref import gmm_reference

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,dtype", [
    (2, 256, 4, 2, 64, True, 0, jnp.float32),
    (1, 128, 8, 1, 32, True, 0, jnp.float32),
    (2, 256, 4, 4, 64, True, 64, jnp.float32),
    (1, 256, 2, 2, 128, False, 0, jnp.float32),
    (1, 128, 4, 2, 64, True, 0, jnp.bfloat16),
])
def test_flash_attention(B, S, Hq, Hkv, D, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 3, 16, 32, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 32, 1, 8, 8, 8),
    (1, 64, 4, 16, 16, 64),   # single chunk
])
def test_ssd_scan(B, S, H, P, N, chunk):
    xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=(H,))) - 0.1, jnp.float32)
    y, h = ssd_scan(xh, dt, Bm, Cm, A, chunk=chunk, interpret=True)
    x2 = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dt2 = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    Bm2 = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cm2 = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    A2 = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1)
    yr, hr = ssd_reference(x2, dt2, Bm2, Cm2, A2)
    yr = yr.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    hr = hr.reshape(B, H, P, N)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-3)


@pytest.mark.parametrize("B,S,C,chunk,strong_decay", [
    (2, 64, 16, 16, False),
    (1, 128, 32, 64, False),
    (3, 32, 8, 32, True),
    (1, 256, 16, 128, True),   # strong decay: matrix trick would overflow
])
def test_rglru_scan(B, S, C, chunk, strong_decay):
    scale = 8.0 if strong_decay else 2.0
    log_a = jnp.asarray(-np.abs(RNG.normal(size=(B, S, C))) * scale,
                        jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, C)), jnp.float32)
    y = rglru_scan(log_a, b, chunk=chunk, interpret=True)
    yr = rglru_reference(log_a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


@pytest.mark.parametrize("E,C,D,F,dtype", [
    (4, 32, 16, 24, jnp.float32),
    (2, 64, 32, 32, jnp.float32),
    (3, 16, 8, 8, jnp.bfloat16),
])
def test_moe_gmm(E, C, D, F, dtype):
    x = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, D, F)), dtype)
    counts = jnp.asarray(RNG.integers(0, C + 1, size=(E,)), jnp.int32)
    out = grouped_matmul(x, w, counts, block_c=16, block_f=8, block_d=8,
                         interpret=True)
    ref = gmm_reference(x, w, counts)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_matches_model_layer_path():
    """The Pallas kernel and the model's XLA flash path agree."""
    from repro.models.layers import flash_attention_jnp
    B, S, Hq, Hkv, D = 1, 128, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    G = Hq // Hkv
    b = flash_attention_jnp(q, jnp.repeat(k, G, 2), jnp.repeat(v, G, 2),
                            causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
