"""Fault injection + overrun enforcement (core/faults.py, DESIGN.md §11).

Covers the robustness tentpole end to end:

* seeded fault plans resolve identically across engines and runs;
* containment: with enforcement on, non-faulty gangs' deadline misses
  equal the fault-free baseline (abort/demote), and a hung thread is
  bounded by the watchdog instead of wedging the machine forever;
* engine parity: quantum and event engines agree on misses and on
  every fault/enforcement counter under the same plan;
* property-style invariants over seeded plans: the regulator never
  exceeds its per-window limit, the gang lock is never leaked by an
  aborted gang, and every non-faulty gang's observed response stays
  under the enforcement-aware RTA bound;
* executor: a wall-clock watchdog aborts a hung member instead of
  deadlocking the gang barrier;
* declaration validation and grid-cell hardening.
"""
import time

import pytest

from repro.core.faults import (BeOverrun, Enforcement, FaultPlan,
                               HungThread, LostWakeup, WcetOverrun)
from repro.core.gang import BETask, RTTask, validate_declared
from repro.core.sim import Simulator
from repro.vgang.formation import VirtualGang, singleton_vgangs
from repro.vgang.grid import GridCell, _dispatch, _skipped_row
from repro.vgang.rta import schedulable_vgangs_enforced
from repro.vgang.sched import VirtualGangPolicy

HORIZON = 200.0
DT = 0.05


def taskset():
    """Three gangs on 4 cores, ~60% utilization, distinct criticality.
    tau2 is the designated misbehaver in most scenarios; tau3 spans all
    cores so any leaked lock or unbounded overrun shows up in its
    misses immediately."""
    return [
        RTTask("tau1", wcet=2.0, period=10.0, cores=(0, 1), prio=5,
               mem_budget=100.0, criticality=2),
        RTTask("tau2", wcet=3.0, period=15.0, cores=(2, 3), prio=4,
               mem_budget=100.0, criticality=1),
        RTTask("tau3", wcet=4.0, period=20.0, cores=(0, 1, 2, 3), prio=3,
               mem_budget=100.0, criticality=0),
    ]


def run(dt, fault_plan=None, enforcement=None, tasks=None, be=(),
        horizon=HORIZON, **kw):
    sim = Simulator(4, tasks if tasks is not None else taskset(),
                    be_tasks=be, dt=dt, fault_plan=fault_plan,
                    enforcement=enforcement, **kw)
    return sim, sim.run(horizon)


OVERRUN = FaultPlan(faults=(WcetOverrun("tau2", factor=4.0),))
NONFAULTY = ("tau1", "tau3")


# ---------------------------------------------------------------------
# plan / declaration validation
# ---------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(faults=("not a fault",))
    with pytest.raises(ValueError):
        FaultPlan(faults=(WcetOverrun("t", factor=0.0),))
    with pytest.raises(ValueError):
        FaultPlan(faults=(WcetOverrun("t", prob=1.5),))
    with pytest.raises(ValueError):
        FaultPlan(faults=(HungThread("t", job=-1),))
    with pytest.raises(ValueError):
        FaultPlan(faults=(BeOverrun("b", factor=-2.0),))


def test_enforcement_validation():
    with pytest.raises(ValueError):
        Enforcement(action="panic")
    with pytest.raises(ValueError):
        Enforcement(factor=0.0)
    with pytest.raises(ValueError):
        Enforcement(watchdog_factor=0.0)
    Enforcement(action="degrade", factor=1.5, watchdog_factor=3.0)


def test_task_parameter_validation():
    with pytest.raises(ValueError):
        RTTask("bad", wcet=0.0, period=10.0, cores=(0,), prio=1)
    with pytest.raises(ValueError):
        RTTask("bad", wcet=1.0, period=0.0, cores=(0,), prio=1)
    with pytest.raises(ValueError):
        RTTask("bad", wcet=1.0, period=10.0, cores=(0,), prio=1,
               mem_intensity=1.5)
    with pytest.raises(ValueError):
        BETask("bad", cores=(0,), mem_rate=-1.0)
    # WCET > period is a *declaration* error, rejected only where
    # declarations must be trusted (RTA builds such tasks on purpose)
    fat = RTTask("fat", wcet=12.0, period=10.0, cores=(0,), prio=1)
    with pytest.raises(ValueError):
        validate_declared([fat])
    with pytest.raises(ValueError):
        Simulator(1, [fat], enforcement=Enforcement())
    Simulator(1, [fat])  # un-enforced simulation is allowed to model it


def test_simulator_parameter_validation():
    with pytest.raises(ValueError):
        Simulator(4, taskset(), regulation_interval=0.0)
    with pytest.raises(ValueError):
        Simulator(4, taskset(), dt=0.0)


def test_sibling_budget_exceeds_interval_rejected():
    # critical member declares a per-window tolerance far above what an
    # intensity-scale sibling can even generate in one window: the cap
    # could never trip, so build_simulator flags the declaration
    members = [
        RTTask("crit", wcet=5.0, period=20.0, cores=(0, 1), prio=2,
               mem_budget=50.0, mem_intensity=0.9),
        RTTask("sib", wcet=1.0, period=20.0, cores=(2,), prio=2,
               mem_intensity=0.5),
    ]
    pol = VirtualGangPolicy([VirtualGang("vg", members, prio=1)], 4,
                            auto_prio=False, rtg_throttle=True)
    with pytest.raises(ValueError):
        pol.build_simulator()


# ---------------------------------------------------------------------
# seeded plans are deterministic
# ---------------------------------------------------------------------

def test_seeded_plan_is_deterministic():
    mk = lambda: FaultPlan(
        faults=(WcetOverrun("tau2", factor=3.0, prob=0.5),), seed=7)
    a, b = mk(), mk()
    hits_a = [a.overrun_factor("tau2", i) for i in range(64)]
    hits_b = [b.overrun_factor("tau2", i) for i in range(64)]
    assert hits_a == hits_b
    n_hit = sum(1 for f in hits_a if f > 1.0)
    assert 0 < n_hit < 64          # prob=0.5 actually samples
    other = FaultPlan(
        faults=(WcetOverrun("tau2", factor=3.0, prob=0.5),), seed=8)
    assert hits_a != [other.overrun_factor("tau2", i) for i in range(64)]


# ---------------------------------------------------------------------
# containment
# ---------------------------------------------------------------------

@pytest.mark.parametrize("dt", [DT, None], ids=["quantum", "event"])
def test_abort_containment(dt):
    _, base = run(dt)
    _, loose = run(dt, fault_plan=OVERRUN)
    _, hard = run(dt, fault_plan=OVERRUN,
                  enforcement=Enforcement("abort", factor=1.2,
                                          watchdog_factor=2.0))
    # un-enforced, the 4x overrun starves tau3 outright (misses are
    # stamped at completion, so a job that never finishes shows up as a
    # lost completion, not a recorded miss)
    assert len(loose.response_times["tau3"]) < \
        len(base.response_times["tau3"])
    # enforced: every non-faulty gang sees exactly its fault-free
    # misses AND completes exactly its fault-free job count
    for n in NONFAULTY:
        assert hard.deadline_misses[n] == base.deadline_misses[n]
        assert len(hard.response_times[n]) == len(base.response_times[n])
    assert hard.faults["enforced"]["abort"] > 0
    assert hard.faults["lock_leaks"] == 0
    assert all(name == "tau2" for name, _, _ in
               hard.faults["aborted_jobs"])
    # every aborted job is charged as a miss on the misbehaver
    assert hard.deadline_misses["tau2"] >= len(hard.faults["aborted_jobs"])


@pytest.mark.parametrize("dt", [DT, None], ids=["quantum", "event"])
def test_demote_containment(dt):
    _, base = run(dt)
    _, res = run(dt, fault_plan=OVERRUN,
                 enforcement=Enforcement("demote", factor=1.2))
    for n in NONFAULTY:
        assert res.deadline_misses[n] == base.deadline_misses[n]
        assert len(res.response_times[n]) == len(base.response_times[n])
    assert res.faults["enforced"]["demote"] > 0
    assert res.faults["lock_leaks"] == 0


@pytest.mark.parametrize("dt", [DT, None], ids=["quantum", "event"])
def test_degrade_suspends_lower_criticality(dt):
    # one faulty job only, so the degraded interval ends and the
    # suspended gang gets restored for the rest of the horizon
    plan = FaultPlan(faults=(WcetOverrun("tau2", factor=4.0, jobs=(1,)),))
    _, res = run(dt, fault_plan=plan,
                 enforcement=Enforcement("degrade", factor=1.2,
                                         watchdog_factor=2.0))
    assert res.faults["enforced"]["degrade"] > 0
    assert res.faults["lock_leaks"] == 0
    # tau1 (higher criticality than the overrunner) is never suspended
    assert res.deadline_misses["tau1"] == 0
    # tau3 (lower criticality) is suspended but restored afterwards:
    # it still completes jobs over the horizon
    assert len(res.response_times["tau3"]) > 0


@pytest.mark.parametrize("dt", [DT, None], ids=["quantum", "event"])
def test_hung_thread_bounded_by_watchdog(dt):
    plan = FaultPlan(faults=(HungThread("tau2", job=1, thread=0),))
    _, loose = run(dt, fault_plan=plan)
    # enforcement with a huge work budget: only the wall-clock watchdog
    # can catch the hang
    _, hard = run(dt, fault_plan=plan,
                  enforcement=Enforcement("abort", factor=100.0,
                                          watchdog_factor=2.0))
    assert hard.faults["watchdog_fires"] >= 1
    assert ("tau2", 1) in {(n, i) for n, i, _ in
                           hard.faults["aborted_jobs"]}
    assert hard.faults["lock_leaks"] == 0
    # un-enforced, the hung gang wedges the lock forever: every lower-
    # priority job from the hang onwards never completes (and a job
    # that never finishes records no miss — it vanishes). The watchdog
    # bounds the outage to 2 periods, after which tau3 resumes.
    assert len(hard.response_times["tau3"]) > \
        len(loose.response_times["tau3"])


@pytest.mark.parametrize("dt", [DT, None], ids=["quantum", "event"])
def test_lost_wakeup_extends_stall(dt):
    tasks = [RTTask("rt", wcet=6.0, period=10.0, cores=(0, 1), prio=2,
                    mem_budget=0.3)]
    be = [BETask("be", cores=(2, 3), mem_rate=1.0)]
    _, base = run(dt, tasks=tasks, be=be)
    plan = FaultPlan(faults=(LostWakeup(core=2, nth=1, extra=25.0),))
    _, res = run(dt, tasks=tasks, be=be, fault_plan=plan)
    assert res.faults["injected_lost_wakeups"] == 1
    # the lost wakeup keeps core 2 stalled past its window end until
    # the gang's budget lift: strictly less best-effort progress
    assert res.be_progress["be"] < base.be_progress["be"] - 1.0
    # RT side is unaffected — the stall is on a best-effort core
    assert res.deadline_misses["rt"] == base.deadline_misses["rt"]


# ---------------------------------------------------------------------
# engine parity under fault plans
# ---------------------------------------------------------------------

SCENARIOS = {
    "overrun-loose": (OVERRUN, None),
    "overrun-abort": (OVERRUN, Enforcement("abort", factor=1.2,
                                           watchdog_factor=2.0)),
    "overrun-demote": (OVERRUN, Enforcement("demote", factor=1.2)),
    "overrun-degrade": (OVERRUN, Enforcement("degrade", factor=1.2,
                                             watchdog_factor=2.0)),
    "hung-watchdog": (FaultPlan(faults=(HungThread("tau2", job=1),)),
                      Enforcement("abort", factor=100.0,
                                  watchdog_factor=2.0)),
    "seeded-prob": (FaultPlan(
        faults=(WcetOverrun("tau2", factor=3.0, prob=0.5),), seed=3),
        Enforcement("abort", factor=1.2, watchdog_factor=2.0)),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=str)
def test_engine_parity_under_faults(scenario):
    plan, enf = SCENARIOS[scenario]
    _, q = run(DT, fault_plan=plan, enforcement=enf)
    _, e = run(None, fault_plan=plan, enforcement=enf)
    assert q.deadline_misses == e.deadline_misses
    for name in q.miss_times:
        assert len(q.miss_times[name]) == len(e.miss_times[name])
        for tq, te in zip(q.miss_times[name], e.miss_times[name]):
            assert abs(tq - te) <= DT + 1e-9
    for key in ("injected_overruns", "injected_hangs", "enforced",
                "watchdog_fires", "lock_leaks"):
        assert q.faults[key] == e.faults[key], key
    # aborts land at the same (task, job), within one quantum in time
    aq = sorted((n, i) for n, i, _ in q.faults["aborted_jobs"])
    ae = sorted((n, i) for n, i, _ in e.faults["aborted_jobs"])
    assert aq == ae


# ---------------------------------------------------------------------
# property-style invariants over seeded plans
# ---------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_seeded_invariants(seed):
    enf = Enforcement("abort", factor=1.2, watchdog_factor=2.0)
    plan = FaultPlan(
        faults=(WcetOverrun("tau2", factor=3.0, prob=0.6),), seed=seed)
    bound = schedulable_vgangs_enforced(
        singleton_vgangs(taskset()), enforcement=enf)
    for dt in (DT, None):
        sim, res = run(dt, fault_plan=plan, enforcement=enf)
        # regulator never spends more than its per-window limit (the
        # quantum engine can overshoot by at most one quantum of traffic)
        slack = 1e-9 if dt is None else 2.0 * dt
        assert sim.reg.max_overrun() <= slack
        # the gang lock is never left held by an aborted gang
        assert res.faults["lock_leaks"] == 0
        # every non-faulty gang's observed response respects the
        # enforcement-aware RTA bound — no matter what tau2 did
        for name in NONFAULTY:
            assert bound[name]["ok"]
            assert res.wcrt(name) <= bound[name]["wcrt"] + 1e-6


def test_result_has_no_fault_summary_when_unarmed():
    _, res = run(None)
    assert res.faults is None


# ---------------------------------------------------------------------
# executor watchdog
# ---------------------------------------------------------------------

def test_executor_watchdog_aborts_hung_member():
    from repro.core.executor import GangExecutor, RTJob

    def hang(lane, idx):
        if idx == 1 and lane == 0:
            time.sleep(1.2)          # runaway member

    def quick(lane, idx):
        time.sleep(0.002)

    ex = GangExecutor(2, watchdog_factor=2.0)
    ex.submit_rt(RTJob("hog", hang, lanes=(0, 1), prio=2, period_s=0.06,
                       wcet_s=0.01, n_jobs=3))
    ex.submit_rt(RTJob("ok", quick, lanes=(0, 1), prio=1, period_s=0.1,
                       wcet_s=0.01))
    t0 = time.monotonic()
    res = ex.run(0.5)
    # the hung member was aborted by the lane watchdog: the barrier did
    # not deadlock and the run returned without waiting out the sleep
    assert res["aborted"].get("hog", 0) >= 1
    assert any(name == "hog" and idx == 1
               for name, _lane, idx, _t in res["watchdog_aborts"])
    # the lower-priority gang still ran after the abort
    assert len(res["response_times"].get("ok", [])) >= 1
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------
# grid hardening
# ---------------------------------------------------------------------

_CELL = GridCell(seed=0, n_cores=4, dist="uniform", util=0.5, n_sets=1,
                 columns=("rtgang", "intfaware"),
                 sim_check=0, gamma=2.0, cycles=20.0)


def _ok_worker(cell):
    return {"n_cores": cell.n_cores, "dist": cell.dist, "util": cell.util,
            "n": 1, "accept": {}, "sim_accept": {}, "sim_n": 0,
            "soundness_violations": 0, "mean_util_gain": 0.0,
            "wall_s": 0.0}


def _boom_worker(cell):
    raise RuntimeError("boom")


def _slow_worker(cell):
    time.sleep(30.0)
    return _ok_worker(cell)


def test_grid_dispatch_ok():
    rows, skipped = _dispatch([_CELL, _CELL], procs=2, cell_timeout=60.0,
                              worker=_ok_worker)
    assert skipped == []
    assert all(not r.get("skipped") for r in rows)


def test_grid_dispatch_skips_failing_cell():
    rows, skipped = _dispatch([_CELL, _CELL], procs=2, cell_timeout=60.0,
                              worker=_boom_worker)
    assert len(skipped) == 2
    assert all(r["skipped"] for r in rows)
    assert rows[0] == _skipped_row(_CELL)


def test_grid_dispatch_times_out_slow_cell():
    t0 = time.monotonic()
    rows, skipped = _dispatch([_CELL], procs=2, cell_timeout=0.5,
                              worker=_slow_worker)
    assert len(skipped) == 1 and rows[0]["skipped"]
    assert time.monotonic() - t0 < 20.0
