"""Executor-side virtual gangs (DESIGN.md §2.4) and the budget
hand-off ordering fix: budgets are applied from the glock's gang-change
hook, never by a worker between pick and the gang-isolation barrier."""
import time

import pytest

from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.core.gang import RTTask
from repro.vgang.formation import VirtualGang, assign_priorities
from repro.vgang.sched import VirtualGangPolicy, remap_members


def _sleep_fn(dur):
    def fn(lane, idx):
        time.sleep(dur)
    return fn


# ---------------------------------------------------------------------
# the pre-barrier budget-clobber regression (ISSUE 4 satellite 1)
# ---------------------------------------------------------------------

def test_stale_lane_cannot_clobber_running_gang_budget():
    """Pins the old racy interleaving: lane 0 picks gang A (acquiring
    the glock) but is descheduled before it can touch the regulator;
    lane 1's higher-priority gang B preempts A and starts. The old code
    had lane 0 resume with ``reg.set_gang_budget(A.budget)`` — clobbering
    running gang B's best-effort budget fleet-wide. Fixed code applies
    budgets inside the gang-change hook under g.lock, so (a) B's budget
    is already enforced the instant B acquires, and (b) the stale lane-0
    worker has no budget write at all between pick and the barrier.

    On the old code the first assertion fails (the hook applied no
    budgets; lanes still carry the boot-time inf budget)."""
    ex = GangExecutor(n_lanes=3, regulation_interval_s=0.01)
    a = RTJob("A", _sleep_fn(0.001), lanes=(0,), prio=1,
              budget_bytes=100.0, n_jobs=1)
    b = RTJob("B", _sleep_fn(0.001), lanes=(1,), prio=9,
              budget_bytes=0.0, n_jobs=1)
    ex.submit_rt(a)
    ex.submit_rt(b)
    ex._release_jobs()

    th_a = ex._threads[(a.uid, 0)]
    th_b = ex._threads[(b.uid, 1)]

    picked_a = ex.sched.pick_next_task_rt(0, None, th_a)
    assert picked_a is th_a
    # A leads: its budget is enforced on the non-member lanes at the
    # acquire instant, from inside the glock — not later by the worker
    assert ex.reg.cores[2].budget == pytest.approx(100.0)
    assert ex.reg.cores[1].budget == pytest.approx(100.0)
    assert ex.reg.cores[0].budget == float("inf")   # gang lane exempt

    # lane 0 is now "descheduled between pick and barrier"; gang B
    # preempts A from lane 1
    picked_b = ex.sched.pick_next_task_rt(1, None, th_b)
    assert picked_b is th_b
    assert ex.sched.g.leader is ex._tasks[b.uid]
    assert ex.reg.cores[2].budget == pytest.approx(0.0)
    assert ex.reg.cores[0].budget == pytest.approx(0.0)

    # the stale lane-0 worker resumes: everything it still does before
    # the barrier (instance lookup) leaves the regulator untouched
    inst = ex._active_instance(a, 0)
    assert inst is not None
    assert ex.reg.cores[2].budget == pytest.approx(0.0), \
        "stale lane clobbered the running gang's budget"
    assert ex.sched.check_invariant()


def test_budget_persists_across_release_until_next_acquire():
    """Full release extends the departing gang's tightest budget to
    every lane — including its own former (exempt-while-occupied)
    lanes, so best-effort work there stays behind the last declared lid
    (paper §IV-F); the next gang's acquire overwrites it."""
    ex = GangExecutor(n_lanes=2, regulation_interval_s=0.01)
    a = RTJob("A", _sleep_fn(0.001), lanes=(0,), prio=5,
              budget_bytes=7.0, n_jobs=1)
    ex.submit_rt(a)
    ex._release_jobs()
    th_a = ex._threads[(a.uid, 0)]
    picked = ex.sched.pick_next_task_rt(0, None, th_a)
    assert ex.reg.cores[1].budget == pytest.approx(7.0)
    assert ex.reg.cores[0].budget == float("inf")   # occupied: exempt
    ex.sched.pick_next_task_rt(0, picked, None)     # full release
    assert not ex.sched.g.held_flag
    assert ex.reg.cores[1].budget == pytest.approx(7.0)
    assert ex.reg.cores[0].budget == pytest.approx(7.0)


# ---------------------------------------------------------------------
# drain-window budgets (ROADMAP item 1): element-wise min over the
# (outgoing, incoming) regimes until the outgoing gang's last in-flight
# quantum retires
# ---------------------------------------------------------------------

def test_drain_window_applies_min_over_outgoing_and_incoming():
    """Pins the budget-ordering trace across a preemption with a
    draining quantum: tight gang A (budget 5) has a quantum in flight on
    lane 0 when loose gang B (budget 1e9) preempts from lane 1. The old
    code applied B's budget fleet-wide at the acquire — best-effort
    work admitted at 1e9 bytes while A still executed pierced A's
    isolation. Fixed code enforces min(outgoing, incoming) = 5 on every
    lane until A's quantum retires, then re-derives B's pure regime."""
    ex = GangExecutor(n_lanes=3, regulation_interval_s=0.01)
    a = RTJob("A", _sleep_fn(0.001), lanes=(0,), prio=1,
              budget_bytes=5.0, n_jobs=1)
    b = RTJob("B", _sleep_fn(0.001), lanes=(1,), prio=9,
              budget_bytes=1e9, n_jobs=1)
    ex.submit_rt(a)
    ex.submit_rt(b)
    ex._release_jobs()
    picked_a = ex.sched.pick_next_task_rt(0, None, ex._threads[(a.uid, 0)])
    assert picked_a is not None
    with ex._lock:
        ex._inflight[0] = a.prio          # A's quantum starts draining
    assert ex.reg.cores[2].budget == pytest.approx(5.0)

    ex.sched.pick_next_task_rt(1, None, ex._threads[(b.uid, 1)])  # preempt
    assert ex.sched.g.leader is ex._tasks[b.uid]
    # drain active: the incoming regime is floored by the outgoing one
    # on the best-effort lane (lane 0 is still executing A's quantum;
    # its enforced value only matters once the drain ends)
    assert ex._draining == frozenset({a.prio})
    assert ex.reg.cores[2].budget == pytest.approx(5.0)

    # A's quantum retires -> drain completes -> B's regime applies alone
    assert ex._quantum_retired(0) is True
    ex._end_drain()
    assert ex._draining == frozenset()
    assert ex.reg.cores[2].budget == pytest.approx(1e9)
    assert ex.reg.cores[0].budget == pytest.approx(1e9)
    assert ex.reg.cores[1].budget == float("inf")   # B's own lane exempt


def test_drain_window_keeps_tighter_incoming_regime():
    """The min is element-wise: an incoming regime tighter than the
    outgoing one is enforced during the drain and stays afterwards."""
    ex = GangExecutor(n_lanes=3, regulation_interval_s=0.01)
    a = RTJob("A", _sleep_fn(0.001), lanes=(0,), prio=1,
              budget_bytes=100.0, n_jobs=1)
    b = RTJob("B", _sleep_fn(0.001), lanes=(1,), prio=9,
              budget_bytes=2.0, n_jobs=1)
    ex.submit_rt(a)
    ex.submit_rt(b)
    ex._release_jobs()
    ex.sched.pick_next_task_rt(0, None, ex._threads[(a.uid, 0)])
    with ex._lock:
        ex._inflight[0] = a.prio
    ex.sched.pick_next_task_rt(1, None, ex._threads[(b.uid, 1)])
    assert ex.reg.cores[2].budget == pytest.approx(2.0)
    assert ex._quantum_retired(0) is True
    ex._end_drain()
    assert ex.reg.cores[2].budget == pytest.approx(2.0)


# ---------------------------------------------------------------------
# submit_vgang / build_executor: lane remapping + live-member budgets
# ---------------------------------------------------------------------

def _two_member_vgang(b1=5.0, b2=2.0, w2=2):
    m1 = RTTask("m1", wcet=8.0, period=40.0, cores=(3,), prio=0,
                mem_budget=b1)
    m2 = RTTask("m2", wcet=6.0, period=40.0, cores=(5, 6)[:w2], prio=0,
                mem_budget=b2)
    return VirtualGang("m1+m2", members=[m1, m2], prio=4)


def test_submit_vgang_remaps_onto_disjoint_lane_blocks():
    vg = _two_member_vgang(w2=2)
    ex = GangExecutor(n_lanes=4)
    jobs = ex.submit_vgang(vg, {"m1": _sleep_fn(0), "m2": _sleep_fn(0)},
                           n_jobs=1)
    assert [j.lanes for j in jobs] == [(0,), (1, 2)]
    assert all(j.prio == 4 for j in jobs)
    assert all(j.period_s == pytest.approx(0.040) for j in jobs)
    # uids preserved from the member tasks (policy budget tables match)
    assert [j.uid for j in jobs] == [m.uid for m in vg.members]
    remapped = remap_members(vg)
    assert [m.cores for m in remapped] == [(0,), (1, 2)]
    assert [m.uid for m in remapped] == [m.uid for m in vg.members]


def test_vgang_live_member_budgets_through_executor_hook():
    """min-over-live-members on the free lanes, member lanes uncapped;
    a member leaving mid-gang raises the floor immediately (the glock's
    new join/leave events drive VirtualGangPolicy.apply)."""
    vg = _two_member_vgang(b1=5.0, b2=2.0, w2=1)
    policy = VirtualGangPolicy([vg], n_cores=4, auto_prio=False)
    ex = policy.build_executor({"m1": _sleep_fn(0), "m2": _sleep_fn(0)},
                               n_jobs=1)
    ex._release_jobs()
    m1, m2 = vg.members
    th1 = ex._threads[(m1.uid, 0)]
    th2 = ex._threads[(m2.uid, 1)]

    p1 = ex.sched.pick_next_task_rt(0, None, th1)    # m1 acquires
    assert ex.reg.cores[2].budget == pytest.approx(5.0)
    assert ex.reg.cores[0].budget == float("inf")

    p2 = ex.sched.pick_next_task_rt(1, None, th2)    # m2 joins
    assert p1 is th1 and p2 is th2
    assert ex.sched.check_invariant()
    assert ex.reg.cores[2].budget == pytest.approx(2.0)   # min over live
    assert ex.reg.cores[3].budget == pytest.approx(2.0)
    assert ex.reg.cores[0].budget == float("inf")
    assert ex.reg.cores[1].budget == float("inf")

    # the sensitive member m2 finishes -> "leave" -> floor rises to m1's
    ex.sched.pick_next_task_rt(1, p2, None)
    assert ex.sched.g.held_flag                       # m1 still holds
    assert ex.reg.cores[2].budget == pytest.approx(5.0)


def test_rtg_throttle_caps_sibling_lanes_not_critical():
    """RTG-throttle through the executor hook: the critical member's
    lanes stay uncapped; sibling lanes (and the best-effort fillers) are
    capped at the critical member's declared tolerable traffic."""
    # m1 has the larger WCET -> critical; cap = its mem_budget
    vg = _two_member_vgang(b1=3.0, b2=50.0, w2=1)
    policy = VirtualGangPolicy([vg], n_cores=4, auto_prio=False,
                               rtg_throttle=True)
    ex = policy.build_executor({"m1": _sleep_fn(0), "m2": _sleep_fn(0)},
                               n_jobs=1)
    ex._release_jobs()
    m1, m2 = vg.members
    ex.sched.pick_next_task_rt(0, None, ex._threads[(m1.uid, 0)])
    ex.sched.pick_next_task_rt(1, None, ex._threads[(m2.uid, 1)])
    assert ex.reg.cores[0].budget == float("inf")     # critical lane
    assert ex.reg.cores[1].budget == pytest.approx(3.0)   # sibling lane
    assert ex.reg.cores[2].budget == pytest.approx(3.0)   # BE filler
    assert ex.reg.cores[3].budget == pytest.approx(3.0)


def test_executor_vgang_end_to_end_sync_release():
    """Members of one virtual gang co-run (same prio passes the
    gang-isolation barrier together) and both record response times."""
    vg = _two_member_vgang(w2=1)
    policy = VirtualGangPolicy([vg], n_cores=2, auto_prio=False)
    seen = []
    ex = policy.build_executor(
        {"m1": lambda lane, idx: (seen.append(("m1", lane)),
                                  time.sleep(0.002)),
         "m2": lambda lane, idx: (seen.append(("m2", lane)),
                                  time.sleep(0.002))},
        n_jobs=5)
    stats = ex.run(0.5)
    assert len(stats["response_times"]["m1"]) == 5
    assert len(stats["response_times"]["m2"]) == 5
    assert {lane for name, lane in seen if name == "m1"} == {0}
    assert {lane for name, lane in seen if name == "m2"} == {1}
    assert ex.sched.check_invariant()


def test_rt_admission_stall_on_sibling_cap():
    """A sibling whose quanta exceed the per-window cap stalls to the
    next regulation window (executor analogue of the engines' RT-thread
    charging); the critical member is never gated."""
    m1 = RTTask("crit", wcet=8.0, period=10.0, cores=(0,), prio=0,
                mem_budget=4.0)
    m2 = RTTask("sib", wcet=1.0, period=10.0, cores=(1,), prio=0,
                mem_budget=100.0)
    vg = VirtualGang("crit+sib", members=[m1, m2], prio=3)
    policy = VirtualGangPolicy([vg], n_cores=2, auto_prio=False,
                               rtg_throttle=True)
    # period 10 ms * 1e-3 = 0.01 s; window = 0.05 s -> 5 sibling quanta
    # land per window, cap 4.0 admits only one 3.0-byte quantum
    ex = policy.build_executor(
        {"crit": _sleep_fn(0.001), "sib": _sleep_fn(0.001)},
        n_jobs=20, bytes_per_quantum={"sib": 3.0},
        regulation_interval_s=0.05)
    stats = ex.run(0.8)
    assert stats["rt_stalls"].get("sib", 0) > 0
    assert stats["rt_stalls"].get("crit", 0) == 0
    assert ex.reg.cores[1].throttle_events > 0
    assert ex.reg.cores[0].throttle_events == 0
    assert len(stats["response_times"]["sib"]) == 20
    assert ex.sched.check_invariant()
    # stalled quanta show up as throttled:<name> trace segments
    assert any(s.label == "throttled:sib" for s in ex.trace.segments)


def test_admission_requeues_when_another_gang_leads():
    """A quantum whose gang lost the lock while it waited for admission
    must requeue, never charge: the preemptor's regime could admit it
    (its acquire may have lifted the stall), but the bytes would come
    out of the preemptor's regulation window."""
    ex = GangExecutor(n_lanes=2, regulation_interval_s=0.01)
    a = RTJob("A", _sleep_fn(0), lanes=(0,), prio=2, budget_bytes=0.0,
              bytes_per_quantum=1.0, n_jobs=1)
    b = RTJob("B", _sleep_fn(0), lanes=(1,), prio=9, budget_bytes=1e9,
              n_jobs=1)
    ex.submit_rt(a)
    ex.submit_rt(b)
    ex._release_jobs()
    ex.sched.pick_next_task_rt(0, None, ex._threads[(a.uid, 0)])
    ex.sched.pick_next_task_rt(1, None, ex._threads[(b.uid, 1)])  # preempt
    used_before = ex.reg.cores[0].total_used
    assert ex._admit_rt_quantum(0, a)[0] == "requeue"
    assert ex.reg.cores[0].total_used == used_before   # nothing charged


def test_admission_gating_bypassed_when_scheduler_disabled():
    """Passthrough mode (enabled=False) never sets held_flag, so gated
    quanta must run ungated instead of requeue-spinning forever."""
    ex = GangExecutor(n_lanes=1, enabled=False)
    a = RTJob("A", _sleep_fn(0), lanes=(0,), prio=2,
              bytes_per_quantum=1.0, period_s=0.005, n_jobs=5)
    ex.submit_rt(a)
    stats = ex.run(0.3)
    assert len(stats["response_times"]["A"]) == 5
    assert stats["rt_stalls"] == {}


def test_budget_memo_tracks_member_identity_not_just_mask():
    """A different same-prio task replacing a member on the same lane
    keeps leader and core mask identical while the floor moves with the
    member set — the apply memo must not swallow that re-derivation."""
    vg = _two_member_vgang(b1=5.0, b2=2.0, w2=1)
    policy = VirtualGangPolicy([vg], n_cores=3, auto_prio=False)
    ex = GangExecutor(n_lanes=3, budget_policy=policy)
    m1, m2 = vg.members
    # both members submitted on the *same* lane: m2 replaces m1 at a
    # quantum boundary without the core mask ever changing
    for m, fn in ((m1, _sleep_fn(0)), (m2, _sleep_fn(0))):
        ex.submit_rt(RTJob(m.name, fn, lanes=(0,), prio=vg.prio,
                           budget_bytes=m.mem_budget, n_jobs=1,
                           uid=m.uid))
    ex._release_jobs()
    th1 = ex._threads[(m1.uid, 0)]
    th2 = ex._threads[(m2.uid, 0)]
    picked = ex.sched.pick_next_task_rt(0, None, th1)
    assert ex.reg.cores[2].budget == pytest.approx(5.0)   # m1's floor
    assert ex.sched.pick_next_task_rt(0, picked, th2) is th2
    assert ex.reg.cores[2].budget == pytest.approx(2.0)   # m2's floor


def test_rtg_sibling_cap_cache_is_per_interval():
    """One policy object drives both engines and the executor; the
    headroom fallback cap scales with the regulation interval, so the
    cache must not leak a sim-unit cap into the executor's regulator."""
    m1 = RTTask("c0", wcet=8.0, period=40.0, cores=(0,), prio=0,
                mem_budget=0.0, mem_intensity=0.6)   # headroom fallback
    m2 = RTTask("s0", wcet=2.0, period=40.0, cores=(1,), prio=0,
                mem_budget=9.0)
    vg = VirtualGang("c0+s0", members=[m1, m2], prio=5)
    policy = VirtualGangPolicy([vg], n_cores=3, auto_prio=False,
                               rtg_throttle=True)

    def caps_with(interval):
        ex = policy.build_executor({"c0": _sleep_fn(0), "s0": _sleep_fn(0)},
                                   n_jobs=1,
                                   regulation_interval_s=interval)
        ex._release_jobs()
        ex.sched.pick_next_task_rt(0, None, ex._threads[(m1.uid, 0)])
        ex.sched.pick_next_task_rt(1, None, ex._threads[(m2.uid, 1)])
        return ex.reg.cores[1].budget

    assert caps_with(1.0) == pytest.approx(0.4)      # (1-0.6)*1.0
    assert caps_with(0.010) == pytest.approx(0.004)  # (1-0.6)*0.010


def test_submit_vgang_rejects_duplicate_uids_and_oversized_gangs():
    vg = _two_member_vgang()
    ex = GangExecutor(n_lanes=4)
    fns = {"m1": _sleep_fn(0), "m2": _sleep_fn(0)}
    ex.submit_vgang(vg, fns)
    n_before = len(ex.rt_jobs)
    with pytest.raises(ValueError):
        ex.submit_vgang(vg, fns)          # same member uids again
    assert len(ex.rt_jobs) == n_before    # atomic: no partial submit
    wide = GangExecutor(n_lanes=2)
    with pytest.raises(ValueError):
        wide.submit_vgang(_two_member_vgang(w2=2), fns)
    # rejection must not leave a half gang behind (m1 fits, m2 doesn't)
    assert wide.rt_jobs == []
    # a missing member callable is caught up front, not mid-submit
    nofn = GangExecutor(n_lanes=4)
    with pytest.raises(ValueError):
        nofn.submit_vgang(_two_member_vgang(), {"m1": _sleep_fn(0)})
    assert nofn.rt_jobs == []


# ---------------------------------------------------------------------
# admission-mode reclaiming (DESIGN.md §7.5): retired member lanes
# donate, and a preemption revokes unspent grants
# ---------------------------------------------------------------------

def _reclaim_vgang():
    """crit c0 (intensity 0.9: most intense, so both siblings are
    dominated by the donor d0), donor d0, drawer s0."""
    c0 = RTTask("c0", wcet=9.0, period=50.0, cores=(0,), prio=0,
                mem_budget=4.0, mem_intensity=0.9)
    d0 = RTTask("d0", wcet=1.0, period=50.0, cores=(1,), prio=0,
                mem_budget=50.0, mem_intensity=0.5)
    s0 = RTTask("s0", wcet=2.0, period=50.0, cores=(2,), prio=0,
                mem_budget=50.0, mem_intensity=0.3)
    from repro.vgang.formation import intensity_interference
    intf = intensity_interference([c0, d0, s0])
    return VirtualGang("c+d+s", members=[c0, d0, s0], prio=3), intf


def test_reclaim_draws_from_retired_member_lanes():
    """A gated sibling quantum that would be denied draws the unspent
    window quota of a member whose work this release already retired,
    instead of stalling."""
    vg, intf = _reclaim_vgang()
    policy = VirtualGangPolicy([vg], n_cores=4, interference=intf,
                               auto_prio=False, rtg_throttle=True,
                               reclaim=True)
    fns = {n: _sleep_fn(0) for n in ("c0", "d0", "s0")}
    ex = policy.build_executor(fns, n_jobs=1,
                               bytes_per_quantum={"s0": 3.0},
                               regulation_interval_s=0.05)
    ex._release_jobs()
    c0, d0, s0 = vg.members
    for m, lane in ((c0, 0), (d0, 1), (s0, 2)):
        ex.sched.pick_next_task_rt(lane, None, ex._threads[(m.uid, lane)])
    cap = ex.reg.cores[2].budget            # sibling cap = crit budget
    assert cap == pytest.approx(4.0)
    # d0's only job retires on its lane -> lane 1 becomes a donor
    d_job = ex._jobs[d0.uid]
    inst = ex._active_instance(d_job, 1)
    inst.remaining_lanes.discard(1)
    # s0's window is nearly spent: the next quantum would be denied
    now = 0.01
    assert ex.reg.charge(2, 3.0, now)
    got = ex._reclaim_rt_draw(2, ex._jobs[s0.uid], 2.0, now)
    assert got == pytest.approx(2.0)
    assert ex.reg.cores[1].donated == pytest.approx(2.0)
    assert ex.reg.charge(2, 3.0, now + 0.001)   # admitted on the grant
    # the drawer is dominated by the donor for the crit (0.3 <= 0.5);
    # a hungrier-than-the-donor drawer would be refused
    assert ex._reclaim_rt_draw(2, ex._jobs[c0.uid], 1.0, now) == 0.0


def test_reclaim_lifts_already_stalled_lane():
    """A lane tripped earlier in the window (e.g. by a filler charge) is
    lifted the moment a covering donation exists — the admission
    analogue of the engines' claim_lift — instead of waiting out the
    window; and a pool too small to admit the quantum strands nothing."""
    vg, intf = _reclaim_vgang()
    policy = VirtualGangPolicy([vg], n_cores=4, interference=intf,
                               auto_prio=False, rtg_throttle=True,
                               reclaim=True)
    fns = {n: _sleep_fn(0) for n in ("c0", "d0", "s0")}
    ex = policy.build_executor(fns, n_jobs=1,
                               bytes_per_quantum={"s0": 3.0},
                               regulation_interval_s=10.0)
    ex._t0 = time.monotonic()      # _admit_rt_quantum reads ex._now()
    ex._release_jobs()
    c0, d0, s0 = vg.members
    for m, lane in ((c0, 0), (d0, 1), (s0, 2)):
        ex.sched.pick_next_task_rt(lane, None, ex._threads[(m.uid, lane)])
    # trip lane 2: an admission denial stalls it to the window end
    assert ex.reg.charge(2, 3.0, ex._now())
    assert ex.reg.charge(2, 3.0, ex._now()) is False
    assert ex.reg.is_stalled(2, ex._now())
    # no donor yet: the quantum stays stalled and no quota is stranded
    assert ex._reclaim_rt_draw(2, ex._jobs[s0.uid], 2.0, ex._now()) == 0.0
    # d0 retires -> its lane's unspent cap covers the shortfall
    inst = ex._active_instance(ex._jobs[d0.uid], 1)
    inst.remaining_lanes.discard(1)
    verdict, stalled = ex._admit_rt_quantum(2, ex._jobs[s0.uid])
    assert verdict == "run"
    assert not ex.reg.is_stalled(2, ex._now())
    assert ex.reg.cores[1].donated > 0.0


def test_reclaim_grant_revoked_when_preemption_races_donation():
    """A donor's quota lift racing a preemption must not leak into the
    preemptor's regime: the acquire lowers the drawer lane's budget,
    which revokes the unspent reclaimed grant and stalls the lane that
    already consumed more than the new limit allows."""
    vg, intf = _reclaim_vgang()
    policy = VirtualGangPolicy([vg], n_cores=4, interference=intf,
                               auto_prio=False, rtg_throttle=True,
                               reclaim=True)
    fns = {n: _sleep_fn(0) for n in ("c0", "d0", "s0")}
    ex = policy.build_executor(fns, n_jobs=1,
                               bytes_per_quantum={"s0": 3.0},
                               regulation_interval_s=0.05)
    p = RTJob("P", _sleep_fn(0.001), lanes=(3,), prio=9,
              budget_bytes=1.0, n_jobs=1)
    ex.submit_rt(p)
    ex._release_jobs()
    c0, d0, s0 = vg.members
    for m, lane in ((c0, 0), (d0, 1), (s0, 2)):
        ex.sched.pick_next_task_rt(lane, None, ex._threads[(m.uid, lane)])
    inst = ex._active_instance(ex._jobs[d0.uid], 1)
    inst.remaining_lanes.discard(1)
    now = 0.01
    assert ex.reg.charge(2, 3.0, now)
    assert ex._reclaim_rt_draw(2, ex._jobs[s0.uid], 2.0, now) > 0.0
    assert ex.reg.cores[2].drawn == pytest.approx(2.0)

    # preemption lands while the grant is still unspent
    ex.sched.pick_next_task_rt(3, None, ex._threads[(p.uid, 3)])
    assert ex.sched.g.leader is ex._tasks[p.uid]
    st = ex.reg.cores[2]
    assert st.drawn == 0.0                   # grant revoked
    assert st.budget == pytest.approx(1.0)   # preemptor's floor
    # lane 2 already consumed 3.0 > 1.0: it may not run again this
    # window under the stricter regime
    assert ex.reg.is_stalled(2, now + 0.001)
    # requeue path: the waiting sibling quantum re-enters the scheduler
    assert ex._admit_rt_quantum(2, ex._jobs[s0.uid])[0] == "requeue"


def test_formed_multi_vgang_executor_one_gang_at_a_time():
    """Two formed vgangs at distinct priorities never co-run; budgets
    observed on the free lane during each gang's quantum are that
    gang's floor (no cross-gang clobber under load)."""
    a1 = RTTask("a1", wcet=2.0, period=30.0, cores=(0,), prio=0,
                mem_budget=8.0)
    a2 = RTTask("a2", wcet=2.0, period=30.0, cores=(1,), prio=0,
                mem_budget=6.0)
    b1 = RTTask("b1", wcet=2.0, period=60.0, cores=(0, 1), prio=0,
                mem_budget=1.0)
    vgangs = assign_priorities([
        VirtualGang("a1+a2", members=[a1, a2]),
        VirtualGang("b1", members=[b1])])
    policy = VirtualGangPolicy(vgangs, n_cores=3)
    floors = {vg.prio: min(m.mem_budget for m in vg.members)
              for vg in policy.vgangs}
    bad = []
    overlap = []

    def mk(name, width):
        my_prio = next(vg.prio for vg in policy.vgangs
                       for m in vg.members if m.name == name)

        def fn(lane, idx):
            inflight = dict(ex._inflight)
            if len(set(inflight.values())) > 1:
                overlap.append(inflight)
            g = ex.sched.g
            # budget writes happen under g.lock (gang-change hook), so
            # leader + budget sampled under it form a consistent pair
            with g.lock:
                leader_prio = g.leader.prio if g.leader else None
                live = sum(1 for t in g.gthreads if t is not None)
                b = ex.reg.cores[2].budget
            if leader_prio == my_prio and live == width:
                if b > floors[my_prio] + 1e-9:
                    bad.append((name, b))
            time.sleep(0.002)
        return fn

    ex = policy.build_executor(
        {"a1": mk("a1", 2), "a2": mk("a2", 2), "b1": mk("b1", 1)},
        n_jobs=8)
    stats = ex.run(1.0)
    assert overlap == [], overlap
    assert bad == [], bad
    assert len(stats["response_times"]["b1"]) == 8
    assert ex.sched.check_invariant()
