"""Unit + property tests for the gang-lock state machine (Algorithms 1-4)."""
from _hyp import given, settings, st

from repro.core.gang import RTTask, Thread, make_virtual_gang, validate_taskset
from repro.core.glock import GangScheduler


def mk(name, cores, prio):
    t = RTTask(name=name, wcet=1.0, period=10.0, cores=tuple(cores), prio=prio)
    return t, {c: Thread(task=t, core=c, index=i)
               for i, c in enumerate(cores)}


def test_acquire_and_same_gang_joins():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 5)
    assert s.pick_next_task_rt(0, None, th1[0]) is th1[0]
    assert s.g.held_flag and s.g.leader is t1
    assert s.pick_next_task_rt(1, None, th1[1]) is th1[1]
    assert s.g.locked_cores == 0b11
    assert s.check_invariant()


def test_lower_prio_blocked_even_with_idle_cores():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 5)
    t2, th2 = mk("t2", (2, 3), 3)
    s.pick_next_task_rt(0, None, th1[0])
    s.pick_next_task_rt(1, None, th1[1])
    # cores 2,3 idle but t2 must NOT run (one-gang-at-a-time)
    assert s.pick_next_task_rt(2, None, th2[2]) is None
    assert s.pick_next_task_rt(3, None, th2[3]) is None
    assert s.g.blocked_cores == 0b1100
    assert s.check_invariant()


def test_higher_prio_gang_preempts():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 3)
    t3, th3 = mk("t3", (2,), 9)
    s.pick_next_task_rt(0, None, th1[0])
    s.pick_next_task_rt(1, None, th1[1])
    woken = []
    s.reschedule_cpus = woken.extend
    assert s.pick_next_task_rt(2, None, th3[2]) is th3[2]
    assert s.g.leader is t3
    assert s.g.locked_cores == 0b100
    assert sorted(woken) == [0, 1]          # IPIs to the preempted cores
    assert s.g.preemptions == 1


def test_release_wakes_blocked_cores():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0,), 5)
    t2, th2 = mk("t2", (1, 2), 3)
    s.pick_next_task_rt(0, None, th1[0])
    assert s.pick_next_task_rt(1, None, th2[1]) is None
    assert s.pick_next_task_rt(2, None, th2[2]) is None
    woken = []
    s.reschedule_cpus = woken.extend
    # t1's thread leaves the cpu with no successor -> lock free -> IPIs
    assert s.pick_next_task_rt(0, th1[0], None) is None
    assert not s.g.held_flag
    assert sorted(woken) == [1, 2]
    # now t2 can acquire
    assert s.pick_next_task_rt(1, None, th2[1]) is th2[1]
    assert s.g.leader is t2


def test_virtual_gang_same_prio_coschedules():
    s = GangScheduler(4)
    a, tha = mk("a", (0,), 7)
    b, thb = mk("b", (1, 2), 7)       # same prio == same (virtual) gang
    assert s.pick_next_task_rt(0, None, tha[0]) is tha[0]
    assert s.pick_next_task_rt(1, None, thb[1]) is thb[1]
    assert s.pick_next_task_rt(2, None, thb[2]) is thb[2]
    assert s.g.locked_cores == 0b111
    assert s.check_invariant()


def test_blocked_core_joining_gang_sheds_blocked_bit():
    """A core blocked at Algorithm 1 line 18-19 that later joins the
    running gang at equal priority (line 14-15) must drop its blocked
    bit — otherwise the eventual release sends it a spurious reschedule
    IPI and inflates ipis_sent."""
    s = GangScheduler(4)
    hi, th_hi = mk("hi", (0,), 5)
    lo, th_lo = mk("lo", (1, 2), 3)
    mid, th_mid = mk("mid", (1,), 5)       # same prio as hi: joins
    assert s.pick_next_task_rt(0, None, th_hi[0]) is th_hi[0]
    assert s.pick_next_task_rt(1, None, th_lo[1]) is None   # blocked
    assert s.pick_next_task_rt(2, None, th_lo[2]) is None   # blocked
    assert s.g.blocked_cores == 0b110
    # core 1 now runs a same-priority thread -> joins the gang
    assert s.pick_next_task_rt(1, None, th_mid[1]) is th_mid[1]
    assert s.g.blocked_cores == 0b100, "join must clear the blocked bit"
    woken = []
    s.reschedule_cpus = woken.extend
    s.pick_next_task_rt(0, th_hi[0], None)
    s.pick_next_task_rt(1, th_mid[1], None)       # last member: release
    assert not s.g.held_flag
    # only the still-blocked core 2 gets an IPI — exactly one
    assert woken == [2]
    assert s.g.ipis_sent == 1


def test_blocked_core_preempting_sheds_blocked_bit():
    """A blocked core whose runqueue later surfaces a *higher*-priority
    thread preempts and acquires — it too must shed its blocked bit."""
    s = GangScheduler(4)
    mid, th_mid = mk("mid", (0,), 5)
    lo, th_lo = mk("lo", (1,), 3)
    hi, th_hi = mk("hi", (1,), 9)
    s.pick_next_task_rt(0, None, th_mid[0])
    assert s.pick_next_task_rt(1, None, th_lo[1]) is None   # blocked
    assert s.g.blocked_cores == 0b10
    assert s.pick_next_task_rt(1, None, th_hi[1]) is th_hi[1]  # preempt
    assert s.g.blocked_cores == 0b00
    woken = []
    s.reschedule_cpus = woken.extend
    s.pick_next_task_rt(1, th_hi[1], None)                  # release
    assert woken == [] and s.g.blocked_cores == 0


def test_gang_change_join_and_leave_events():
    """The hook reports joins (line 14-15) and partial departures, so
    drivers can re-derive live-member budgets (executor §2.4)."""
    s = GangScheduler(4)
    events = []
    s.on_gang_change = lambda ev, leader: events.append(
        (ev, leader.name if leader else None))
    a, th_a = mk("a", (0,), 7)
    b, th_b = mk("b", (1,), 7)             # same prio: one virtual gang
    s.pick_next_task_rt(0, None, th_a[0])
    s.pick_next_task_rt(1, None, th_b[1])
    assert events == [("acquire", "a"), ("join", "a")]
    s.pick_next_task_rt(1, th_b[1], None)  # b departs, lock still held
    assert events[-1] == ("leave", "a")
    s.pick_next_task_rt(0, th_a[0], None)  # last member: full release
    assert events[-1] == ("release", None)


def test_same_task_requeue_at_quantum_boundary_fires_no_events():
    """A member re-picked for its next quantum (prev departs, same task
    immediately re-joins on the same core) must fire neither leave nor
    join: the member set never changed, and a leave+join flap would
    transiently lift budget caps derived from the live-member set."""
    s = GangScheduler(4)
    events = []
    s.on_gang_change = lambda ev, leader: events.append(ev)
    a, th_a = mk("a", (0,), 7)
    b, th_b = mk("b", (1,), 7)
    s.pick_next_task_rt(0, None, th_a[0])
    s.pick_next_task_rt(1, None, th_b[1])
    assert events == ["acquire", "join"]
    # quantum boundary: b's thread goes off and straight back on
    picked = s.pick_next_task_rt(1, th_b[1], th_b[1])
    assert picked is th_b[1]
    assert events == ["acquire", "join"]   # no leave/join flap
    assert s.g.locked_cores == 0b11
    # a *different* same-prio task replacing prev still reports both
    c, th_c = mk("c", (1,), 7)
    s.pick_next_task_rt(1, th_b[1], th_c[1])
    assert events == ["acquire", "join", "leave", "join"]


def test_disabled_passthrough():
    s = GangScheduler(4, enabled=False)
    t1, th1 = mk("t1", (0, 1), 5)
    t2, th2 = mk("t2", (2, 3), 3)
    assert s.pick_next_task_rt(0, None, th1[0]) is th1[0]
    assert s.pick_next_task_rt(2, None, th2[2]) is th2[2]  # co-scheduled


def test_make_virtual_gang_and_validation():
    t1 = RTTask("x", 1, 10, (0,), 1)
    t2 = RTTask("y", 1, 10, (1,), 2)
    gang = make_virtual_gang("g", [t1, t2], prio=5)
    assert all(t.prio == 5 for t in gang)
    validate_taskset(gang)
    bad = make_virtual_gang("g", [RTTask("x", 1, 10, (0,), 1),
                                  RTTask("y", 1, 10, (0,), 2)], prio=5)
    try:
        validate_taskset(bad)
        assert False, "expected ValueError"
    except ValueError:
        pass


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),            # cpu
                          st.integers(0, 3),            # task idx
                          st.booleans()),               # thread departs
                min_size=1, max_size=60))
def test_invariant_under_random_schedules(events):
    """One-gang-at-a-time holds under arbitrary pick sequences."""
    tasks = [mk(f"t{i}", (0, 1, 2, 3), prio=i + 1) for i in range(4)]
    s = GangScheduler(4)
    running = {}
    for cpu, ti, depart in events:
        task, threads = tasks[ti]
        prev = running.get(cpu)
        nxt = threads[cpu]
        if depart and prev is not None:
            picked = s.pick_next_task_rt(cpu, prev, None)
            running.pop(cpu, None)
        else:
            picked = s.pick_next_task_rt(cpu, prev, nxt)
            if picked is not None:
                running[cpu] = picked
            else:
                running.pop(cpu, None)
        # sync with preemptions
        for c in list(running):
            if s.g.gthreads[c] is not running[c]:
                running.pop(c)
        assert s.check_invariant()
        if s.g.held_flag:
            assert s.g.leader is not None
            assert s.g.locked_cores != 0
        else:
            assert s.g.locked_cores == 0
