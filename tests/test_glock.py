"""Unit + property tests for the gang-lock state machine (Algorithms 1-4)."""
from _hyp import given, settings, st

from repro.core.gang import RTTask, Thread, make_virtual_gang, validate_taskset
from repro.core.glock import GangScheduler


def mk(name, cores, prio):
    t = RTTask(name=name, wcet=1.0, period=10.0, cores=tuple(cores), prio=prio)
    return t, {c: Thread(task=t, core=c, index=i)
               for i, c in enumerate(cores)}


def test_acquire_and_same_gang_joins():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 5)
    assert s.pick_next_task_rt(0, None, th1[0]) is th1[0]
    assert s.g.held_flag and s.g.leader is t1
    assert s.pick_next_task_rt(1, None, th1[1]) is th1[1]
    assert s.g.locked_cores == 0b11
    assert s.check_invariant()


def test_lower_prio_blocked_even_with_idle_cores():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 5)
    t2, th2 = mk("t2", (2, 3), 3)
    s.pick_next_task_rt(0, None, th1[0])
    s.pick_next_task_rt(1, None, th1[1])
    # cores 2,3 idle but t2 must NOT run (one-gang-at-a-time)
    assert s.pick_next_task_rt(2, None, th2[2]) is None
    assert s.pick_next_task_rt(3, None, th2[3]) is None
    assert s.g.blocked_cores == 0b1100
    assert s.check_invariant()


def test_higher_prio_gang_preempts():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0, 1), 3)
    t3, th3 = mk("t3", (2,), 9)
    s.pick_next_task_rt(0, None, th1[0])
    s.pick_next_task_rt(1, None, th1[1])
    woken = []
    s.reschedule_cpus = woken.extend
    assert s.pick_next_task_rt(2, None, th3[2]) is th3[2]
    assert s.g.leader is t3
    assert s.g.locked_cores == 0b100
    assert sorted(woken) == [0, 1]          # IPIs to the preempted cores
    assert s.g.preemptions == 1


def test_release_wakes_blocked_cores():
    s = GangScheduler(4)
    t1, th1 = mk("t1", (0,), 5)
    t2, th2 = mk("t2", (1, 2), 3)
    s.pick_next_task_rt(0, None, th1[0])
    assert s.pick_next_task_rt(1, None, th2[1]) is None
    assert s.pick_next_task_rt(2, None, th2[2]) is None
    woken = []
    s.reschedule_cpus = woken.extend
    # t1's thread leaves the cpu with no successor -> lock free -> IPIs
    assert s.pick_next_task_rt(0, th1[0], None) is None
    assert not s.g.held_flag
    assert sorted(woken) == [1, 2]
    # now t2 can acquire
    assert s.pick_next_task_rt(1, None, th2[1]) is th2[1]
    assert s.g.leader is t2


def test_virtual_gang_same_prio_coschedules():
    s = GangScheduler(4)
    a, tha = mk("a", (0,), 7)
    b, thb = mk("b", (1, 2), 7)       # same prio == same (virtual) gang
    assert s.pick_next_task_rt(0, None, tha[0]) is tha[0]
    assert s.pick_next_task_rt(1, None, thb[1]) is thb[1]
    assert s.pick_next_task_rt(2, None, thb[2]) is thb[2]
    assert s.g.locked_cores == 0b111
    assert s.check_invariant()


def test_disabled_passthrough():
    s = GangScheduler(4, enabled=False)
    t1, th1 = mk("t1", (0, 1), 5)
    t2, th2 = mk("t2", (2, 3), 3)
    assert s.pick_next_task_rt(0, None, th1[0]) is th1[0]
    assert s.pick_next_task_rt(2, None, th2[2]) is th2[2]  # co-scheduled


def test_make_virtual_gang_and_validation():
    t1 = RTTask("x", 1, 10, (0,), 1)
    t2 = RTTask("y", 1, 10, (1,), 2)
    gang = make_virtual_gang("g", [t1, t2], prio=5)
    assert all(t.prio == 5 for t in gang)
    validate_taskset(gang)
    bad = make_virtual_gang("g", [RTTask("x", 1, 10, (0,), 1),
                                  RTTask("y", 1, 10, (0,), 2)], prio=5)
    try:
        validate_taskset(bad)
        assert False, "expected ValueError"
    except ValueError:
        pass


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),            # cpu
                          st.integers(0, 3),            # task idx
                          st.booleans()),               # thread departs
                min_size=1, max_size=60))
def test_invariant_under_random_schedules(events):
    """One-gang-at-a-time holds under arbitrary pick sequences."""
    tasks = [mk(f"t{i}", (0, 1, 2, 3), prio=i + 1) for i in range(4)]
    s = GangScheduler(4)
    running = {}
    for cpu, ti, depart in events:
        task, threads = tasks[ti]
        prev = running.get(cpu)
        nxt = threads[cpu]
        if depart and prev is not None:
            picked = s.pick_next_task_rt(cpu, prev, None)
            running.pop(cpu, None)
        else:
            picked = s.pick_next_task_rt(cpu, prev, nxt)
            if picked is not None:
                running[cpu] = picked
            else:
                running.pop(cpu, None)
        # sync with preemptions
        for c in list(running):
            if s.g.gthreads[c] is not running[c]:
                running.pop(c)
        assert s.check_invariant()
        if s.g.held_flag:
            assert s.g.leader is not None
            assert s.g.locked_cores != 0
        else:
            assert s.g.locked_cores == 0
