"""Batched vectorized RTA (analysis/batched_rta.py, DESIGN.md §13):
property tests asserting the padded masked kernel matches the scalar
Audsley fixed point EXACTLY — same float bits for every WCRT, same
accept bit, same divergence verdict — across ~500 seeded random
tasksets plus the padded-lane edge cases (singleton tasksets,
all-divergent shards, infinite-WCET interferers)."""
import math
import random

import pytest

from repro.analysis.batched_rta import (accept_bits, batched_accepts,
                                        batched_response_times,
                                        batched_schedulable, fixed_point,
                                        pad_rows, pad_tasksets)
from repro.core.gang import RTTask
from repro.core.rta import response_time, schedulable
from repro.launch.sweep import random_gang_taskset, taskset_seed
from repro.vgang.formation import (assign_priorities,
                                   intensity_interference,
                                   singleton_vgangs)
from repro.vgang.grid import n_tasks_for, random_vgang_taskset
from repro.vgang.rta import (accepts, accepts_rtg_throttle, batched_accepts
                             as vg_batched_accepts,
                             batched_accepts_rtg_throttle,
                             batched_schedulable_rtg_throttle,
                             batched_schedulable_vgangs, schedulable_rtg_throttle,
                             schedulable_vgangs)
from repro.vgang.formation import HEURISTICS


def _random_tasksets(n_sets, seed=0, n_cores=4, max_tasks=8,
                     max_util=2.2):
    """Seeded shard: varying sizes and utilizations, so the batch mixes
    converging, deadline-missing and divergent lanes."""
    sets = []
    for k in range(n_sets):
        rng = random.Random(taskset_seed(seed, k, 1.0))
        n = rng.randint(1, max_tasks)
        u = rng.uniform(0.1, max_util)
        sets.append(random_gang_taskset(rng, n_cores, n, u))
    return sets


def _assert_exact(tasksets, **kw):
    got = batched_schedulable(tasksets, **kw)
    assert len(got) == len(tasksets)
    for ts, res in zip(tasksets, got):
        want = schedulable(ts, **kw)
        assert list(res) == list(want)
        for name in want:
            w, g = want[name], res[name]
            assert g["ok"] == w["ok"], (name, g, w)
            assert g["deadline"] == w["deadline"]
            if w["wcrt"] is None:
                assert g["wcrt"] is None, (name, g, w)
            else:
                # bit-for-bit, not approx: identical float
                assert g["wcrt"] == w["wcrt"] and \
                    math.copysign(1, g["wcrt"]) == math.copysign(1, w["wcrt"])


def test_batched_matches_scalar_500_tasksets():
    """The headline property: ~500 random tasksets, exact equality."""
    sets = _random_tasksets(250, seed=0) + \
        _random_tasksets(150, seed=1, max_tasks=12, max_util=3.0) + \
        _random_tasksets(100, seed=2, n_cores=8, max_util=1.5)
    _assert_exact(sets)


def test_batched_blocking_and_crpd():
    sets = _random_tasksets(60, seed=3)
    _assert_exact(sets, blocking=0.7)
    _assert_exact(sets, crpd=0.25)
    _assert_exact(sets, blocking=0.3, crpd=0.1)


def test_singleton_tasksets():
    """One-task sets: no hp interference, padded lanes all masked."""
    sets = [[RTTask("solo", wcet=w, period=10.0, cores=(0,), prio=1)]
            for w in (0.5, 9.999999, 10.0, 10.5)]
    _assert_exact(sets)


def test_all_divergent_shard():
    """Every lane diverges (hp utilization > 1): every wcrt is None,
    every accept bit False — and the batch must not spin to max_iter."""
    sets = []
    for k in range(20):
        rng = random.Random(k)
        sets.append(random_gang_taskset(rng, 4, 6, rng.uniform(4.0, 8.0)))
    got = batched_schedulable(sets)
    for ts, res in zip(sets, got):
        want = schedulable(ts)
        for name in want:
            assert res[name]["wcrt"] == want[name]["wcrt"]
            assert res[name]["ok"] == want[name]["ok"]
    # the lowest-prio lanes genuinely diverge at these utilizations
    assert any(res[name]["wcrt"] is None
               for res in got for name in res)


def test_infinite_wcet_interferer():
    """An inf-WCET task is skipped by analysis but still interferes:
    scalar returns None for it and for everything below it."""
    ts = [RTTask("hi", wcet=float("inf"), period=20.0, cores=(0,), prio=3),
          RTTask("mid", wcet=1.0, period=20.0, cores=(0,), prio=2),
          RTTask("lo", wcet=1.0, period=40.0, cores=(0,), prio=1)]
    fine = [RTTask("a", wcet=2.0, period=10.0, cores=(0,), prio=2),
            RTTask("b", wcet=3.0, period=30.0, cores=(0,), prio=1)]
    _assert_exact([ts, fine])


def test_mixed_size_padding():
    """Sets of very different sizes in one shard: the padded columns of
    the short sets must not leak into their verdicts."""
    sets = [_random_tasksets(1, seed=10, max_tasks=2)[0],
            _random_tasksets(1, seed=11, max_tasks=15, max_util=1.2)[0],
            _random_tasksets(1, seed=12, max_tasks=1)[0]]
    _assert_exact(sets)


def test_batched_response_times_wrapper():
    sets = _random_tasksets(40, seed=5)
    wcrts = batched_response_times(sets)
    for ts, Rs in zip(sets, wcrts):
        for t, r in zip(ts, Rs):
            assert r == response_time(t, ts)


def test_accept_bits_match_schedulable():
    sets = _random_tasksets(80, seed=6, max_util=2.5)
    bits = batched_accepts(sets)
    for ts, bit in zip(sets, bits):
        assert bit == all(v["ok"] for v in schedulable(ts).values())


def test_empty_and_degenerate_shapes():
    assert batched_schedulable([]) == []
    batch = pad_rows([[("x", 1.0, 10.0, 1.0)]])
    R = fixed_point(batch)
    assert R.shape == (1, 1) and R[0, 0] == 1.0
    assert accept_bits(batch, R).tolist() == [True]


# ---------------------------------------------------------------------
# vgang batched entry points vs their scalar twins


def _vgang_workload(n_sets, seed=0, cores=(4, 8, 16), dist="mixed"):
    out = []
    for k in range(n_sets):
        m = cores[k % len(cores)]
        rng = random.Random(taskset_seed(seed, k, 1.1))
        tasks = random_vgang_taskset(rng, m, n_tasks_for(m),
                                    rng.uniform(0.3, 2.0), dist)
        intf = intensity_interference(tasks, 0.5)
        out.append((m, tasks, intf))
    return out


def test_vgang_batched_accepts_matches_scalar():
    work = _vgang_workload(60, seed=7)
    vsets, intfs = [], []
    for m, tasks, intf in work:
        vsets.append(assign_priorities(singleton_vgangs(tasks)))
        intfs.append(intf)
    got = vg_batched_accepts(vsets, intfs)
    want = [accepts(v, i) for v, i in zip(vsets, intfs)]
    assert got == want
    # dict-level too: exact wcrt equality
    res = batched_schedulable_vgangs(vsets, intfs)
    for v, i, r in zip(vsets, intfs, res):
        assert r == schedulable_vgangs(v, i)


def test_vgang_batched_rtg_throttle_matches_scalar():
    work = _vgang_workload(40, seed=8)
    vsets, intfs = [], []
    for m, tasks, intf in work:
        packed = HEURISTICS["intfaware"](tasks, m, intf)
        vsets.append(assign_priorities(packed))
        intfs.append(intf)
    for reclaim in (False, True):
        cache = {}
        got = batched_accepts_rtg_throttle(vsets, intfs, reclaim=reclaim,
                                           wcet_cache=cache)
        want = [accepts_rtg_throttle(v, i, reclaim=reclaim)
                for v, i in zip(vsets, intfs)]
        assert got == want
        res = batched_schedulable_rtg_throttle(vsets, intfs,
                                               reclaim=reclaim,
                                               wcet_cache=cache)
        for v, i, r in zip(vsets, intfs, res):
            assert r == schedulable_rtg_throttle(v, i, reclaim=reclaim)


def test_jax_backend_matches_numpy():
    jax = pytest.importorskip("jax")
    del jax
    sets = _random_tasksets(25, seed=9, max_util=2.0)
    a = batched_schedulable(sets, backend="numpy")
    b = batched_schedulable(sets, backend="jax")
    for ra, rb in zip(a, b):
        for name in ra:
            assert ra[name]["wcrt"] == rb[name]["wcrt"]
            assert ra[name]["ok"] == rb[name]["ok"]


# ---- window-kernel closed form (ISSUE 9 satellite): the vectorized
# rtg-throttle / reclaim window evaluation must be bit-identical to the
# scalar segment walk, including the infinite (starved-sibling) bounds.

def _window_workload(n_sets, seed, heuristic="intfaware"):
    from repro.vgang.formation import assign_priorities
    out = []
    for k in range(n_sets):
        m = (4, 8, 16)[k % 3]
        rng = random.Random(taskset_seed(seed, k, 1.3))
        tasks = random_vgang_taskset(rng, m, n_tasks_for(m),
                                     rng.uniform(0.3, 2.0), "mixed")
        intf = intensity_interference(tasks, rng.choice((0.5, 2.0, 8.0)))
        out.append((assign_priorities(HEURISTICS[heuristic](
            tasks, m, intf)), intf))
    return out


def test_batched_rtg_throttle_wcet_bit_identical():
    from repro.analysis.batched_rta import batched_rtg_throttle_wcet
    from repro.vgang.rta import rtg_throttle_wcet
    work = _window_workload(40, seed=11)
    flat = [(vg, intf) for vgs, intf in work for vg in vgs]
    got = batched_rtg_throttle_wcet([vg for vg, _ in flat],
                                    [i for _, i in flat])
    assert len(got) == len(flat)
    saw_inf = False
    for (vg, intf), g in zip(flat, got):
        w = rtg_throttle_wcet(vg, intf)
        assert g == w or (math.isinf(g) and math.isinf(w)), \
            (vg.name, g, w)
        saw_inf |= math.isinf(w)
    assert len(flat) > 50


def test_batched_reclaim_wcet_bit_identical():
    from repro.analysis.batched_rta import batched_reclaim_wcet
    from repro.vgang.rta import reclaim_wcet
    work = _window_workload(40, seed=12)
    flat = [(vg, intf) for vgs, intf in work for vg in vgs]
    got = batched_reclaim_wcet([vg for vg, _ in flat],
                               [i for _, i in flat])
    assert len(got) == len(flat)
    for (vg, intf), g in zip(flat, got):
        w = reclaim_wcet(vg, intf)
        assert g == w or (math.isinf(g) and math.isinf(w)), \
            (vg.name, g, w)


def test_batched_window_wcet_starved_sibling_inf():
    """A fully memory-bound critical member leaves zero sibling budget:
    the sibling's window never makes progress and both scalar and
    batched kernels must price the gang at exactly +inf."""
    from repro.analysis.batched_rta import (batched_reclaim_wcet,
                                            batched_rtg_throttle_wcet)
    from repro.vgang.formation import VirtualGang
    from repro.vgang.rta import reclaim_wcet, rtg_throttle_wcet
    # crit's C*slow dominates -> it is the protected member; its full
    # memory intensity leaves Q = (1 - 1.0) * interval = 0 for siblings
    crit = RTTask("crit", wcet=9.0, period=20.0, cores=(0,), prio=1,
                  mem_intensity=1.0)
    sib = RTTask("sib", wcet=1.0, period=20.0, cores=(1,), prio=1,
                 mem_intensity=0.9)
    vg = VirtualGang("starved", [crit, sib], prio=1)
    intf = intensity_interference([crit, sib], 0.5)
    w = rtg_throttle_wcet(vg, intf)
    assert math.isinf(w)
    (b,) = batched_rtg_throttle_wcet([vg], [intf])
    assert math.isinf(b)
    r = reclaim_wcet(vg, intf)
    (br,) = batched_reclaim_wcet([vg], [intf])
    assert r == br or (math.isinf(r) and math.isinf(br))


def test_window_eval_pad_lanes_exact_zero():
    """Padded lanes (d=0, s=1) contribute exactly 0.0 to the cumsum, so
    mixed-length profiles evaluate identically to their scalar walks."""
    import numpy as np
    from repro.analysis.batched_rta import pad_profiles, window_eval
    profiles = [[(0.4, 1.0), (0.6, 0.5)], [(1.0, 1.0)]]
    D, S, valid = pad_profiles(profiles)
    work, full, offset, feasible = window_eval(
        D, S, valid, np.array([3.2, 2.0]))
    # lane 0: work/interval = 0.4 + 1.2 = 1.6; lane 1: 1.0
    assert work[0] == 0.4 + 0.6 / 0.5 and work[1] == 1.0
    assert feasible.all()
    # need=3.2 -> 2 full windows (3.2) ... exactly consumed at the end
    # of window 2, need=2.0 -> 1 full window + offset 1.0
    assert (full[1], offset[1]) == (1.0, 1.0)
