"""Response-time analysis: exactness on the paper examples and the
sim-vs-analysis soundness property (RTA bound >= simulated WCRT)."""
import pytest

from _hyp import given, settings, st

from repro.core.gang import RTTask
from repro.core.rta import (co_sched_wcet, response_time, schedulable,
                            total_utilization)
from repro.core.sim import Simulator, matrix_interference


def test_illustrative_example_rta():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1)
    assert response_time(t1, [t1, t2]) == pytest.approx(2.0)
    assert response_time(t2, [t1, t2]) == pytest.approx(6.0)
    res = schedulable([t1, t2])
    assert res["tau1"]["ok"] and res["tau2"]["ok"]
    assert total_utilization([t1, t2]) == pytest.approx(0.6)


def test_dnn_taskset_tx2_schedulable():
    """Paper Table II (Jetson TX2): dnn(4) + bww under RT-Gang."""
    dnn = RTTask("dnn", wcet=7.6, period=17, cores=(0, 1, 2, 3), prio=2)
    bww = RTTask("bww", wcet=40.0, period=100, cores=(0, 1, 2, 3), prio=1)
    res = schedulable([dnn, bww])
    assert res["dnn"]["ok"]
    # bww WCRT = 40 + interference from dnn releases
    assert res["bww"]["wcrt"] > 40.0
    assert res["bww"]["ok"]


def test_cosched_wcet_blowup():
    """The 10x co-scheduling WCET makes the set unschedulable, while RT-Gang
    keeps solo WCETs (the paper's core argument)."""
    intf = matrix_interference({("tau1", "tau2"): 10.0})
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1)
    assert co_sched_wcet(t1, [t1, t2], intf) == pytest.approx(20.0)
    pess = RTTask("tau1p", wcet=20.0, period=10, cores=(0, 1), prio=2)
    assert not schedulable([pess, t2])["tau1p"]["ok"]


def test_blocking_term():
    """Non-preemptible lower-prio quanta add B_i (TPU-executor adaptation)."""
    t1 = RTTask("hi", wcet=2, period=10, cores=(0,), prio=2)
    t2 = RTTask("lo", wcet=4, period=20, cores=(0,), prio=1)
    r0 = response_time(t1, [t1, t2], blocking=0.0)
    r1 = response_time(t1, [t1, t2], blocking=1.5)
    assert r1 == pytest.approx(r0 + 1.5)


@settings(max_examples=25, deadline=None)
@given(st.lists(
    st.tuples(st.integers(1, 4),      # wcet
              st.integers(2, 6)),     # period multiplier
    min_size=1, max_size=3))
def test_rta_bounds_simulated_wcrt(spec):
    """Soundness: if RTA declares the set schedulable, the simulator observes
    response times <= the RTA bound (one-gang-at-a-time transform)."""
    tasks = []
    for i, (c, pm) in enumerate(spec):
        period = c * pm * 2
        tasks.append(RTTask(f"t{i}", wcet=float(c), period=float(period),
                            cores=(i % 4,), prio=100 - i))
    res = schedulable(tasks)
    if not all(v["ok"] for v in res.values()):
        return
    horizon = 4 * max(t.period for t in tasks)
    sim = Simulator(4, tasks, rt_gang_enabled=True, dt=0.25)
    r = sim.run(horizon)
    for t in tasks:
        if r.response_times[t.name]:
            assert max(r.response_times[t.name]) <= \
                res[t.name]["wcrt"] + 0.5 + 1e-6, \
                (t.name, r.response_times[t.name], res[t.name])
