"""Event-driven engine (core/events.py): paper-number exactness and
quantum-mode equivalence on the Fig.4 and Fig.5 tasksets.

The exact engine is the dt -> 0 limit of the quantum engine, so agreement
is asserted within one default quantum (0.05 ms): the quantum reference
runs at dt=0.025, where its reactive-throttle discretization bias is well
inside that envelope (the bias is O(dt) per regulation window; see the
convergence study in DESIGN.md §8.4).
"""
import pytest

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference

DT_DEFAULT = 0.05          # the quantum engine's default quantum (ms)


def fig4_taskset():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2,
                mem_budget=1e9)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1,
                mem_budget=1e9)
    be = [BETask("tau3", cores=(0, 1, 2, 3))]
    return [t1, t2], be


def fig5_taskset():
    # benchmarks/fig5_synthetic.py::taskset, restated so the test is
    # self-contained
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return [t1, t2], [bem, bec], intf


# ---------------------------------------------------------------------
# paper numbers reproduced exactly in dt=None mode (no quantization)
# ---------------------------------------------------------------------

def test_exact_fig4a_cosched():
    rts, be = fig4_taskset()
    r = Simulator(4, rts, be_tasks=be, rt_gang_enabled=False,
                  dt=None).run(10.0)
    assert r.engine == "event"
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(4.0)]
    assert r.slack_time == pytest.approx(28.0)


def test_exact_fig4b_rtgang():
    rts, be = fig4_taskset()
    r = Simulator(4, rts, be_tasks=be, rt_gang_enabled=True,
                  dt=None).run(10.0)
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(6.0)]
    assert r.slack_time == pytest.approx(28.0)


def test_exact_fig4c_interference():
    rts, be = fig4_taskset()
    intf = matrix_interference({("tau1", "tau2"): 10.0})
    r = Simulator(4, rts, be_tasks=be, interference=intf,
                  rt_gang_enabled=False, dt=None).run(10.0)
    assert r.response_times["tau1"] == [pytest.approx(5.6)]
    assert r.response_times["tau2"] == [pytest.approx(4.0)]
    assert r.slack_time == pytest.approx(20.8)


def test_exact_rtgang_immune_to_interference():
    rts, be = fig4_taskset()
    intf = matrix_interference({("tau1", "tau2"): 10.0,
                                ("tau2", "tau1"): 100.0})
    r = Simulator(4, rts, rt_gang_enabled=True, interference=intf,
                  dt=None).run(10.0)
    assert r.response_times["tau1"] == [pytest.approx(2.0)]
    assert r.response_times["tau2"] == [pytest.approx(6.0)]


def test_exact_fig2_single_thread_idles_all_other_cores():
    t1 = RTTask("t1", wcet=4, period=100, cores=(0, 1, 2, 3), prio=1)
    t2 = RTTask("t2", wcet=2, period=100, cores=(0, 1, 2), prio=2,
                release_offset=1.0)
    t3 = RTTask("t3", wcet=1, period=100, cores=(2,), prio=3,
                release_offset=2.0)
    r = Simulator(4, [t1, t2, t3], dt=None).run(20.0)
    r.trace.finish_view()
    for seg in r.trace.segments:
        if seg.label in ("t1", "t2"):
            assert not (seg.t0 < 3.0 - 1e-9 and seg.t1 > 2.0 + 1e-9), \
                f"{seg.label} overlaps t3 on core {seg.core}"
    assert r.response_times["t3"] == [pytest.approx(1.0)]


def test_exact_fig3_virtual_gang():
    def vgang():
        return [RTTask("g1", wcet=3, period=100, cores=(0,), prio=5),
                RTTask("g2", wcet=2, period=100, cores=(1,), prio=5),
                RTTask("g3", wcet=1, period=100, cores=(2, 3), prio=5)]

    t4 = RTTask("t4", wcet=1, period=100, cores=(1,), prio=4,
                release_offset=1.0)
    r = Simulator(4, vgang() + [t4], dt=None).run(20.0)
    assert r.response_times["t4"] == [pytest.approx(3.0)]

    t4h = RTTask("t4", wcet=1, period=100, cores=(1,), prio=9,
                 release_offset=1.0)
    r = Simulator(4, vgang() + [t4h], dt=None).run(20.0)
    assert r.response_times["t4"] == [pytest.approx(1.0)]
    assert r.response_times["g1"] == [pytest.approx(4.0)]


def test_exact_throttling_bounds_be_progress():
    t1 = RTTask("rt", wcet=5, period=10, cores=(0, 1), prio=5,
                mem_budget=0.2)
    bem = BETask("be_mem", cores=(2, 3), mem_rate=1.0)
    r = Simulator(4, [t1], be_tasks=[bem], dt=None,
                  throttle_mode="reactive").run(10.0)
    assert r.throttle_events > 0
    assert r.be_progress["be_mem"] < 2 * 5 * 0.35 + 2 * 5 * 1.0 + 1.0


# ---------------------------------------------------------------------
# quantum-mode equivalence (the ISSUE's acceptance criterion)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("enabled", [False, True])
def test_fig4_equivalence(enabled):
    intf = matrix_interference({("tau1", "tau2"): 10.0})
    rts, be = fig4_taskset()
    q = Simulator(4, rts, be_tasks=be, interference=intf,
                  rt_gang_enabled=enabled, dt=DT_DEFAULT).run(40.0)
    rts, be = fig4_taskset()
    e = Simulator(4, rts, be_tasks=be, interference=intf,
                  rt_gang_enabled=enabled, dt=None).run(40.0)
    assert e.engine == "event" and q.engine == "quantum"
    for name in ("tau1", "tau2"):
        assert len(q.response_times[name]) == len(e.response_times[name])
        assert abs(q.wcrt(name) - e.wcrt(name)) <= DT_DEFAULT + 1e-9
    assert q.deadline_misses == e.deadline_misses
    assert q.slack_time == pytest.approx(e.slack_time, abs=4 * DT_DEFAULT)
    # best-effort progress parity: both engines share the fractional
    # fair-sharing model, so unthrottled progress matches exactly
    for b in q.be_progress:
        assert q.be_progress[b] == pytest.approx(e.be_progress[b],
                                                 abs=4 * DT_DEFAULT), b


@pytest.mark.parametrize("enabled", [False, True])
def test_fig5_equivalence(enabled):
    # quantum reference at dt=0.025: its O(dt)-per-window throttle bias
    # stays within the one-default-quantum (0.05 ms) agreement envelope
    rts, bes, intf = fig5_taskset()
    q = Simulator(4, rts, be_tasks=bes, interference=intf,
                  rt_gang_enabled=enabled, dt=0.025,
                  throttle_mode="reactive").run(120.0)
    rts, bes, intf = fig5_taskset()
    e = Simulator(4, rts, be_tasks=bes, interference=intf,
                  rt_gang_enabled=enabled, dt=None,
                  throttle_mode="reactive").run(120.0)
    for name in ("tau1", "tau2"):
        assert len(q.response_times[name]) == len(e.response_times[name])
        assert abs(q.wcrt(name) - e.wcrt(name)) <= DT_DEFAULT + 1e-9, name
        # every job, not just the worst case
        for rq, re_ in zip(q.response_times[name], e.response_times[name]):
            assert abs(rq - re_) <= 2 * DT_DEFAULT + 1e-9, name
    assert q.deadline_misses == e.deadline_misses
    assert q.throttle_events == e.throttle_events
    # be_progress parity within the quantum engine's reactive-throttle
    # discretization bias: O(dt) per 1 ms regulation window
    for b in q.be_progress:
        assert q.be_progress[b] == pytest.approx(
            e.be_progress[b], abs=120.0 * 0.025 + 1e-6), b


def test_event_count_is_small():
    """O(events), not O(horizon/dt): a 1000 ms Fig.5 run needs ~40 events
    per ms of *activity*, far below the 20k quantum steps."""
    rts, bes, intf = fig5_taskset()
    e = Simulator(4, rts, be_tasks=bes, interference=intf,
                  rt_gang_enabled=True, dt=None,
                  throttle_mode="reactive").run(1000.0)
    assert 0 < e.events < 1000.0 / DT_DEFAULT
    assert len(e.response_times["tau1"]) == 50


def test_exact_backlogged_jobs_fifo():
    """An overloaded task backlogs: releases queue and are served FIFO,
    with deadline misses counted on completion (same rule as quantum)."""
    t = RTTask("over", wcet=3, period=2, cores=(0,), prio=5, n_jobs=4)
    q = Simulator(1, [t], dt=DT_DEFAULT).run(20.0)
    e = Simulator(1, [t], dt=None).run(20.0)
    assert q.response_times["over"] == pytest.approx(
        e.response_times["over"], abs=DT_DEFAULT)
    assert q.deadline_misses == e.deadline_misses
    assert e.deadline_misses["over"] > 0


# ---------------------------------------------------------------------
# deadline-miss parity: counts AND per-task miss timestamps agree
# between engines (ISSUE 6 satellite; miss_times is stamped at the
# completion/abort instant, same rule in both engines)
# ---------------------------------------------------------------------

def _miss_parity(q, e, tol):
    assert q.deadline_misses == e.deadline_misses
    assert set(q.miss_times) == set(e.miss_times)
    for name in q.miss_times:
        assert len(q.miss_times[name]) == len(e.miss_times[name]), name
        for tq, te in zip(q.miss_times[name], e.miss_times[name]):
            assert abs(tq - te) <= tol, name


def test_miss_parity_fig4():
    rts, bes = fig4_taskset()
    q = Simulator(4, rts, be_tasks=bes, rt_gang_enabled=True,
                  dt=0.025).run(100.0)
    e = Simulator(4, rts, be_tasks=bes, rt_gang_enabled=True,
                  dt=None).run(100.0)
    _miss_parity(q, e, DT_DEFAULT)
    assert sum(q.deadline_misses.values()) == 0     # Fig.4b: schedulable


def test_miss_parity_fig5():
    rts, bes, intf = fig5_taskset()
    q = Simulator(4, rts, be_tasks=bes, interference=intf,
                  rt_gang_enabled=True, dt=0.025,
                  throttle_mode="reactive").run(120.0)
    e = Simulator(4, rts, be_tasks=bes, interference=intf,
                  rt_gang_enabled=True, dt=None,
                  throttle_mode="reactive").run(120.0)
    _miss_parity(q, e, DT_DEFAULT)


def test_miss_parity_overloaded():
    """A genuinely overloaded variant, so the parity check exercises
    non-empty miss lists: every miss lands at the same (task, ordinal)
    with timestamps within one default quantum."""
    rts, bes = fig4_taskset()
    import dataclasses
    rts = [dataclasses.replace(rts[0], wcet=5.0, n_jobs=8),
           dataclasses.replace(rts[1], wcet=7.0, n_jobs=8)]
    q = Simulator(4, rts, be_tasks=bes, rt_gang_enabled=True,
                  dt=0.025).run(140.0)
    e = Simulator(4, rts, be_tasks=bes, rt_gang_enabled=True,
                  dt=None).run(140.0)
    assert sum(e.deadline_misses.values()) > 0
    _miss_parity(q, e, DT_DEFAULT)
    # every recorded miss count matches its timestamp list's length
    for name, n in e.deadline_misses.items():
        assert len(e.miss_times.get(name, [])) == n
