"""Optional-hypothesis shim: lets test modules keep their deterministic
unit tests runnable when hypothesis is absent (requirements-dev.txt),
skipping only the @given property tests.

    from _hyp import given, settings, st
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                              # pragma: no cover
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="property test needs hypothesis (requirements-dev.txt)")

    def settings(*args, **kwargs):
        return lambda f: f

__all__ = ["given", "settings", "st"]
