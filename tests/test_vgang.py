"""Virtual-gang subsystem (src/repro/vgang/): formation heuristics vs the
exhaustive optimum, vgang RTA degenerate-case equivalence with core/rta.py,
event-engine agreement with vgang RTA on the paper tasksets, per-member
throttle budgets, SimResult percentiles and sweep reproducibility."""
import dataclasses
import random

import pytest

from repro.core import rta as core_rta
from repro.core.gang import BETask, RTTask
from repro.core.sim import SimResult, Simulator, matrix_interference
from repro.core.tracing import Trace
from repro.launch.sweep import (_sched_cell, schedulability_sweep,
                                taskset_seed)
from repro.vgang.formation import (HEURISTICS, VirtualGang,
                                   assign_priorities, exhaustive_optimal,
                                   first_fit_decreasing,
                                   intensity_interference,
                                   interference_aware, singleton_vgangs,
                                   total_vgang_utilization)
from repro.vgang.grid import random_vgang_taskset, run_grid
from repro.vgang.rta import (response_time_vgang, schedulable_vgangs,
                             vgang_equivalent_task)
from repro.vgang.sched import VirtualGangPolicy

ALL_FORMERS = dict(HEURISTICS)


def random_case(seed, n_cores=4, n_tasks=5, util=1.0, dist="mixed",
                gamma=0.5):
    rng = random.Random(seed)
    tasks = random_vgang_taskset(rng, n_cores, n_tasks, util, dist)
    return tasks, intensity_interference(tasks, gamma)


# ---------------------------------------------------------------------
# formation invariants + heuristics vs the exhaustive optimum
# ---------------------------------------------------------------------

@pytest.mark.parametrize("hname", sorted(ALL_FORMERS))
def test_formation_invariants(hname):
    """Every heuristic yields a true partition: each gang in exactly one
    virtual gang, members share a period, widths fit the machine."""
    for seed in range(5):
        tasks, intf = random_case(seed, util=1.2)
        vgangs = ALL_FORMERS[hname](tasks, 4, intf)
        names = [m.name for vg in vgangs for m in vg.members]
        assert sorted(names) == sorted(t.name for t in tasks)
        for vg in vgangs:
            assert vg.width <= 4
            assert len({m.period for m in vg.members}) == 1


def test_heuristics_vs_exhaustive_optimum():
    """No heuristic packs below the exhaustive minimum of total inflated
    utilization. The cost-aware heuristic additionally never packs worse
    than the singleton baseline (it merges only when the merge is
    cheaper than standing alone); the width-greedy packers may, since
    they merge on fit, not on cost."""
    for seed in range(6):
        tasks, intf = random_case(seed, util=1.0)
        opt = total_vgang_utilization(exhaustive_optimal(tasks, 4, intf),
                                      intf)
        base = total_vgang_utilization(singleton_vgangs(tasks), intf)
        assert opt <= base + 1e-9
        for hname, h in ALL_FORMERS.items():
            got = total_vgang_utilization(h(tasks, 4, intf), intf)
            assert got >= opt - 1e-9, (hname, seed, got, opt)
        u_ia = total_vgang_utilization(interference_aware(tasks, 4, intf),
                                       intf)
        assert u_ia <= base + 1e-9, (seed, u_ia, base)


def test_interference_aware_separates_memory_heavy_gangs():
    """Crafted case: two memory-hungry gangs inflate each other 2x, two
    quiet gangs are free to pack. FFD (width-greedy) pairs the heavies;
    the interference-aware rule keeps them apart and matches the
    exhaustive optimum."""
    mk = lambda n, w, c, s: RTTask(n, wcet=c, period=20.0,
                                   cores=tuple(range(w)), prio=1,
                                   mem_intensity=s)
    tasks = [mk("h1", 2, 6.0, 1.0), mk("h2", 2, 2.0, 1.0),
             mk("l1", 1, 6.0, 0.0), mk("l2", 1, 2.0, 0.0)]
    intf = intensity_interference(tasks, gamma=1.0)
    u_ffd = total_vgang_utilization(first_fit_decreasing(tasks, 4, intf),
                                    intf)
    u_ia = total_vgang_utilization(interference_aware(tasks, 4, intf), intf)
    u_opt = total_vgang_utilization(exhaustive_optimal(tasks, 4, intf),
                                    intf)
    assert u_ia == pytest.approx(u_opt)
    assert u_ffd > u_ia + 0.1
    # the heavies ended up in different virtual gangs
    for vg in interference_aware(tasks, 4, intf):
        heavies = [m for m in vg.members if m.mem_intensity > 0.5]
        assert len(heavies) <= 1


# ---------------------------------------------------------------------
# vgang RTA: degenerate one-member case == core/rta.py, bit for bit
# ---------------------------------------------------------------------

def test_singleton_vgang_rta_equals_core_rta_exactly():
    """A real gang is the degenerate one-member virtual gang: the vgang
    RTA path must reproduce core/rta.py bit for bit (same taskset order,
    so even float summation order matches)."""
    for seed in range(5):
        tasks, _ = random_case(seed, util=0.9)
        vgangs = singleton_vgangs(tasks)      # keeps each task's prio
        got = schedulable_vgangs(vgangs)
        want = core_rta.schedulable(tasks)
        assert set(got) == set(want)
        for name in want:
            assert got[name]["wcrt"] == want[name]["wcrt"], name  # exact
            assert got[name]["ok"] == want[name]["ok"], name


def test_singleton_response_time_exact_paper_numbers():
    """The Fig.4 pair through the vgang path gives the paper's exact
    2 ms / 6 ms response times."""
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1)
    vgangs = singleton_vgangs([t1, t2])
    assert response_time_vgang(vgangs[0], vgangs) == pytest.approx(2.0)
    assert response_time_vgang(vgangs[1], vgangs) == pytest.approx(6.0)


def test_rta_rejects_unprioritized_formation_output():
    """Freshly formed vgangs all carry the default prio 0; analyzing
    them that way would drop every inter-vgang interference term, so the
    RTA entry points refuse instead of returning optimistic verdicts."""
    tasks, intf = random_case(0, util=1.9)
    vgangs = first_fit_decreasing(tasks, 4, intf)
    if len(vgangs) > 1:
        with pytest.raises(ValueError, match="distinct priorities"):
            schedulable_vgangs(vgangs, intf)
    assert isinstance(
        schedulable_vgangs(assign_priorities(vgangs), intf), dict)


def test_vgang_equivalent_task_inflation():
    """A two-member vgang's equivalent task carries the max-of-pairwise
    inflated WCET and the most sensitive member's budget."""
    a = RTTask("a", wcet=2.0, period=10, cores=(0,), prio=1,
               mem_budget=5.0)
    b = RTTask("b", wcet=3.0, period=10, cores=(0, 1), prio=1,
               mem_budget=0.5)
    vg = VirtualGang("a+b", [a, b], prio=7)
    intf = matrix_interference({("a", "b"): 4.0, ("b", "a"): 1.5})
    eq = vgang_equivalent_task(vg, intf)
    assert eq.wcet == pytest.approx(max(2.0 * 4.0, 3.0 * 1.5))
    assert eq.period == 10 and eq.prio == 7
    assert eq.mem_budget == pytest.approx(0.5)
    assert eq.n_threads == vg.width == 3


# ---------------------------------------------------------------------
# event engine under VirtualGangPolicy vs vgang RTA (paper tasksets)
# ---------------------------------------------------------------------

def fig4_pair():
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1)
    return [t1, t2]


def test_fig4_merged_vgang_sim_matches_rta_schedulable():
    """tau1+tau2 merged into one width-4 virtual gang, no interference:
    RTA accepts (C_v = 4 <= 10) and the event engine runs miss-free with
    the members co-executing (tau1 finishes at 2, not serialized to 6)."""
    vg = assign_priorities([VirtualGang("v", fig4_pair())])
    assert all(v["ok"] for v in schedulable_vgangs(vg).values())
    pol = VirtualGangPolicy(vg, 4, auto_prio=False)
    r = pol.simulate(40.0)
    assert r.engine == "event"
    assert sum(r.deadline_misses.values()) == 0
    assert r.response_times["tau1"][0] == pytest.approx(2.0)
    assert r.response_times["tau2"][0] == pytest.approx(4.0)


def test_fig4_merged_vgang_sim_matches_rta_unschedulable():
    """Same merge under 10x mutual interference: the inflated WCET blows
    past the period, RTA rejects, and the simulated members indeed miss
    — verdicts agree on the negative side too."""
    intf = matrix_interference({("tau1", "tau2"): 10.0,
                                ("tau2", "tau1"): 10.0})
    vg = assign_priorities([VirtualGang("v", fig4_pair())])
    assert not all(v["ok"] for v in schedulable_vgangs(vg, intf).values())
    pol = VirtualGangPolicy(vg, 4, intf, auto_prio=False)
    r = pol.simulate(40.0)
    assert sum(r.deadline_misses.values()) > 0


def test_fig5_singletons_sim_matches_rta():
    """Fig.5 taskset as singleton virtual gangs: RTA accepts and bounds
    the simulated response times (soundness), so the verdicts agree."""
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1)
    intf = matrix_interference({("tau1", "tau2"): 2.0,
                                ("tau2", "tau1"): 2.0})
    vgangs = assign_priorities(singleton_vgangs([t1, t2]))
    rta = schedulable_vgangs(vgangs, intf)
    assert all(v["ok"] for v in rta.values())
    pol = VirtualGangPolicy(vgangs, 4, intf, auto_prio=False)
    r = pol.simulate(20 * 30.0)
    assert sum(r.deadline_misses.values()) == 0
    for name in ("tau1", "tau2"):
        assert r.wcrt(name) <= rta[name]["wcrt"] + 1e-9


@pytest.mark.parametrize("hname", sorted(ALL_FORMERS))
def test_random_sets_rta_accept_implies_simulated_missfree(hname):
    """Monte-Carlo soundness on the event engine: whenever vgang RTA
    accepts a formed set, the simulated schedule has no deadline miss."""
    checked = 0
    for seed in range(8):
        for util in (0.7, 1.1, 1.5):
            tasks, intf = random_case(1000 * seed + 7, util=util)
            vgangs = assign_priorities(ALL_FORMERS[hname](tasks, 4, intf))
            rta_ok = all(v["ok"]
                         for v in schedulable_vgangs(vgangs, intf).values())
            if not rta_ok:
                continue
            pol = VirtualGangPolicy(vgangs, 4, intf, auto_prio=False)
            horizon = 20 * max(t.period for t in tasks)
            r = pol.simulate(horizon)
            assert sum(r.deadline_misses.values()) == 0, (hname, seed, util)
            checked += 1
    assert checked > 0


# ---------------------------------------------------------------------
# per-member throttle budgets (VirtualGangPolicy.apply)
# ---------------------------------------------------------------------

def budget_taskset():
    a = RTTask("a", wcet=2.0, period=20.0, cores=(0,), prio=5,
               mem_budget=0.2, n_jobs=1)
    b = RTTask("b", wcet=10.0, period=20.0, cores=(1,), prio=5,
               mem_budget=1e18, n_jobs=1)
    be = BETask("be_mem", cores=(2, 3), mem_rate=1.0)
    return a, b, be


def test_per_member_budget_tracks_live_members():
    """While sensitive member a runs (t in [0,2)) best-effort cores get
    its 0.2 budget; once a finishes, the surviving member b's huge
    budget applies immediately. The default leader rule would pin a's
    budget for the whole gang."""
    a, b, be = budget_taskset()
    vg = VirtualGang("ab", [a, b], prio=5)
    pol = VirtualGangPolicy([vg], 4, auto_prio=False)
    r = pol.simulate(20.0, be_tasks=[be])
    # throttled at 0.2/window for 2 windows on 2 cores, free afterwards
    expect = 2 * (0.2 * 2) + 2 * 8.0 + 2 * 10.0
    assert r.be_progress["be_mem"] == pytest.approx(expect, abs=0.1)
    assert r.throttle_events >= 4

    # contrast: default leader-budget rule keeps the first acquirer's
    # (a's) budget until the lock is fully released
    a2, b2, be2 = budget_taskset()
    members = pol.taskset()  # same shape, but rebuild without the policy
    sim = Simulator(4, [a2, b2], be_tasks=[be2], dt=None)
    r2 = sim.run(20.0)
    expect2 = 2 * (0.2 * 10) + 2 * 10.0
    assert r2.be_progress["be_mem"] == pytest.approx(expect2, abs=0.1)
    assert r.be_progress["be_mem"] > r2.be_progress["be_mem"] + 10.0


def test_policy_budget_floor_is_min_over_members():
    """With both members alive the enforced budget is the minimum, even
    when the tolerant member acquired the lock first."""
    a = RTTask("a", wcet=10.0, period=20.0, cores=(0,), prio=5,
               mem_budget=1e18, n_jobs=1)     # core 0 acquires first
    b = RTTask("b", wcet=10.0, period=20.0, cores=(1,), prio=5,
               mem_budget=0.2, n_jobs=1)
    be = BETask("be_mem", cores=(2, 3), mem_rate=1.0)
    vg = VirtualGang("ab", [a, b], prio=5)
    r = VirtualGangPolicy([vg], 4, auto_prio=False).simulate(
        20.0, be_tasks=[be])
    assert r.throttle_events > 0
    # leader-only rule: leader is a (inf budget) -> no throttling at all
    r2 = Simulator(4, [dataclasses.replace(a), dataclasses.replace(b)],
                   be_tasks=[BETask("be_mem", cores=(2, 3), mem_rate=1.0)],
                   dt=None).run(20.0)
    assert r2.throttle_events == 0


# ---------------------------------------------------------------------
# SimResult percentiles (satellite: Fig.6 CDFs through the engine)
# ---------------------------------------------------------------------

def _result_with(rs):
    return SimResult(trace=Trace(1), response_times={"t": rs},
                     deadline_misses={"t": 0}, be_progress={},
                     throttle_events=0, ipis=0, preemptions=0,
                     slack_time=0.0, horizon=1.0)


def test_simresult_percentile_empty_series_is_nan():
    import math
    r = _result_with([])
    assert math.isnan(r.percentile("t", 50.0))
    assert math.isnan(r.percentile("missing", 99.0))
    assert math.isnan(r.wcrt("missing"))
    p = r.percentiles("t")
    assert p["n"] == 0 and math.isnan(p["p50"])


def test_simresult_percentile_single_sample():
    r = _result_with([7.25])
    for q in (0.0, 37.0, 50.0, 99.9, 100.0):
        assert r.percentile("t", q) == 7.25
    assert r.percentiles("t")["max"] == 7.25


def test_simresult_percentile_extremes_and_interpolation():
    r = _result_with([4.0, 1.0, 3.0, 2.0])       # unsorted on purpose
    assert r.percentile("t", 0.0) == 1.0          # q=0 -> min
    assert r.percentile("t", 100.0) == 4.0        # q=100 -> max
    assert r.percentile("t", 50.0) == pytest.approx(2.5)
    assert r.percentile("t", 25.0) == pytest.approx(1.75)


def test_simresult_percentiles():
    rs = [float(i) for i in range(1, 1001)]          # 1..1000
    r = SimResult(trace=Trace(1), response_times={"t": rs},
                  deadline_misses={"t": 0}, be_progress={},
                  throttle_events=0, ipis=0, preemptions=0,
                  slack_time=0.0, horizon=1.0)
    assert r.percentile("t", 0) == 1.0
    assert r.percentile("t", 100) == 1000.0
    assert r.percentile("t", 50) == pytest.approx(500.5)
    p = r.percentiles("t")
    assert p["p999"] == pytest.approx(999.001, abs=0.01)
    assert p["max"] == 1000.0 and p["n"] == 1000
    assert r.percentiles("missing")["n"] == 0


def test_fig6_sim_mode_percentiles_run():
    """Fig.6 through the event engine at a 10^4 ms horizon: RT-Gang's
    CDF is tight and below Co-Sched's tail (the paper's headline)."""
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "fig6", os.path.join(os.path.dirname(__file__), "..",
                             "benchmarks", "fig6_dnn_cdf.py"))
    fig6 = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(fig6)
    rows = fig6.run_sim(horizon_ms=1e4)
    assert rows["solo"]["p50"] == pytest.approx(7.6)
    assert rows["rtgang"]["p999"] < rows["cosched"]["p999"]
    assert rows["rtgang"]["misses"] == 0
    assert rows["solo"]["n"] >= 580          # ~10^4 / 17 releases


# ---------------------------------------------------------------------
# sweep batching + seeding (satellites), grid smoke
# ---------------------------------------------------------------------

def test_schedulability_sweep_reproducible_and_batched():
    kw = dict(n_cores=4, n_tasks=3, utils=(0.5, 0.9), n_per_util=3,
              cycles=5.0, processes=1, seed=42)
    a = schedulability_sweep(**kw)
    b = schedulability_sweep(**kw)
    for ra, rb in zip(a["rows"], b["rows"]):
        assert ra["sim_sched_ratio"] == rb["sim_sched_ratio"]
        assert ra["rta_sched_ratio"] == rb["rta_sched_ratio"]
        assert ra["events_total"] == rb["events_total"]
    assert a["seed"] == 42
    # sharding-independent: more workers, same per-taskset seeds
    c = schedulability_sweep(**{**kw, "processes": 4})
    for ra, rc in zip(a["rows"], c["rows"]):
        assert ra["events_total"] == rc["events_total"]
        assert ra["sim_sched_ratio"] == rc["sim_sched_ratio"]
    # the shard workers preserve the per-taskset seed formula
    cell = _sched_cell(taskset_seed(42, 1, 0.5), 4, 3, 0.5, 5.0)
    assert cell["util"] == 0.5 and isinstance(cell["sim_ok"], bool)


def test_vgang_grid_smoke(tmp_path):
    out = run_grid(cores=(4,), dists=("mixed",), utils=(0.8, 2.4),
                   heuristics=("ffd", "intfaware"), n_per_cell=4,
                   sim_check=1, processes=1, out_dir=str(tmp_path),
                   seed=3)
    s = out["summary"]
    assert s["soundness_violations"] == 0
    assert (tmp_path / "grid_4c_mixed.json").exists()
    assert (tmp_path / "summary.json").exists()
    rows = {r["util"]: r for r in out["results"]}
    # plain RT-Gang can never accept a single-core-equivalent util > 1
    assert rows[2.4]["accept"]["rtgang"] == 0.0
    for h in ("ffd", "intfaware"):
        assert 0.0 <= rows[0.8]["accept"][h] <= 1.0
    # the baseline label is accepted (and deduped) in --heuristics
    out2 = run_grid(cores=(4,), dists=("mixed",), utils=(0.8,),
                    heuristics=("rtgang", "ffd"), n_per_cell=2,
                    sim_check=0, processes=1, out_dir=str(tmp_path))
    assert set(out2["results"][0]["accept"]) == {"rtgang", "ffd"}
    # rejected when the synthesized ExperimentConfig validates the
    # policy stack (field-path ConfigurationError, a ValueError)
    with pytest.raises(ValueError, match="unknown policy column"):
        run_grid(cores=(4,), dists=("mixed",), utils=(0.8,),
                 heuristics=("nope",), n_per_cell=1, sim_check=0,
                 processes=1, out_dir=str(tmp_path))


def test_vgang_grid_rtg_throttle_column(tmp_path):
    """The RTG-throttle policy column: appears under its own label,
    its RTA verdicts stay sound against the event engine (0 violations
    on accepted cells), and — pricing sibling regulation on top of the
    same interference-aware formation — it never accepts more than
    intfaware."""
    out = run_grid(cores=(4,), dists=("mixed",), utils=(0.8, 1.2),
                   heuristics=("intfaware", "rtgT"), n_per_cell=6,
                   sim_check=2, processes=1, out_dir=str(tmp_path),
                   seed=1)
    s = out["summary"]
    assert s["soundness_violations"] == 0
    assert s["heuristics"] == ["rtgang", "intfaware", "rtgT"]
    for row in out["results"]:
        assert set(row["accept"]) == {"rtgang", "intfaware", "rtgT"}
        assert row["accept"]["rtgT"] <= row["accept"]["intfaware"] + 1e-9
