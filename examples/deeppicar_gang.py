"""The paper's headline scenario, end to end: a DAVE-2 DNN control loop as
the real-time gang, co-located with memory/cpu best-effort jobs, with and
without RT-Gang — on the real gang executor running real JAX compute.

    PYTHONPATH=src python examples/deeppicar_gang.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.deeppicar import Dave2Config
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.models.dave2 import make_dave2


def main():
    cfg = Dave2Config()
    params, fn = make_dave2(cfg)
    img = jnp.ones((1, *cfg.input_hw, 3), jnp.float32)
    fn(params, img).block_until_ready()

    mem = jnp.ones((1536, 1536), jnp.float32)
    mem_fn = jax.jit(lambda a: (a @ a).sum())
    mem_fn(mem).block_until_ready()

    period = 0.033                       # 30 Hz control loop (paper §II)
    for enabled in (False, True):
        ex = GangExecutor(n_lanes=2, enabled=enabled,
                          regulation_interval_s=0.01)
        ex.submit_rt(RTJob(
            "dnn-control", lambda lane, i: fn(params, img).block_until_ready(),
            lanes=(0,), prio=10, period_s=period, budget_bytes=0.0,
            n_jobs=120))
        ex.submit_be(BEJob(
            "mem-hog", lambda lane: mem_fn(mem).block_until_ready(),
            lanes=(0, 1), bytes_per_quantum=1536 * 1536 * 8.0))
        stats = ex.run(5.0)
        lat = np.array([s.t1 - s.t0 for s in ex.trace.segments
                        if s.label == "dnn-control"])
        mode = "RT-Gang" if enabled else "Co-Sched"
        print(f"{mode:>8}: dnn p50={np.percentile(lat, 50):.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms max={lat.max():.2f}ms "
              f"jobs={len(stats['response_times']['dnn-control'])} "
              f"be_quanta={stats['be_quanta']['mem-hog']}")
    print("RT-Gang keeps the control-loop latency near its solo value while"
          " the best-effort job is throttled to the declared budget.")


if __name__ == "__main__":
    main()
