"""Fleet scenario: formed virtual gangs on the real executor.

Three latency-critical pipelines (perception / fusion / planning) are
packed into virtual gangs by the interference-aware heuristic and
dispatched as units on JAX lanes through
``VirtualGangPolicy.build_executor`` — the glock's gang-change hook
enforces min-over-live-member lane budgets, so a best-effort analytics
filler only moves the bytes the most sensitive co-running member
tolerates. Pass ``--rtg-throttle`` to switch to RTG-throttle dispatch
(critical member uncapped, sibling lanes admission-capped), and
``--reclaim`` to add mid-window bandwidth donation on top (DESIGN.md
§7.5: retired member lanes donate their unspent window quota to gated
sibling quanta that would otherwise stall).

    PYTHONPATH=src python examples/vgang_fleet.py [--rtg-throttle]
        [--reclaim]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.executor import BEJob
from repro.core.gang import RTTask
from repro.vgang.formation import (assign_priorities, interference_aware,
                                   intensity_interference)
from repro.vgang.rta import schedulable_vgangs
from repro.vgang.sched import VirtualGangPolicy

N_LANES = 4


def jit_step(n):
    @jax.jit
    def f(x):
        return jnp.tanh(x @ x)
    x0 = jnp.full((n, n), 0.01, jnp.float32)
    f(x0).block_until_ready()
    return lambda lane, idx: f(x0).block_until_ready()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rtg-throttle", action="store_true")
    ap.add_argument("--reclaim", action="store_true",
                    help="mid-window donation on top of RTG-throttle")
    ap.add_argument("--duration", type=float, default=2.0)
    args = ap.parse_args()

    # (width, intensity, tolerable BE bytes/window); periods in task-ms
    tasks = [
        RTTask("perception", wcet=6.0, period=100.0, cores=(0,), prio=0,
               mem_intensity=0.2, mem_budget=6e6),
        RTTask("fusion", wcet=6.0, period=100.0, cores=(0,), prio=0,
               mem_intensity=0.1, mem_budget=8e6),
        RTTask("planner", wcet=8.0, period=200.0, cores=(0, 1, 2),
               prio=0, mem_intensity=0.6, mem_budget=1e6),
    ]
    intf = intensity_interference(tasks)
    vgangs = assign_priorities(interference_aware(tasks, N_LANES, intf))
    print("formed:", ", ".join(
        f"{vg.name} (prio {vg.prio}, width {vg.width})" for vg in vgangs))

    policy = VirtualGangPolicy(vgangs, n_cores=N_LANES, interference=intf,
                               auto_prio=False,
                               rtg_throttle=args.rtg_throttle
                               or args.reclaim,
                               reclaim=args.reclaim)
    fns = {"perception": jit_step(96), "fusion": jit_step(112),
           "planner": jit_step(144)}
    ex = policy.build_executor(
        fns, regulation_interval_s=0.010,
        bytes_per_quantum={n: 2e6 for n in fns}
        if policy.rtg_throttle else None)
    ex.submit_be(BEJob("analytics", lambda lane: time.sleep(3e-4),
                       lanes=tuple(range(N_LANES)),
                       bytes_per_quantum=5e5))
    stats = ex.run(args.duration)

    rta = schedulable_vgangs(vgangs, intf, blocking=10.0)
    print(f"gang invariant holds: {ex.sched.check_invariant()}; "
          f"acquisitions={stats['acquisitions']} "
          f"preemptions={stats['preemptions']} "
          f"rt_stalls={stats['rt_stalls']} "
          f"reclaimed={stats['reclaimed_bytes']:.3g}")
    for vg in vgangs:
        wcrt = rta[vg.name]["wcrt"]
        bound = "divergent" if wcrt is None else f"{wcrt:.2f} ms"
        for m in vg.members:
            rts = stats["response_times"][m.name]
            worst = max(rts) * 1e3 if rts else float("nan")
            print(f"  {m.name:10s} jobs={len(rts):3d} "
                  f"worst={worst:6.2f} ms  "
                  f"rta[{vg.name}]={bound}")
    print(f"analytics best-effort quanta: {stats['be_quanta']['analytics']}"
          f" (admitted within the running gang's budget)")


if __name__ == "__main__":
    main()
