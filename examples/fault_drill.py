"""Fault drill: watch enforcement contain a misbehaving gang.

Walks one workload through the failure modes of DESIGN.md §11: a WCET
overrun left un-enforced (starves everyone below it), then contained by
``abort``, ``demote`` and ``degrade`` enforcement, and finally a hung
member thread caught by the wall-clock watchdog. Runs on the exact
event engine; swap ``dt=None`` for ``dt=0.05`` to see the quantum
engine produce the same numbers.

    PYTHONPATH=src python examples/fault_drill.py
"""
from repro.core.faults import (Enforcement, FaultPlan, HungThread,
                               WcetOverrun)
from repro.core.gang import RTTask
from repro.core.sim import Simulator

HORIZON = 300.0


def taskset():
    # three gangs on 4 cores; tau2 will misbehave. tau3 spans every
    # core, so any un-contained overrun starves it immediately.
    return [
        RTTask("tau1", wcet=2.0, period=10.0, cores=(0, 1), prio=5,
               mem_budget=100.0, criticality=2),
        RTTask("tau2", wcet=3.0, period=15.0, cores=(2, 3), prio=4,
               mem_budget=100.0, criticality=1),
        RTTask("tau3", wcet=4.0, period=20.0, cores=(0, 1, 2, 3), prio=3,
               mem_budget=100.0, criticality=0),
    ]


def show(label, res):
    parts = []
    for t in ("tau1", "tau2", "tau3"):
        done = len(res.response_times.get(t, []))
        miss = res.deadline_misses.get(t, 0)
        parts.append(f"{t}: {done:2d} done/{miss:2d} missed")
    line = f"  {label:<22s} " + "  ".join(parts)
    if res.faults:
        f = res.faults
        enf = {k: v for k, v in f["enforced"].items() if v}
        extras = []
        if enf:
            extras.append(f"enforced={enf}")
        if f["watchdog_fires"]:
            extras.append(f"watchdog={f['watchdog_fires']}")
        extras.append(f"leaks={f['lock_leaks']}")
        line += "   [" + " ".join(extras) + "]"
    print(line)


def run(fault_plan=None, enforcement=None):
    return Simulator(4, taskset(), dt=None, fault_plan=fault_plan,
                     enforcement=enforcement).run(HORIZON)


def main():
    print(f"horizon {HORIZON:.0f} ms — misses are stamped at completion,"
          " so a starved job that never finishes is a *lost completion*")

    print("\n-- 4x WCET overrun on every tau2 job "
          "(utilization 0.6 -> 1.2) --")
    overrun = FaultPlan(faults=(WcetOverrun("tau2", factor=4.0),))
    show("fault-free baseline", run())
    show("un-enforced", run(fault_plan=overrun))
    for action in ("abort", "demote"):
        show(f"enforced: {action}",
             run(fault_plan=overrun,
                 enforcement=Enforcement(action, factor=1.2,
                                         watchdog_factor=2.0)))
    print("   -> abort kills the overrunning job at 1.2x its declared"
          " work; demote finishes the\n      residual best-effort."
          " Either way tau1/tau3 match the baseline exactly.")

    print("\n-- same overrun, one job only, under `degrade` --")
    one = FaultPlan(faults=(WcetOverrun("tau2", factor=4.0, jobs=(1,)),))
    show("enforced: degrade",
         run(fault_plan=one,
             enforcement=Enforcement("degrade", factor=1.2,
                                     watchdog_factor=2.0)))
    print("   -> tau2 (criticality 1) overruns; tau3 (criticality 0) is"
          " suspended until it\n      finishes, then restored. tau1"
          " (criticality 2) is untouched. A suspended job\n      that"
          " ages past its absolute watchdog is dropped as stale, never"
          " resumed.")

    print("\n-- hung member thread (runaway loop in tau2 job 1) --")
    hung = FaultPlan(faults=(HungThread("tau2", job=1, thread=0),))
    show("un-enforced", run(fault_plan=hung))
    show("watchdog only",
         run(fault_plan=hung,
             enforcement=Enforcement("abort", factor=100.0,
                                     watchdog_factor=2.0)))
    print("   -> un-enforced, the hung gang holds the lock forever:"
          " everything below it\n      stops completing. The watchdog"
          " aborts it at release + 2 periods and releases\n      the"
          " lock through the normal pick path — the system recovers.")


if __name__ == "__main__":
    main()
