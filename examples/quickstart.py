"""Quickstart: train a small LM end-to-end with the full substrate
(sharded data, FSDP/TP-capable model, AdamW, async checkpointing), then
serve it for a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.runner import RunnerConfig, TrainRunner


def main():
    cfg = reduced(get_config("qwen2-7b"))      # tiny same-family config
    mesh = make_local_mesh(len(jax.devices()), 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=64, kv_block=64)
    api = build_model(cfg, parallel, mesh)
    print(f"model: {cfg.name}  params={api.n_params():,}  "
          f"recipe={api.recipe}")

    opt = Optimizer(OptConfig(name="adamw", lr=3e-3, warmup=10,
                              decay_steps=100))
    data = DataConfig(seq_len=128, global_batch=8, vocab_size=cfg.vocab_size)
    runner = TrainRunner(api, opt, data,
                         RunnerConfig(total_steps=100, ckpt_every=25,
                                      ckpt_dir="/tmp/quickstart_ckpt"))
    state = runner.run()
    losses = [m["loss"] for m in runner.metrics_log]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "loss should decrease"

    engine = ServingEngine(api, state["params"], max_batch=2, max_seq=256)
    engine.warmup(prompt_len=16)
    rng = np.random.default_rng(0)
    req = Request(rid=0, prompt=rng.integers(
        1, cfg.vocab_size, size=(16,)).astype(np.int32), max_new=8)
    engine.run_until_done([req])
    print("generated tokens:", req.out)


if __name__ == "__main__":
    main()
