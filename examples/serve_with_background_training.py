"""Fleet scenario: latency-critical serving (RT gang) sharing a machine with
a best-effort training job, under RT-Gang admission throttling. The serving
decode step is the paper's 'DNN control task'; training is the memory hog.

    PYTHONPATH=src python examples/serve_with_background_training.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.data.pipeline import DataConfig, TokenSource
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.step import make_train_state, make_train_step


def main():
    mesh = make_local_mesh(1, 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=8, kv_block=8)

    # RT: serve a small qwen2-family model
    scfg = reduced(get_config("qwen2-7b"))
    sapi = build_model(scfg, parallel, mesh)
    sparams = sapi.init(jax.random.key(0))
    engine = ServingEngine(sapi, sparams, max_batch=2, max_seq=128)
    engine.warmup(prompt_len=16)
    rng = np.random.default_rng(0)
    pending = [Request(rid=i, prompt=rng.integers(
        1, scfg.vocab_size, size=(16,)).astype(np.int32), max_new=12)
        for i in range(8)]

    # BE: train a small olmoe-family model (memory-heavy microsteps)
    tcfg = reduced(get_config("olmoe-1b-7b"))
    tapi = build_model(tcfg, parallel, mesh)
    opt = Optimizer(OptConfig(lr=1e-3))
    tstate = {"v": make_train_state(tapi, opt, jax.random.key(1))}
    tstep = jax.jit(make_train_step(tapi, opt), donate_argnums=(0,))
    src = TokenSource(DataConfig(seq_len=64, global_batch=4,
                                 vocab_size=tcfg.vocab_size))
    tsteps = {"n": 0}

    def train_quantum(lane):
        b = src.train_batch(tsteps["n"])
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        tstate["v"], _ = tstep(tstate["v"], batch)
        jax.block_until_ready(tstate["v"]["step"])
        tsteps["n"] += 1

    train_quantum(1)  # compile before timing

    def decode_quantum(lane, idx):
        while pending and engine.add_request(pending[0]):
            pending.pop(0)
        engine.decode_step()

    ex = GangExecutor(n_lanes=2, regulation_interval_s=0.02)
    ex.submit_rt(RTJob("serve-decode", decode_quantum, lanes=(0,), prio=10,
                       period_s=0.02, budget_bytes=5e5, n_jobs=200))
    ex.submit_be(BEJob("train-be", train_quantum, lanes=(1,),
                       bytes_per_quantum=1e6))
    stats = ex.run(6.0)

    lat = np.array([s.t1 - s.t0 for s in ex.trace.segments
                    if s.label == "serve-decode"])
    done = sum(1 for r in pending) == 0
    print(f"serve: decode p50={np.percentile(lat, 50):.2f}ms "
          f"p99={np.percentile(lat, 99):.2f}ms; requests pending={len(pending)}")
    print(f"train: {tsteps['n']} best-effort microsteps completed "
          f"(throttled to the gang's budget)")


if __name__ == "__main__":
    main()
