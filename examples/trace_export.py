"""Export a Perfetto-viewable trace of the Fig.5 synthetic workload
(DESIGN.md §12.2): run the exact event engine with metrics and counter
history on, write the Chrome-trace JSON, and print where to load it.

    PYTHONPATH=src python examples/trace_export.py [out.json]

Open the file in https://ui.perfetto.dev (or chrome://tracing): pid
"fig5: cores" shows one track per core — gang spans in strong colors,
best-effort grey, regulator-throttled windows red — and pid
"fig5: counters" stacks per-core bandwidth used-vs-budget and the
cumulative glock hold time.
"""
import json
import sys

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference
from repro.obs.metrics import MetricsRegistry
from repro.obs.perfetto import export_sim, write_chrome_trace


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "/tmp/fig5_trace.json"

    # benchmarks/fig5_synthetic.py's taskset, restated
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })

    reg = MetricsRegistry()
    sim = Simulator(4, [t1, t2], be_tasks=[bem, bec], interference=intf,
                    rt_gang_enabled=True, dt=None,
                    throttle_mode="reactive", metrics=reg,
                    rta_bounds={"tau1": 5.25, "tau2": 15.0},
                    record_counters=True)
    res = sim.run(120.0)

    data = export_sim(sim, res, title="fig5")
    write_chrome_trace(out, data)

    spans = sum(1 for e in data["traceEvents"] if e["ph"] == "X")
    tracks = {e["name"] for e in data["traceEvents"] if e["ph"] == "C"}
    print(f"wrote {out}: {spans} spans, counter tracks {sorted(tracks)}")
    print("margins:", json.dumps(res.rta_margins, indent=1))
    print(f"open in https://ui.perfetto.dev -> 'Open trace file'")


if __name__ == "__main__":
    main()
