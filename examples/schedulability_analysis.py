"""Design-time workflow the paper enables: measure solo WCETs, form (virtual)
gangs, run classical single-core RTA, and confirm with the exact simulator —
including the co-scheduling counterfactual that RTA cannot certify.

    PYTHONPATH=src python examples/schedulability_analysis.py \\
        [--sweep] [--config configs/experiments/sweep_smoke.json] [--vgang]

--sweep additionally runs a small Monte-Carlo schedulability sweep (random
gang tasksets per utilization level, event-driven engine fanned across
processes; see repro.launch.sweep --schedulability for the full version).
--config points it at a declarative ExperimentConfig (kind "sweep",
DESIGN.md §14) instead of the built-in example axes, and implies --sweep.
The sweep's RTA verdicts run on the batched vectorized kernel
(repro.analysis.batched_rta, DESIGN.md §13) and its sims are
trace-free — both bit-identical to the scalar/traced path, which stays
reachable via ``repro.launch.sweep --schedulability --scalar-rta`` (and
``repro.vgang.grid --scalar-rta`` for the acceptance grid).

--vgang plots the virtual-gang acceptance-ratio curves from
results/vgang/*.json (produce them with ``python -m repro.vgang.grid``):
RT-Gang singleton baseline vs the formation heuristics, per core count
and width distribution. ASCII always; a PNG per grid file when
matplotlib is installed.
"""
import argparse
import glob
import json
import os

from repro.core.gang import RTTask, make_virtual_gang
from repro.core.rta import co_sched_wcet, schedulable, total_utilization
from repro.core.sim import Simulator, matrix_interference


def main():
    # Paper Table II (Jetson TX2): DNN gang + BwWrite gang
    dnn = RTTask("dnn(4)", wcet=7.6, period=17, cores=(0, 1, 2, 3), prio=2,
                 mem_budget=100e6)
    bww = RTTask("bww", wcet=40.0, period=100, cores=(0, 1, 2, 3), prio=1)
    taskset = [dnn, bww]

    print("utilization (single-core equivalent):",
          round(total_utilization(taskset), 3))
    res = schedulable(taskset)
    for name, r in res.items():
        print(f"  {name}: WCRT={r['wcrt']:.2f}ms deadline={r['deadline']} "
              f"ok={r['ok']}")

    # counterfactual: co-scheduling with the measured 10.33x DNN slowdown
    intf = matrix_interference({("dnn(4)", "bww"): 10.33})
    w = co_sched_wcet(dnn, taskset, intf)
    print(f"co-scheduled DNN WCET would be {w:.1f}ms vs period 17ms -> "
          f"unschedulable; RT-Gang keeps the solo 7.6ms")

    # virtual gang: two single-threaded sensor tasks linked at one priority
    cam = RTTask("camera", wcet=3.0, period=20, cores=(0, 1), prio=0)
    lidar = RTTask("lidar", wcet=4.0, period=20, cores=(2,), prio=0)
    vg = make_virtual_gang("sensors", [cam, lidar], prio=3, mem_budget=50e6)
    full = [dnn, bww] + vg
    print("with virtual gang 'sensors' @prio 3:")
    for name, r in schedulable(full).items():
        print(f"  {name}: WCRT={r['wcrt']:.2f} ok={r['ok']}")

    # dt=None: the exact event-driven engine — no quantization, O(events)
    sim = Simulator(4, full, interference=intf, rt_gang_enabled=True,
                    dt=None)
    out = sim.run(200.0)
    print("simulated WCRTs:", {k: round(max(v), 2)
                               for k, v in out.response_times.items() if v})
    print("deadline misses:", out.deadline_misses,
          f"({out.events} events)")


def sweep(config_path=None):
    """Monte-Carlo schedulability sweep. With ``config_path`` the sweep
    is parameterized by a declarative ExperimentConfig (kind "sweep",
    DESIGN.md §14) instead of the built-in example axes."""
    from repro.launch.sweep import schedulability_sweep
    if config_path:
        from repro.experiment import ExperimentConfig
        cfg = ExperimentConfig.load(config_path)
        if cfg.kind != "sweep":
            raise SystemExit(
                f"{config_path}: kind {cfg.kind!r} != 'sweep'")
        res = schedulability_sweep(
            n_cores=cfg.taskset.cores[0], n_tasks=cfg.taskset.n_tasks,
            utils=cfg.taskset.utils, n_per_util=cfg.taskset.n_per_point,
            cycles=cfg.engine.cycles,
            processes=cfg.engine.processes or None,
            seed=cfg.taskset.seed, scalar_rta=cfg.engine.scalar_rta,
            config=cfg)
        header = (f"\nMonte-Carlo schedulability "
                  f"(config {res['config_digest'][:12]}, "
                  f"{cfg.taskset.cores[0]} cores, "
                  f"{res['processes']} processes):")
    else:
        res = schedulability_sweep(n_cores=4, n_tasks=4,
                                   utils=(0.5, 0.7, 0.9), n_per_util=25)
        header = ("\nMonte-Carlo schedulability (4 cores, 4 gangs, 25 "
                  f"tasksets per point, {res['processes']} processes):")
    print(header)
    for row in res["rows"]:
        print(f"  util={row['util']:.2f}: simulated "
              f"{row['sim_sched_ratio']:.0%} schedulable, RTA admits "
              f"{row['rta_sched_ratio']:.0%}")


def vgang_curves(out_dir=None):
    """Plotting hook for the virtual-gang grid (repro.vgang.grid):
    acceptance ratio vs utilization, one curve per formation heuristic
    with the RT-Gang singleton baseline."""
    from repro.launch.sweep import ROOT
    out_dir = out_dir or os.path.join(ROOT, "results", "vgang")
    files = sorted(glob.glob(os.path.join(out_dir, "grid_*.json")))
    if not files:
        print(f"no grid files under {out_dir}; run "
              "`PYTHONPATH=src python -m repro.vgang.grid` first")
        return
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        plt = None
    from repro.vgang.grid import print_curves
    for path in files:
        with open(path) as f:
            data = json.load(f)
        rows = sorted(data["rows"], key=lambda r: r["util"])
        heuristics = list(rows[0]["accept"])
        print_curves(rows)
        if plt is not None:
            fig, ax = plt.subplots(figsize=(5, 3.2))
            for h in heuristics:
                ax.plot([r["util"] for r in rows],
                        [r["accept"][h] for r in rows],
                        marker="o", label=h)
            ax.set_xlabel("total gang utilization (single-core equiv.)")
            ax.set_ylabel("acceptance ratio")
            ax.set_title(f"{data['n_cores']} cores, {data['dist']} widths")
            ax.set_ylim(-0.05, 1.05)
            ax.legend(fontsize=7)
            fig.tight_layout()
            png = path.replace(".json", ".png")
            fig.savefig(png, dpi=150)
            plt.close(fig)
            print(f"  -> {png}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--config", metavar="PATH",
                    help="ExperimentConfig JSON (kind 'sweep') "
                         "parameterizing the --sweep section; implies "
                         "--sweep")
    ap.add_argument("--vgang", action="store_true",
                    help="plot acceptance curves from results/vgang")
    args = ap.parse_args()
    main()
    if args.sweep or args.config:
        sweep(args.config)
    if args.vgang:
        vgang_curves()
