"""Trip-count-aware HLO cost analysis from post-partitioning HLO text.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE, so any
scan-over-layers model under-reports FLOPs/bytes by ~n_layers. This module
re-derives the three roofline inputs from ``compiled.as_text()``:

* ``flops``  — dot/convolution FLOPs per computation, multiplied through the
  call graph (while bodies get their trip count, parsed from the loop
  condition's comparison constant; nested scans multiply; fusions/calls
  inherit the caller's multiplier).
* ``bytes``  — fusion-boundary traffic: every top-level op in a non-fused
  computation reads its operands and writes its output once; ops inside
  fused computations are not materialized and are skipped.
* ``collective_bytes`` — per-primitive output-shape bytes (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute).

Operand shapes are resolved via a per-computation symbol table (the CPU
backend prints bare ``%name`` operand references). Validated in
tests/test_roofline.py against hand-computable cases.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1, "token": 0, "opaque": 0}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions: newer
    releases return one properties dict, older ones a 1-element list of
    dicts (one per partition). Returns the (first) dict, or {} if the
    backend reports nothing."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes appearing in an HLO type signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(sig: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(sig)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_sig: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]            # param name -> sig
    ops: List[Op]
    symbols: Dict[str, str]           # op name -> out sig
    is_fused: bool


_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\((?P<params>.*)\)\s*->\s*(?P<ret>.+?)\s*\{\s*$")
# out_sig may be a (nested) tuple type: match lazily up to " kind(".
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                name = m.group(1).lstrip("%")
                params = {pm.group(1): pm.group(2)
                          for pm in _PARAM_RE.finditer(m.group("params"))}
                cur = Computation(name=name, params=params, ops=[],
                                  symbols=dict(params),
                                  is_fused="fused" in name)
                comps[name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_sig, kind = m.group(1), m.group(2).strip(), m.group(3)
        # operand names: inside the first (...) after the op kind,
        # up to the matching close paren (approx: stop at "), ")
        idx = line.find(kind + "(")
        operand_str = line[idx + len(kind) + 1:] if idx >= 0 else ""
        # cut at the paren that closes the operand list
        depth = 1
        end = 0
        for i, ch in enumerate(operand_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = operand_str[:end]
        operands = _OPERAND_RE.findall(operand_str)
        op = Op(name=name, kind=kind, out_sig=out_sig, operands=operands,
                line=line)
        cur.ops.append(op)
        cur.symbols[name] = out_sig
    return comps


_SINGLE_ROLE_RE = {
    role: re.compile(role + r"=%?([\w\.\-]+)")
    for role in ("body", "condition", "calls", "to_apply")}
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_comps(op: Op) -> List[Tuple[str, str]]:
    out = []
    for role, rx in _SINGLE_ROLE_RE.items():
        m = rx.search(op.line)
        if m:
            out.append((role, m.group(1)))
    m = _BRANCH_RE.search(op.line)
    if m:
        for nm in m.group(1).split(","):
            nm = nm.strip().lstrip("%")
            if nm:
                out.append(("branch", nm))
    return out


def _while_trip_count(cond: Computation) -> int:
    consts = []
    for op in cond.ops:
        m = re.search(r"constant\((\d+)\)", op.line)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _dot_flops(op: Op, symbols: Dict[str, str]) -> int:
    out_dims = _first_shape_dims(op.out_sig)
    if out_dims is None or not op.operands:
        return 0
    lhs_sig = symbols.get(op.operands[0], "")
    lhs_dims = _first_shape_dims(lhs_sig) or []
    m = re.search(r"lhs_contracting_dims=\{([0-9, ]*)\}", op.line)
    contracted = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            idx = idx.strip()
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    return 2 * out_elems * contracted


def _conv_flops(op: Op, symbols: Dict[str, str]) -> int:
    out_dims = _first_shape_dims(op.out_sig)
    if out_dims is None or len(op.operands) < 2:
        return 0
    k_dims = _first_shape_dims(symbols.get(op.operands[1], "")) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    kernel = 1
    for d in k_dims[:-1]:
        kernel *= d
    return 2 * out_elems * kernel


# Ops that *materialize* HBM traffic on TPU. Everything else (elementwise,
# broadcast, convert, compare, select, ...) fuses into a neighbor on the TPU
# backend; XLA:CPU additionally rewrites bf16 GEMMs as convert-to-f32 + f32
# dot, which must not be charged as real traffic (TPU MXUs read bf16
# natively) — hence operand resolution through converts below.
_MATERIALIZING_KINDS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "sort", "concatenate",
    "pad", "copy", "transpose", "custom-call", "rng", "rng-bit-generator",
    "cholesky", "triangular-solve", "fft", "select-and-scatter",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}


def analyze(hlo: str, *, compute_dtype_bytes: int = 0) -> Dict[str, object]:
    """``compute_dtype_bytes``: if nonzero (e.g. 2 for bf16 models), f32
    collective payloads are charged at this width — XLA:CPU's bf16->f32 dot
    rewrite makes psums of matmul outputs f32 here, while the TPU backend
    keeps them in the compute dtype."""
    comps = parse_computations(hlo)

    def coll_sig_bytes(sig: str) -> int:
        if not compute_dtype_bytes:
            return shape_bytes(sig)
        total = 0
        for dt, dims in _SHAPE_RE.findall(sig):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            width = _DTYPE_BYTES[dt]
            if dt == "f32":
                width = min(width, compute_dtype_bytes)
            total += n * width
        return total

    callees = set()
    for c in comps.values():
        for op in c.ops:
            for _, nm in _called_comps(op):
                callees.add(nm)
    entries = [n for n in comps if n not in callees]

    mult: Dict[str, float] = {}
    loop_depth: Dict[str, int] = {}
    work: List[Tuple[str, float, int]] = [(n, 1.0, 0) for n in entries]
    # propagate multipliers through the call graph (DAG in valid HLO);
    # track while-nest depth: depth>=2 computations are inner loops of a
    # scanned layer (flash-attention kv/q scans, SSD chunk scans) — the
    # traffic the Pallas kernels keep in VMEM on TPU.
    while work:
        name, m, d = work.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        mult[name] = mult.get(name, 0.0) + m
        loop_depth[name] = max(loop_depth.get(name, 0), d)
        for op in comp.ops:
            called = _called_comps(op)
            trip = 1
            cond_name = next((nm for r, nm in called if r == "condition"),
                             None)
            if cond_name and cond_name in comps:
                trip = _while_trip_count(comps[cond_name])
            for role, nm in called:
                if nm not in comps:
                    continue
                if role == "body":
                    work.append((nm, m * trip, d + 1))
                else:
                    work.append((nm, m, d))

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = {c: 0.0 for c in COLLECTIVES}
    coll_counts = {c: 0.0 for c in COLLECTIVES}
    coll_items: List[Tuple[float, str, float, str]] = []
    bytes_items: List[Tuple[float, str, float, str]] = []

    bytes_inner = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        is_inner = loop_depth.get(name, 0) >= 2
        producers = {op.name: op for op in comp.ops}

        def _elems(sig: str) -> int:
            d = _first_shape_dims(sig)
            if d is None:
                return -1
            n = 1
            for x in d:
                n *= x
            return n

        def _is_convert_like(op: Op) -> Optional[str]:
            """If op is a dtype-convert (or a fusion that merely converts /
            slices-and-converts a larger-dtype view of one operand), return
            that operand's name."""
            if op.kind == "convert" and op.operands:
                return op.operands[0]
            if op.kind == "fusion" and op.operands:
                out_n = _elems(op.out_sig)
                for o in op.operands:
                    sig = comp.symbols.get(o, "")
                    if sig and _elems(sig) == out_n and \
                            shape_bytes(sig) < shape_bytes(op.out_sig):
                        return o
            return None

        def through_convert(opnd_name: str) -> str:
            """Resolve an operand through CPU-inserted bf16->f32 converts
            (bare or fused) to the original buffer's signature — TPU MXUs
            read bf16 directly, so the f32 copies are CPU artifacts."""
            seen = 0
            cur = opnd_name
            while seen < 4:
                prod = producers.get(cur)
                if prod is None:
                    break
                nxt = _is_convert_like(prod)
                if nxt is None:
                    break
                cur = nxt
                seen += 1
            return comp.symbols.get(cur, comp.symbols.get(opnd_name, ""))

        carried = {op.name for op in comp.ops
                   if op.kind in ("parameter", "get-tuple-element")}

        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, comp.symbols)
            elif op.kind == "convolution":
                flops += m * _conv_flops(op, comp.symbols)
            kind_base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if kind_base in COLLECTIVES and not op.kind.endswith("-done"):
                # charge at the *logical* dtype (see compute_dtype_bytes)
                b = m * coll_sig_bytes(op.out_sig)
                coll_bytes[kind_base] += b
                coll_counts[kind_base] += m
                coll_items.append((b, kind_base, m,
                                   op.out_sig[:90] + f"  [{name[:40]}]"))
            if not comp.is_fused and op.kind in _MATERIALIZING_KINDS \
                    and not op.kind.endswith("-done"):
                # HBM-traffic proxy: every materializing op writes its output
                # and a consumer reads it (2x out). GEMMs additionally read
                # their operands (weights/activations), resolved through
                # CPU-inserted bf16->f32 converts. Fusion operands are NOT
                # charged (fusions read slices; out_sig reflects the slice).
                # Special cases:
                # * convert-like fusions are CPU dtype artifacts: skip.
                # * in-place updates of loop-carried state (DUS pattern:
                #   output shape == a carried operand's shape): charge the
                #   delta (other operands), not the whole buffer.
                if _is_convert_like(op) is not None and op.kind == "fusion":
                    continue
                inplace_src = None
                if op.kind in ("fusion", "dynamic-update-slice"):
                    for o in op.operands:
                        if o in carried and \
                                comp.symbols.get(o, "") == op.out_sig:
                            inplace_src = o
                            break
                if inplace_src is not None:
                    delta = sum(shape_bytes(through_convert(o))
                                for o in op.operands if o != inplace_src)
                    b = m * 2 * delta
                elif op.kind in ("dot", "convolution"):
                    b = m * (2 * shape_bytes(op.out_sig)
                             + sum(shape_bytes(through_convert(o))
                                   for o in op.operands))
                else:
                    b = m * 2 * shape_bytes(op.out_sig)
                bytes_accessed += b
                if is_inner:
                    bytes_inner += b
                if b > 0:
                    bytes_items.append((b, op.kind, m,
                                        op.out_sig[:80] + f" [{name[:40]}]"))

    coll_items.sort(reverse=True)
    top = [{"bytes": b, "kind": k, "mult": m, "sig": s}
           for b, k, m, s in coll_items[:20]]
    bytes_items.sort(reverse=True)
    top_bytes = [{"bytes": b, "kind": k, "mult": m, "sig": s}
                 for b, k, m, s in bytes_items[:25]]

    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "bytes_inner_loops": bytes_inner,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total": float(sum(coll_bytes.values())),
        "top_collectives": top,
        "top_bytes_ops": top_bytes,
        "computations": len(comps),
        "entry_count": len(entries),
    }
