"""Roofline terms from dry-run cells.

Hardware model (TPU v5e, per brief): 197 TFLOP/s bf16 per chip; 819 GB/s HBM;
~50 GB/s/link ICI.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per training step;
inference steps use 2*N*D_new (+ attention KV reads are in the memory term).
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12           # bf16 / chip
HBM_BW = 819e9                # bytes/s / chip
ICI_BW = 50e9                 # bytes/s/link (conservative single-link)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one new token per sequence
    "long_500k": 1,
}


def model_flops(cell: Dict) -> float:
    """Useful FLOPs per device for the cell (training: 6*N*D; inference
    forward-only: 2*N*D)."""
    n = cell.get("n_active_params") or cell.get("n_params")
    tokens = SHAPE_TOKENS[cell["shape"]]
    mult = 6 if cell["shape"].startswith("train") else 2
    chips = 512 if cell["mesh"].startswith("pod") else 256
    return mult * n * tokens / chips


def roofline_row(cell: Dict) -> Dict:
    f = cell["flops_per_device"]
    b = cell["bytes_per_device"]
    b_inner = cell.get("bytes_inner_loops_per_device", 0.0)
    # ring all-reduce moves ~2x the payload per link (reduce-scatter +
    # all-gather phases); AG/RS/A2A move ~1x.
    by_type = cell["collectives_per_device"]["bytes_by_type"]
    c = (cell["collectives_per_device"]["total_bytes"]
         + by_type.get("all-reduce", 0.0))
    t_compute = f / PEAK_FLOPS
    t_memory = b / HBM_BW
    # kernel-adjusted memory term: inner-loop (depth>=2 scan) traffic is what
    # the Pallas kernels keep in VMEM on TPU (flash attention / SSD chunk
    # scans); subtracting it bounds the memory term with kernels deployed.
    t_memory_k = max(b - b_inner, 0.0) / HBM_BW
    t_collective = c / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory_k,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell)
    bound = max(terms.values())
    bound_nok = max(t_compute, t_memory, t_collective)
    roofline_frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    roofline_frac_nok = (mf / PEAK_FLOPS) / bound_nok if bound_nok > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "variant": cell.get("variant", "baseline"),
        "compute_s": round(t_compute, 4),
        "memory_s": round(t_memory, 4),
        "memory_s_kernel": round(t_memory_k, 4),
        "collective_s": round(t_collective, 4),
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": f,
        "useful_flops_ratio": round(mf / f, 3) if f else 0.0,
        "roofline_fraction": round(roofline_frac, 4),
        "roofline_fraction_xla_only": round(roofline_frac_nok, 4),
        "mem_args_gb": round(cell["memory"].get("argument_size_bytes", 0)
                             / 2**30, 2),
        "mem_temp_gb": round(cell["memory"].get("temp_size_bytes", 0)
                             / 2**30, 2),
        "compile_s": cell.get("compile_s"),
    }


def markdown_table(rows) -> str:
    if not rows:
        return "(no dry-run cells found)"
    cols = ["arch", "shape", "mesh", "variant", "compute_s", "memory_s",
            "memory_s_kernel", "collective_s", "dominant",
            "useful_flops_ratio", "roofline_fraction", "mem_args_gb",
            "mem_temp_gb"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)
