"""jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_bc


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan(log_a, b, *, chunk: int = 256, interpret: bool | None = None):
    """log_a, b: (B, S, C) -> (B, S, C) recurrence outputs."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rglru_scan_bc(log_a, b, chunk=chunk, interpret=interpret)
