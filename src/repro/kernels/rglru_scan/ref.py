"""Oracle for the RG-LRU recurrence kernel: plain lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_reference(log_a, b):
    """log_a, b: (B, S, C) -> h_all (B, S, C); h_0 = 0."""
    a = jnp.exp(log_a.astype(jnp.float32))
    bb = b.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    def per_b(ab, bbb):
        h0 = jnp.zeros((ab.shape[-1],), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (ab, bbb))
        return ys

    return jax.vmap(per_b)(a, bb).astype(log_a.dtype)
