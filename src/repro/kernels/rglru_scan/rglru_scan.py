"""Pallas TPU kernel for the RG-LRU linear recurrence (RecurrentGemma).

h_t = a_t * h_{t-1} + b_t   per channel, with a_t in (0,1) given in log space.

TPU-native blocking: per (batch, channel-block), the sequence is processed in
chunks held in VMEM; within a chunk the recurrence is materialized as a
lower-triangular decay matrix product (MXU) instead of a sequential loop:

    h_i = exp(cum_i) * h0 + sum_{j<=i} exp(cum_i - cum_j) * b_j
        = exp(cum_i) * h0 + (tril(exp(cum_i - cum_j)) @ b)_i

The carry h (1, channel-block) persists in VMEM scratch across chunks
(sequential grid dim). This replaces jax.lax.associative_scan (O(S log S)
work on XLA) with O(S*Q) MXU work and one HBM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _rglru_kernel(loga_ref, b_ref, y_ref, h_scr, *, chunk: int,
                  n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = jnp.exp(loga_ref[0].astype(jnp.float32))   # (Q, C), in (0,1)
    b = b_ref[0].astype(jnp.float32)               # (Q, C)

    # exact sequential recurrence over the VMEM-resident chunk (VPU work;
    # the HBM win is the single chunked pass + persistent carry). A masked
    # exp(cum_i - cum_j) matrix form is possible but can overflow for long
    # chunks under strong decay, so we keep the exact loop.
    def step(t, carry):
        h, ys = carry
        h = a[t] * h + b[t]
        return h, jax.lax.dynamic_update_slice(ys, h[None], (t, 0))

    h0 = h_scr[0]
    h_last, ys = jax.lax.fori_loop(
        0, chunk, step, (h0, jnp.zeros((chunk, b.shape[1]), jnp.float32)))
    h_scr[0] = h_last
    y_ref[0] = ys.astype(y_ref.dtype)


def rglru_scan_bc(log_a, b, *, chunk: int = 256, interpret: bool = True):
    """log_a, b: (B, S, C) -> h_all: (B, S, C). Carry chunk-sequential."""
    B, S, C = log_a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, C), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, C), lambda b_, ci: (b_, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, C), lambda b_, ci: (b_, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), log_a.dtype),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b)
