"""Pallas TPU flash-attention forward kernel (causal / local-window, GQA).

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks) with the kv dimension
sequential ("arbitrary") so the online-softmax state (m, l, acc) lives in
VMEM scratch across kv steps. Fully-masked blocks are skipped with pl.when,
so causal FLOPs track the triangle. GQA is handled in the k/v BlockSpec
index maps (kv head = q head // group). Layout: (B*H, S, D) per operand with
block (1, block_q, head_dim) — head_dim is the lane dimension (128-aligned
for the assigned architectures).

Validated against ``ref.naive_attention`` in interpret mode on CPU
(tests/test_kernels.py sweeps shapes, dtypes, window sizes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, n_kv_blocks: int,
                  causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    needed = jnp.asarray(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window > 0:
        needed &= k_start + block_k - 1 > q_start - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         group: int = 1, interpret: bool = True):
    """q: (B*Hq, Sq, D); k, v: (B*Hkv, Sk, D); group = Hq // Hkv per batch
    element. ``q`` rows are ordered (b, h); kv row for q row i is
    (i // (Hkv*group)) * Hkv + (i % (Hkv*group)) // group.
    """
    BHq, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = D ** -0.5
    assert BHq == BHkv * group, (BHq, BHkv, group)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    # q rows are (b, h)-ordered with h = 0..Hq-1 and Hq = Hkv*group, so the
    # kv row for q row bh is exactly bh // group.
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        causal=causal, window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(BHq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_index),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
