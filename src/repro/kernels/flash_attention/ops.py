"""jit'd public wrapper for the flash-attention Pallas kernel.

Accepts the model-layer layout (B, S, H, D); transposes to the kernel's
(B*H, S, D) layout; handles GQA via the kernel's index-map grouping.
``interpret`` defaults to True off-TPU (CPU validation) and False on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    if interpret is None:
        interpret = _default_interpret()
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, group=G,
                               interpret=interpret)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
