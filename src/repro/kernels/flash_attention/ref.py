"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def naive_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D).
    Materialized-scores reference; fp32 softmax."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
