"""Pallas TPU grouped (per-expert) matmul kernel for MoE.

Computes out[e] = x[e] @ w[e] for E experts with per-expert valid row counts
(capacity buffers are padded): blocks whose row range is entirely beyond the
expert's count are skipped with pl.when, so padded capacity costs no MXU
work — the Pallas analogue of a ragged GEMM (dropless MoE on TPU).

Grid: (E, C/block_c, F/block_f, D/block_d); the contraction dim is the
innermost sequential axis accumulating into a VMEM scratch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _gmm_kernel(cnt_ref, x_ref, w_ref, o_ref, acc_scr, *, block_c: int,
                block_d: int, n_d: int):
    e = pl.program_id(0)
    ci = pl.program_id(1)
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    count = cnt_ref[0]
    row_start = ci * block_c

    @pl.when(row_start < count)
    def _compute():
        x = x_ref[0]                       # (block_c, block_d)
        w = w_ref[0]                       # (block_d, block_f)
        acc_scr[...] += jax.lax.dot(x, w,
                                    preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _emit():
        rows = row_start + jax.lax.broadcasted_iota(
            jnp.int32, acc_scr.shape, 0)
        valid = rows < count
        o_ref[0] = jnp.where(valid, acc_scr[...], 0.0).astype(o_ref.dtype)


def moe_gmm(x, w, counts, *, block_c: int = 128, block_f: int = 128,
            block_d: int = 128, interpret: bool = True):
    """x: (E, C, D); w: (E, D, F); counts: (E,) int32 -> out (E, C, F).

    Rows >= counts[e] are treated as padding (zeroed in the output and
    skipped by whole blocks where possible).
    """
    E, C, D = x.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    nc, nf, nd = C // block_c, F // block_f, D // block_d

    kernel = functools.partial(_gmm_kernel, block_c=block_c, block_d=block_d,
                               n_d=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1,), lambda e, ci, fi, di: (e,)),
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(counts, x, w)
