"""Oracle for the grouped matmul: masked batched einsum."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(x, w, counts):
    """x: (E, C, D); w: (E, D, F); counts: (E,) -> (E, C, F) with rows >=
    counts[e] zeroed."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    rows = jnp.arange(x.shape[1])[None, :, None]
    valid = rows < counts[:, None, None]
    return jnp.where(valid, out, 0.0).astype(x.dtype)
