"""jit'd wrapper for the grouped-matmul kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.moe_gmm.moe_gmm import moe_gmm as _moe_gmm


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                   "interpret"))
def grouped_matmul(x, w, counts, *, block_c: int = 128, block_f: int = 128,
                   block_d: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _moe_gmm(x, w, counts, block_c=block_c, block_f=block_f,
                    block_d=block_d, interpret=interpret)
