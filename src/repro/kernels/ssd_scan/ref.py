"""Sequential-scan oracle for the SSD kernel (and for mamba2's chunked jnp
path): the literal recurrence, one token at a time."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, Bm, Cm, A):
    """x: (BH, S, P); dt: (BH, S, 1); Bm, Cm: (BH, S, N); A: (BH, 1).
    Returns (y: (BH, S, P), h_final: (BH, P, N))."""
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def per_bh(xb, dtb, bb, cb, ab):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            da = jnp.exp(dtt[0] * ab[0])
            h = da * h + dtt[0] * jnp.outer(xt, bt)
            y = h @ ct
            return h, y

        h0 = jnp.zeros((P, N), jnp.float32)
        h, ys = jax.lax.scan(step, h0, (xb.astype(jnp.float32),
                                        dtb.astype(jnp.float32),
                                        bb.astype(jnp.float32),
                                        cb.astype(jnp.float32)))
        return ys, h

    y, h = jax.vmap(per_bh)(x, dt, Bm, Cm, A)
    return y.astype(x.dtype), h
