"""jit'd wrapper: model layout (B, S, H, P) + shared B/C -> kernel layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_bh


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh, dt, Bm, Cm, A, *, chunk: int = 128,
             interpret: bool | None = None):
    """xh: (B, S, H, P); dt: (B, S, H); Bm, Cm: (B, S, N) (shared across
    heads); A: (H,). Returns (y: (B, S, H, P), h: (B, H, P, N))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    x2 = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dt2 = dt.transpose(0, 2, 1).reshape(B * H, S, 1)
    Bm2 = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cm2 = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    A2 = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1)
    y, h = ssd_scan_bh(x2, dt2, Bm2, Cm2, A2, chunk=chunk,
                       interpret=interpret)
    return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
            h.reshape(B, H, P, N))
