"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Per (batch, head): h_t = exp(A*dt_t) h_{t-1} + dt_t B_t (x) x_t;
y_t = C_t . h_t. Grid: (B*H, n_chunks) with the chunk dimension sequential —
the inter-chunk state (P, N) lives in VMEM scratch. Within a chunk the
intra-chunk quadratic form runs on the MXU:

    y_intra = (tril(exp(Lc_i - Lc_j)) * (C B^T) * dt_j) @ x
    y_inter = exp(Lc) * (C @ h_prev^T)
    h_new   = exp(Ltot) h_prev + ((exp(Ltot - Lc) * dt) B)^T @ x

This is the TPU-native blocking of the SSD algorithm (HBM->VMEM chunk
streaming; MXU for the two (Q,Q)/(Q,N) matmuls), replacing the GPU paper's
warp-level implementation. Validated against ref.ssd_reference (sequential
scan oracle) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    A = a_ref[0, 0]                           # scalar (per head)

    logd = dt[:, 0] * A                       # (Q,)
    Lc = jnp.cumsum(logd)                     # (Q,)
    Ltot = Lc[-1]

    # intra-chunk
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    diff = Lc[:, None] - Lc[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(iq >= jq, jnp.exp(diff), 0.0) * CB * dt[:, 0][None, :]
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)     # (Q,P)

    # inter-chunk: y += exp(Lc) * C @ h_prev^T   (h: (P,N))
    h_prev = h_scr[...]
    y += jnp.exp(Lc)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h_new = exp(Ltot) h_prev + x^T @ (exp(Ltot-Lc)*dt*B)
    w = (jnp.exp(Ltot - Lc) * dt[:, 0])[:, None] * Bm               # (Q,N)
    h_scr[...] = jnp.exp(Ltot) * h_prev + jax.lax.dot_general(
        x, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


def ssd_scan_bh(x, dt, Bm, Cm, A, *, chunk: int = 128,
                interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S, 1); Bm, Cm: (BH, S, N); A: (BH, 1).

    Returns (y: (BH, S, P), h_final: (BH, P, N)). fp32 recommended.
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, Bm, Cm, A)
    return y, h
