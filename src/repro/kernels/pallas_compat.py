"""Version gate for the Pallas TPU compiler-params API rename.

jax >= 0.7 exposes ``pltpu.CompilerParams``; 0.4.x-0.6.x call the same
dataclass ``pltpu.TPUCompilerParams`` (and some early versions only accept
``dimension_semantics`` via ``mosaic`` params). All four kernels import
``CompilerParams`` from here so they run under either API.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
