"""Serving launcher: gang-scheduled serving of a latency-critical model with
best-effort background work — the paper's deployment story end-to-end.

``python -m repro.launch.serve --arch qwen2-7b --requests 6``

The decode step of the served model is the RT gang (priority 10); a
background batch job (synthetic compute) is best-effort, throttled by the
gang's byte budget. Compare p99 decode latency with --no-gang.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-gang", action="store_true")
    ap.add_argument("--duration", type=float, default=6.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    mesh = make_local_mesh(1, 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=64, kv_block=64)
    api = build_model(cfg, parallel, mesh)
    params = api.init(jax.random.key(0))
    engine = ServingEngine(api, params, max_batch=4, max_seq=256)
    engine.warmup(prompt_len=32)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(32,))
                    .astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    pending = list(reqs)

    # best-effort background job: memory-heavy matmul batches
    bg = jax.jit(lambda x: (x @ x.T).sum())
    bg_arr = jnp.ones((512, 512), jnp.float32)

    ex = GangExecutor(n_lanes=2, enabled=not args.no_gang,
                      regulation_interval_s=0.02)

    def decode_quantum(lane, idx):
        while pending and engine.add_request(pending[0]):
            pending.pop(0)
        engine.decode_step()

    ex.submit_rt(RTJob(name="decode", fn=decode_quantum, lanes=(0,),
                       prio=10, period_s=0.01, budget_bytes=2e6,
                       n_jobs=int(args.duration / 0.01)))
    ex.submit_be(BEJob(name="bg-batch", fn=lambda lane: float(bg(bg_arr)),
                       lanes=(0, 1), bytes_per_quantum=1e6))

    stats = ex.run(args.duration)
    lat = np.array(stats["response_times"].get("decode", [0.0])) * 1e3
    done = sum(r.done for r in reqs)
    print(f"[serve] gang={'off' if args.no_gang else 'on'} "
          f"requests done {done}/{len(reqs)} decode_steps={engine.decode_steps}")
    if len(lat):
        print(f"[serve] decode quantum latency ms: "
              f"p50={np.percentile(lat, 50):.2f} "
              f"p99={np.percentile(lat, 99):.2f} max={lat.max():.2f}")
    print(f"[serve] best-effort quanta: {stats['be_quanta']}")


if __name__ == "__main__":
    main()
