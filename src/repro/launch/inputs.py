"""ShapeDtypeStruct stand-ins for every model input + KV cache per
(architecture x shape) — weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.model import ModelApi
from repro.models.whisper import MAX_DECODER_POS


def _sds(shape, dtype, api: ModelApi, logical):
    sharding = api.rules_a.sharding(logical, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(api: ModelApi, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for train (kind=train) or prefill (kind=prefill)."""
    cfg = api.cfg
    B, S = shape.global_batch, shape.seq_len
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out: Dict[str, Any] = {
        "tokens": _sds((B, S), jnp.int32, api, ("batch", None)),
    }
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32, api, ("batch", None))
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.n_vision_tokens, cfg.d_model), cd, api,
                              ("batch", None, None))
    if cfg.family == "audio":
        out["frames"] = _sds((B, cfg.n_encoder_frames, cfg.d_model), cd, api,
                             ("batch", None, None))
    return out


def decode_token_specs(api: ModelApi, shape: ShapeConfig):
    B = shape.global_batch
    return (_sds((B, 1), jnp.int32, api, ("batch", None)),
            _sds((B,), jnp.int32, api, ("batch",)))


def _attn_cache_specs(api: ModelApi, n_layers: int, B: int, S: int):
    cfg = api.cfg
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    lg = (None, "batch", "kv_seq", None, None)
    shp = (n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": _sds(shp, cd, api, lg), "v": _sds(shp, cd, api, lg)}


def cache_specs(api: ModelApi, shape: ShapeConfig) -> Any:
    """KV/state cache pytree matching ``ModelApi.decode_fn``'s structure."""
    cfg = api.cfg
    B, S = shape.global_batch, shape.seq_len
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        return _attn_cache_specs(api, cfg.n_layers, B, S)

    if fam == "ssm":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        H = s.n_heads(cfg.d_model)
        N, P_, W = s.state_dim, s.head_dim, s.conv_width
        Lr = cfg.n_layers
        return {
            "conv_x": _sds((Lr, B, W - 1, di), cd, api,
                           (None, "batch", None, "ssm_inner")),
            "conv_B": _sds((Lr, B, W - 1, N), cd, api,
                           (None, "batch", None, None)),
            "conv_C": _sds((Lr, B, W - 1, N), cd, api,
                           (None, "batch", None, None)),
            "h": _sds((Lr, B, H, P_, N), jnp.float32, api,
                      (None, "batch", "ssm_heads", None, None)),
        }

    if fam == "hybrid":
        g = cfg.rglru
        Wd = g.lru_width or cfg.d_model
        plen = len(g.pattern)
        n_groups, tail = divmod(cfg.n_layers, plen)

        def rec_cache(n):
            return {
                "conv": _sds((n, B, g.conv_width - 1, Wd), cd, api,
                             (None, "batch", None, "lru")),
                "h": _sds((n, B, Wd), jnp.float32, api,
                          (None, "batch", "lru")),
            }

        groups: Dict[str, Any] = {}
        for i, kind in enumerate(g.pattern):
            key = f"{kind}{i}"
            if kind == "rec":
                groups[key] = rec_cache(n_groups)
            else:
                groups[key] = _attn_cache_specs(api, n_groups, B, S)
        out = {"groups": groups}
        if tail:
            out["tail"] = rec_cache(tail)
        return out

    if fam == "audio":
        L = cfg.n_layers
        F = cfg.n_encoder_frames
        lg = (None, "batch", None, None, None)
        return {
            "self": _attn_cache_specs(api, L, B, min(S, MAX_DECODER_POS)),
            "cross_k": _sds((L, B, F, cfg.n_kv_heads, cfg.head_dim), cd, api, lg),
            "cross_v": _sds((L, B, F, cfg.n_kv_heads, cfg.head_dim), cd, api, lg),
        }
    raise ValueError(fam)
