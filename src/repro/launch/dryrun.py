import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init). Do not move them.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, get_shape, valid_cells  # noqa: E402
from repro.launch import inputs as I  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.recipes import parallel_for  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.roofline.hlo_analysis import (analyze as analyze_hlo,  # noqa: E402
                                          xla_cost_analysis)
from repro.training.optimizer import OptConfig, Optimizer  # noqa: E402
from repro.training.step import make_train_step, make_train_state, \
    state_pspecs  # noqa: E402


def sds_tree(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _params_specs_tree(api, mesh):
    shapes = api.param_shapes()
    specs = api.param_pspecs()
    return sds_tree(shapes, specs, mesh)


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             overrides: Dict[str, Any] | None = None,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    parallel = parallel_for(cfg, shape, multi_pod, **(overrides or {}))
    api = build_model(cfg, parallel, mesh)

    t0 = time.time()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_id,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "recipe": api.recipe,
        "n_params": api.n_params(),
        "n_active_params": cfg.n_active_params(),
    }

    with mesh:
        if shape.kind == "train":
            opt = Optimizer(OptConfig(name=parallel.optimizer,
                                      state_dtype=parallel.opt_state_dtype))
            step_fn = make_train_step(api, opt)
            state_shapes = jax.eval_shape(
                lambda: make_train_state(api, opt, jax.random.key(0)))
            st_specs = state_pspecs(api, opt)
            state_in = sds_tree(state_shapes, st_specs, mesh)
            batch_in = I.batch_specs(api, shape)
            lowered = jax.jit(step_fn, donate_argnums=(0,)).lower(
                state_in, batch_in)
        elif shape.kind == "prefill":
            params_in = _params_specs_tree(api, mesh)
            batch_in = I.batch_specs(api, shape)
            lowered = jax.jit(api.prefill_fn).lower(params_in, batch_in)
        else:  # decode
            params_in = _params_specs_tree(api, mesh)
            caches_in = I.cache_specs(api, shape)
            tok_in, pos_in = I.decode_token_specs(api, shape)
            lowered = jax.jit(api.decode_fn, donate_argnums=(1,)).lower(
                params_in, caches_in, tok_in, pos_in)

        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        try:
            result["memory"] = {
                "argument_size_bytes": int(mem.argument_size_in_bytes),
                "output_size_bytes": int(mem.output_size_in_bytes),
                "temp_size_bytes": int(mem.temp_size_in_bytes),
                "generated_code_size_bytes": int(
                    mem.generated_code_size_in_bytes),
                "alias_size_bytes": int(mem.alias_size_in_bytes),
            }
        except AttributeError:
            result["memory"] = {"repr": str(mem)}

        cost = xla_cost_analysis(compiled)
        # NOTE: XLA cost_analysis counts while (scan) bodies once; keep it for
        # reference but derive the roofline inputs from the trip-count-aware
        # HLO analyzer below.
        result["xla_cost_flops_unscaled"] = float(
            cost.get("flops", 0.0)) if cost else 0.0

        hlo = compiled.as_text()
        cd_bytes = 2 if parallel.compute_dtype == "bfloat16" else 0
        ana = analyze_hlo(hlo, compute_dtype_bytes=cd_bytes)
        result["flops_per_device"] = float(ana["flops"])
        result["bytes_per_device"] = float(ana["bytes"])
        result["bytes_inner_loops_per_device"] = float(
            ana.get("bytes_inner_loops", 0.0))
        result["collectives_per_device"] = {
            "bytes_by_type": ana["collective_bytes"],
            "counts": ana["collective_counts"],
            "total_bytes": ana["collective_total"],
        }
        result["top_collectives"] = ana.get("top_collectives", [])
        result["top_bytes_ops"] = ana.get("top_bytes_ops", [])
        result["hlo_bytes"] = len(hlo)

    print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="ParallelConfig overrides, e.g. fused_xent=True")
    args = ap.parse_args()

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = json.loads(v.lower() if v in ("True", "False")
                                      else v)
        except json.JSONDecodeError:
            overrides[k] = v

    res = run_cell(args.arch, args.shape, args.multi_pod, overrides,
                   args.variant)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
