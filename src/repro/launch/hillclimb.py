"""Perf hillclimb driver: run named variants (ParallelConfig overrides) of a
dry-run cell and print the roofline deltas vs baseline.

    python -m repro.launch.hillclimb --arch kimi-k2-1t-a32b --shape train_4k \
        --multi-pod --variant fused_xent fused_xent=true

Variants are cached as results/dryrun/<arch>_<shape>_<mesh>__<variant>.json.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.roofline.report import roofline_row

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT = os.path.join(ROOT, "results", "dryrun")


def run_variant(arch: str, shape: str, multi_pod: bool, variant: str,
                overrides: list[str], force: bool = False) -> dict:
    mesh = "pod2x16x16" if multi_pod else "16x16"
    suffix = "" if variant == "baseline" else f"__{variant}"
    path = os.path.join(OUT, f"{arch}_{shape}_{mesh}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", path, "--variant", variant]
    if multi_pod:
        cmd.append("--multi-pod")
    for ov in overrides:
        cmd += ["--override", ov]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    with open(path) as f:
        return json.load(f)


def compare(cells: list[dict]) -> None:
    base = roofline_row(cells[0])
    print(f"{'variant':<28} {'compute_s':>10} {'memory_s':>10} "
          f"{'collect_s':>10} {'dominant':>10} {'temp_gb':>8} {'roofline':>9}")
    for c in cells:
        r = roofline_row(c)
        print(f"{r['variant']:<28} {r['compute_s']:>10} {r['memory_s']:>10} "
              f"{r['collective_s']:>10} {r['dominant']:>10} "
              f"{r['mem_temp_gb']:>8} {r['roofline_fraction']:>9}")
    print("\ntop collectives (baseline):")
    for t in cells[0].get("top_collectives", [])[:8]:
        print(f"  {t['bytes']/2**30:8.2f} GiB  {t['kind']:<18} x{t['mult']:.0f}"
              f"  {t['sig']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", nargs="+", action="append", default=[],
                    metavar="NAME OVERRIDE...",
                    help="variant name followed by k=v overrides")
    args = ap.parse_args()

    cells = [run_variant(args.arch, args.shape, args.multi_pod, "baseline",
                         [], force=False)]
    for spec in args.variant:
        name, overrides = spec[0], spec[1:]
        cells.append(run_variant(args.arch, args.shape, args.multi_pod,
                                 name, overrides, force=args.force))
    compare(cells)


if __name__ == "__main__":
    main()
