"""Mesh construction for single-pod (16x16 = 256 chips) and multi-pod
(2 pods x 256 = 512 chips) deployments.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — crucial because ``dryrun.py`` must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
