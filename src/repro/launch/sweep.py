"""Dry-run sweep driver: every (arch x shape) cell on the single-pod mesh +
the multi-pod mesh, cached as results/dryrun/*.json. Each cell runs in a
fresh subprocess (jax pins the forced device count at first init).

    python -m repro.launch.sweep [--multi-pod-only] [--force] [--cells a:b]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import valid_cells

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT = os.path.join(ROOT, "results", "dryrun")


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "pod2x16x16" if multi_pod else "16x16"
    return os.path.join(OUT, f"{arch}_{shape}_{mesh}.json")


def run_one(arch: str, shape: str, multi_pod: bool, force: bool,
            timeout: int = 3600) -> dict:
    path = cell_path(arch, shape, multi_pod)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return {"cached": True, **json.load(f)}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", path]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=ROOT)
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:], "arch": arch, "shape": shape,
                "multi_pod": multi_pod, "wall_s": time.time() - t0}
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    runnable, skipped = valid_cells()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "skipped.json"), "w") as f:
        json.dump({f"{a}|{s}": r for (a, s), r in skipped.items()}, f,
                  indent=1)

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    failures = []
    for multi_pod in meshes:
        for arch, shape in runnable:
            t0 = time.time()
            res = run_one(arch, shape, multi_pod, args.force)
            tag = "pod2x16x16" if multi_pod else "16x16"
            if "error" in res:
                failures.append((arch, shape, tag))
                print(f"[FAIL] {arch} {shape} {tag}: {res['error'][-400:]}",
                      flush=True)
            else:
                cached = " (cached)" if res.get("cached") else ""
                print(f"[ok] {arch} {shape} {tag} compile={res['compile_s']}s"
                      f" wall={time.time()-t0:.0f}s{cached}", flush=True)
    print(f"\nSWEEP DONE failures={len(failures)}: {failures}")


if __name__ == "__main__":
    main()
