"""Sweep drivers.

1. Dry-run compile sweep: every (arch x shape) cell on the single-pod mesh
   + the multi-pod mesh, cached as results/dryrun/*.json. Each cell runs
   in a fresh subprocess (jax pins the forced device count at first init).

       python -m repro.launch.sweep [--multi-pod-only] [--force]

2. Monte-Carlo schedulability sweep: random gang tasksets per utilization
   level, simulated with the exact event-driven engine (Simulator dt=None)
   and cross-checked against RTA, fanned across worker processes — the
   evaluation style of the Virtual-Gang (arXiv:1912.10959) and strict-
   partitioning gang (arXiv:2403.10726) follow-ups.

       python -m repro.launch.sweep --schedulability \\
           [--utils 0.3,0.5,0.7,0.9] [--n 100] [--procs 8] [--cores 4] \\
           [--seed 0]

   Tasksets are batched into a few contiguous shards per utilization
   level (amortizing worker startup while still using every core);
   per-taskset seeds derive from --seed via ``taskset_seed``, so runs
   are reproducible and sharding-independent. The full virtual-gang
   evaluation grid (formation heuristics x width distributions x
   4/8/16 cores) extends this driver in ``repro.vgang.grid``.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import random
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import valid_cells

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT = os.path.join(ROOT, "results", "dryrun")


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "pod2x16x16" if multi_pod else "16x16"
    return os.path.join(OUT, f"{arch}_{shape}_{mesh}.json")


def run_one(arch: str, shape: str, multi_pod: bool, force: bool,
            timeout: int = 3600) -> dict:
    path = cell_path(arch, shape, multi_pod)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return {"cached": True, **json.load(f)}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", path]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout, cwd=ROOT)
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:], "arch": arch, "shape": shape,
                "multi_pod": multi_pod, "wall_s": time.time() - t0}
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------
# Monte-Carlo schedulability sweep (event-driven engine, process pool)
# ---------------------------------------------------------------------

def uunifast(rng: random.Random, n: int, total_util: float) -> List[float]:
    """UUniFast: unbiased uniform split of ``total_util`` over ``n`` tasks
    (Bini & Buttazzo). Shared by this sweep and the virtual-gang grid
    (repro.vgang.grid); always driven by an explicit seeded rng so every
    sweep is reproducible."""
    utils: List[float] = []
    remaining = total_util
    for i in range(n - 1):
        nxt = remaining * rng.random() ** (1.0 / (n - 1 - i))
        utils.append(remaining - nxt)
        remaining = nxt
    utils.append(remaining)
    return utils


def random_gang_taskset(rng: random.Random, n_cores: int, n_tasks: int,
                        total_util: float):
    """UUniFast utilizations over ``n_tasks`` gangs, log-uniform periods,
    random gang widths, rate-monotonic priorities (shorter period = higher
    prio; ties broken by index so priorities stay distinct — distinct
    priority per gang is the paper's gang-identity requirement)."""
    from repro.core.gang import RTTask

    utils = uunifast(rng, n_tasks, total_util)

    periods = [rng.choice((10.0, 20.0, 25.0, 40.0, 50.0, 100.0))
               for _ in range(n_tasks)]
    by_rm = sorted(range(n_tasks), key=lambda i: (periods[i], i))
    prio_of = {idx: n_tasks - rank for rank, idx in enumerate(by_rm)}

    tasks = []
    for i in range(n_tasks):
        width = rng.randint(1, n_cores)
        cores = tuple(rng.sample(range(n_cores), width))
        wcet = max(utils[i] * periods[i], 1e-3)
        tasks.append(RTTask(
            name=f"g{i}", wcet=wcet, period=periods[i], cores=cores,
            prio=prio_of[i], release_offset=rng.uniform(0, periods[i])))
    return tasks


def _sched_cell(seed: int, n_cores: int, n_tasks: int, total_util: float,
                cycles: float) -> Dict:
    """One random taskset -> exact-sim verdict + RTA verdict. The taskset
    is rebuilt from the seed, so the cell is reproducible in isolation."""
    from repro.core.rta import schedulable
    from repro.core.sim import Simulator

    rng = random.Random(seed)
    tasks = random_gang_taskset(rng, n_cores, n_tasks, total_util)
    horizon = cycles * max(t.period for t in tasks)
    t0 = time.time()
    r = Simulator(n_cores, tasks, dt=None).run(horizon)
    rta = schedulable(tasks)
    return {
        "seed": seed,
        "util": total_util,
        "sim_ok": sum(r.deadline_misses.values()) == 0,
        "rta_ok": all(v["ok"] for v in rta.values()),
        "events": r.events,
        "wall_s": time.time() - t0,
    }


def taskset_seed(seed: int, k: int, total_util: float) -> int:
    """Per-taskset seed derivation — the reproducibility contract shared
    by this sweep and the virtual-gang grid (repro.vgang.grid): results
    are a pure function of (--seed, taskset index, utilization level),
    independent of how tasksets are batched across workers."""
    return seed + 7919 * k + int(1e6 * total_util)


def _sched_level(args: Tuple) -> List[Dict]:
    """Pool worker: one contiguous shard of a utilization level's
    tasksets in one process (ROADMAP item 4 — interpreter startup and
    import cost amortized over the shard, not paid per taskset).
    Per-taskset seeds use ``taskset_seed`` with the absolute index, so
    results are identical for any sharding. Aggregation stays in the
    parent.

    The shard's RTA verdicts run through the batched kernel
    (``analysis.batched_rta``, DESIGN.md §13) in one call — bit-identical
    to the scalar per-taskset ``schedulable`` loop, which stays
    reachable via the ``scalar_rta`` shard flag (``--scalar-rta``).
    Sims run trace-free: the sweep only reads SimResult counters.

    Optional trailing args extend the payload tuple backwards-
    compatibly: ``scalar_rta``, then ``gamma`` and a ``heuristics``
    tuple of PolicyFamily names (vgang/family.py) — each named family
    forms the shard's tasksets and contributes its own batched
    acceptance bit per taskset (``family_ok``)."""
    from repro.core.rta import schedulable
    from repro.core.sim import Simulator

    seed, n_cores, n_tasks, total_util, cycles, k0, k1, *rest = args
    scalar_rta = bool(rest[0]) if rest else False
    gamma = float(rest[1]) if len(rest) > 1 else 0.5
    heuristics = tuple(rest[2]) if len(rest) > 2 else ()
    seeds = [taskset_seed(seed, k, total_util) for k in range(k0, k1)]
    # each taskset gets its own rng seeded from the absolute index, so
    # drawing the whole shard up front cannot perturb the streams
    tasksets = [random_gang_taskset(random.Random(s), n_cores, n_tasks,
                                    total_util) for s in seeds]
    if scalar_rta:
        rta_bits = [all(v["ok"] for v in schedulable(ts).values())
                    for ts in tasksets]
    else:
        from repro.analysis.batched_rta import batched_accepts
        rta_bits = batched_accepts(tasksets)
    fam_bits: Dict[str, List[bool]] = {}
    if heuristics:
        from repro.vgang.family import get_family
        from repro.vgang.formation import intensity_interference
        intfs = [intensity_interference(ts, gamma) for ts in tasksets]
        for h in heuristics:
            fam = get_family(h)
            formed_sets = [fam.assign(fam.form(ts, n_cores, intf))
                           for ts, intf in zip(tasksets, intfs)]
            if scalar_rta:
                fam_bits[h] = [bool(fam.verdict(f, i))
                               for f, i in zip(formed_sets, intfs)]
            else:
                fam_bits[h] = fam.batched_verdict(formed_sets, intfs)
    out = []
    for j, (s, tasks, rta_ok) in enumerate(zip(seeds, tasksets, rta_bits)):
        horizon = cycles * max(t.period for t in tasks)
        t0 = time.time()
        r = Simulator(n_cores, tasks, dt=None, trace=False).run(horizon)
        row = {
            "seed": s,
            "util": total_util,
            "sim_ok": sum(r.deadline_misses.values()) == 0,
            "rta_ok": rta_ok,
            "events": r.events,
            "wall_s": time.time() - t0,
        }
        if heuristics:
            row["family_ok"] = {h: bool(fam_bits[h][j])
                                for h in heuristics}
        out.append(row)
    return out


def _sweep_config(n_cores, n_tasks, utils, n_per_util, cycles, processes,
                  seed, scalar_rta, out=None, heuristics=(), gamma=0.5):
    """The resolved ExperimentConfig a direct ``schedulability_sweep``
    call denotes (provenance parity with the CLI shell)."""
    from repro.experiment import default_sweep_config
    return default_sweep_config().merged({
        "taskset": {"cores": [n_cores], "n_tasks": n_tasks,
                    "utils": list(utils), "n_per_point": n_per_util,
                    "seed": seed, "gamma": gamma},
        "policy": {"heuristics": list(heuristics)},
        "engine": {"cycles": cycles, "processes": processes or 0,
                   "scalar_rta": scalar_rta},
        "output": {"out": out},
    })


def schedulability_sweep(n_cores: int = 4, n_tasks: int = 4,
                         utils: Sequence[float] = (0.3, 0.5, 0.7, 0.9),
                         n_per_util: int = 100, cycles: float = 20.0,
                         processes: Optional[int] = None,
                         seed: int = 0, scalar_rta: bool = False,
                         heuristics: Sequence[str] = (),
                         gamma: float = 0.5,
                         config=None) -> Dict:
    """Run ``n_per_util`` random tasksets per utilization level in
    batched shard workers (a few shards per level — enough to use every
    core, orders of magnitude fewer process tasks than one per taskset),
    aggregating acceptance ratios (simulated + RTA) in the parent.

    ``heuristics`` names PolicyFamilies (vgang/family.py) to score
    alongside the plain gang RTA: each family forms every taskset and
    contributes a ``family_sched_ratio`` column. Families that require
    window-aligned zero-offset releases (the rtgT pricings) are
    rejected — the sweep draws random release offsets by design.

    ``config`` is the resolved ExperimentConfig this run realizes (the
    CLI shell passes it down; one is synthesized for direct calls), and
    its content digest is stamped into the output dict."""
    heuristics = tuple(heuristics)
    if heuristics:
        from repro.vgang.family import family_names, get_family
        for h in heuristics:
            fam = get_family(h)
            if fam.aligned_releases_only:
                valid = [n for n in family_names()
                         if not get_family(n).aligned_releases_only]
                raise ValueError(
                    f"policy family {h!r} needs window-aligned "
                    f"zero-offset releases, but the sweep draws random "
                    f"release offsets — run it on the grid instead "
                    f"(families valid here: {valid})")
    if config is None:
        config = _sweep_config(n_cores, n_tasks, utils, n_per_util,
                               cycles, processes, seed, scalar_rta,
                               heuristics=heuristics, gamma=gamma)
    procs = max(1, processes or min(multiprocessing.cpu_count(), 16))
    shards_per_level = max(1, -(-procs // max(1, len(utils))))
    shards_per_level = min(shards_per_level, n_per_util)
    step = -(-n_per_util // shards_per_level)
    levels = [(seed, n_cores, n_tasks, u, cycles, k0,
               min(k0 + step, n_per_util), scalar_rta, gamma, heuristics)
              for u in utils for k0 in range(0, n_per_util, step)]
    procs = min(procs, len(levels))
    if procs > 1:
        with multiprocessing.Pool(procs) as pool:
            shards = pool.map(_sched_level, levels, chunksize=1)
    else:
        shards = [_sched_level(lv) for lv in levels]

    by_util: Dict[float, List[Dict]] = {u: [] for u in utils}
    for lv, rs in zip(levels, shards):
        by_util[lv[3]].extend(rs)
    rows = []
    for u in utils:
        rs = by_util[u]
        row = {
            "util": u,
            "n": len(rs),
            "sim_sched_ratio": sum(r["sim_ok"] for r in rs) / len(rs),
            "rta_sched_ratio": sum(r["rta_ok"] for r in rs) / len(rs),
            "events_total": sum(r["events"] for r in rs),
            "wall_s_total": round(sum(r["wall_s"] for r in rs), 3),
        }
        if heuristics:
            row["family_sched_ratio"] = {
                h: sum(r["family_ok"][h] for r in rs) / len(rs)
                for h in heuristics}
        rows.append(row)
    return {"n_cores": n_cores, "n_tasks": n_tasks, "cycles": cycles,
            "processes": procs, "seed": seed,
            "config": config.to_dict(),
            "config_digest": config.content_digest(), "rows": rows}


# config fields the schedulability branch exposes as flags; the aliases
# preserve the legacy spellings (DESIGN.md §14.2)
SWEEP_FLAG_PATHS = (
    "taskset.utils", "taskset.n_per_point", "taskset.n_tasks",
    "taskset.cores", "engine.cycles", "engine.processes", "taskset.seed",
    "engine.scalar_rta", "policy.heuristics", "taskset.gamma",
    "output.out")
SWEEP_FLAG_ALIASES = {"taskset.n_per_point": "--n",
                      "taskset.n_tasks": "--tasks",
                      "engine.processes": "--procs"}
SWEEP_FLAG_HELPS = {
    "engine.scalar_rta": "per-taskset scalar RTA instead of the batched "
                         "kernel (same verdicts, for benchmarking)",
    "output.out": "output JSON path (default results/sched_sweep.json)",
}


def run_schedulability(cfg) -> None:
    out = schedulability_sweep(
        n_cores=cfg.taskset.cores[0], n_tasks=cfg.taskset.n_tasks,
        utils=cfg.taskset.utils, n_per_util=cfg.taskset.n_per_point,
        cycles=cfg.engine.cycles,
        processes=cfg.engine.processes or None, seed=cfg.taskset.seed,
        scalar_rta=cfg.engine.scalar_rta,
        heuristics=cfg.policy.heuristics, gamma=cfg.taskset.gamma,
        config=cfg)
    for row in out["rows"]:
        fams = "".join(f" {h}={v:.2f}"
                       for h, v in row.get("family_sched_ratio",
                                           {}).items())
        print(f"util={row['util']:.2f} sim={row['sim_sched_ratio']:.2f} "
              f"rta={row['rta_sched_ratio']:.2f}{fams} n={row['n']} "
              f"({row['events_total']} events in {row['wall_s_total']}s)")
    path = cfg.output.out or os.path.join(ROOT, "results",
                                          "sched_sweep.json")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path} (config {out['config_digest'][:12]})")


def main():
    from repro.experiment import (ConfigurationError, ExperimentConfig,
                                  add_flags, default_sweep_config,
                                  derive_flags, resolve_config)
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--schedulability", action="store_true",
                    help="Monte-Carlo gang schedulability sweep instead "
                         "of the dry-run compile sweep")
    base = default_sweep_config()
    flags = derive_flags(ExperimentConfig, SWEEP_FLAG_PATHS,
                         aliases=SWEEP_FLAG_ALIASES,
                         helps=SWEEP_FLAG_HELPS)
    add_flags(ap, flags, base)
    args = ap.parse_args()

    if args.schedulability or args.config:
        try:
            cfg = resolve_config(base, args, flags, expected_kind="sweep")
        except ConfigurationError as e:
            ap.error(str(e))
        run_schedulability(cfg)
        return

    runnable, skipped = valid_cells()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "skipped.json"), "w") as f:
        json.dump({f"{a}|{s}": r for (a, s), r in skipped.items()}, f,
                  indent=1)

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    failures = []
    for multi_pod in meshes:
        for arch, shape in runnable:
            t0 = time.time()
            res = run_one(arch, shape, multi_pod, args.force)
            tag = "pod2x16x16" if multi_pod else "16x16"
            if "error" in res:
                failures.append((arch, shape, tag))
                print(f"[FAIL] {arch} {shape} {tag}: {res['error'][-400:]}",
                      flush=True)
            else:
                cached = " (cached)" if res.get("cached") else ""
                print(f"[ok] {arch} {shape} {tag} compile={res['compile_s']}s"
                      f" wall={time.time()-t0:.0f}s{cached}", flush=True)
    print(f"\nSWEEP DONE failures={len(failures)}: {failures}")


if __name__ == "__main__":
    main()
