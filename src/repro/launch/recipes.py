"""Per-(arch x shape) run recipes: dtype/optimizer/remat/parallelism choices
used by the dry-run and launchers. These are the *baseline* settings recorded
in EXPERIMENTS.md; hillclimb variants override fields explicitly."""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig


def parallel_for(cfg: ModelConfig, shape: ShapeConfig,
                 multi_pod: bool = False, **overrides) -> ParallelConfig:
    big = cfg.n_params() > 5e10          # qwen2-72b, kimi-k2
    # training always FSDP-shards weights; inference does too when weights
    # can't fit per-device otherwise (kimi-k2: 2.06 TB bf16 / 16-way EP =
    # 128 GB/device >> 16 GB HBM -> shard d_model over `data` and gather
    # per layer inside the scan)
    infer_needs_fsdp = cfg.n_params() * 2 / 16 > 10e9   # bytes per TP shard
    p = ParallelConfig(
        pod_axis="pod" if multi_pod else None,
        fsdp=shape.kind == "train" or infer_needs_fsdp,
        fsdp_pod=multi_pod,
        tensor_parallel=True,
        expert_parallel=cfg.family == "moe",
        sequence_parallel=True,
        remat="block",
        grad_accum=1,
        optimizer="adafactor" if cfg.n_params() > 2e11 else "adamw",
        opt_state_dtype="bfloat16" if big else "float32",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        fused_xent=False,
    )
    return dataclasses.replace(p, **overrides)
