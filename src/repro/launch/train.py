"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a (reduced or full) config on the local mesh with the full substrate:
sharded data loading, FSDP/TP sharding, checkpoint/restart (use
--fail-at-step to watch the restart path recover deterministically).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ParallelConfig
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.runner import RunnerConfig, SimulatedFailure, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke size)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--data", default=None, help="memmapped token file")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    mesh = make_local_mesh(len(jax.devices()), 1)
    parallel = ParallelConfig(param_dtype="float32", compute_dtype="float32",
                              q_block=64, kv_block=64)
    api = build_model(cfg, parallel, mesh)
    opt = Optimizer(OptConfig(name="adamw", lr=args.lr, warmup=10,
                              decay_steps=max(args.steps, 20)))
    data_cfg = DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, path=args.data,
        n_vision_tokens=cfg.n_vision_tokens, d_model=cfg.d_model,
        n_frames=cfg.n_encoder_frames if cfg.family == "audio" else 0)
    rc = RunnerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at_step)
    runner = TrainRunner(api, opt, data_cfg, rc)
    try:
        runner.run()
    except SimulatedFailure as e:
        print(f"[ft] {e}; restarting from latest checkpoint...")
        runner2 = TrainRunner(api, opt, data_cfg,
                              RunnerConfig(total_steps=args.steps,
                                           ckpt_every=args.ckpt_every,
                                           ckpt_dir=args.ckpt_dir))
        runner2.run()
        runner.metrics_log.extend(runner2.metrics_log)
    first = runner.metrics_log[0]["loss"] if runner.metrics_log else None
    last = runner.metrics_log[-1]["loss"] if runner.metrics_log else None
    print(f"[train] {args.arch}: steps={len(runner.metrics_log)} "
          f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
