"""Batched serving engine with slot-based continuous batching and RT-Gang
integration.

The engine mirrors the paper's deployment story: the *decode step* of a
latency-critical model is the real-time gang (it must meet a control-loop
deadline, like the paper's DNN steering task); prefills of newly-arrived
requests and any background jobs are best-effort work that RT-Gang throttles.

Slots: a fixed decode batch of B slots, each with its own cache position;
``decode_fn`` already takes per-slot positions, so slot refill is just a
batch-dim ``dynamic_update_slice`` of the prefilled KV into the live cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelApi


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False


class ServingEngine:
    def __init__(self, api: ModelApi, params, *, max_batch: int,
                 max_seq: int, greedy: bool = True):
        self.api = api
        self.params = params
        self.B = max_batch
        self.S = max_seq
        cfg = api.cfg
        cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.cache = self._empty_cache(cd)
        self.pos = jnp.zeros((max_batch,), jnp.int32)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.active = np.zeros((max_batch,), bool)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self._decode = jax.jit(api.decode_fn, donate_argnums=(1,))
        self._prefill = jax.jit(api.prefill_fn)
        self.greedy = greedy
        self.decode_steps = 0

    def _empty_cache(self, cd):
        cfg = self.api.cfg
        assert cfg.family in ("dense", "vlm", "moe"), \
            "slot engine currently serves attention-cache families"
        L = cfg.n_layers
        shp = (L, self.B, self.S, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, cd), "v": jnp.zeros(shp, cd)}

    # ------------------------------------------------------------------
    def warmup(self, prompt_len: int):
        """Compile prefill+decode ahead of serving, then reset to fresh
        state (the decode cache is donated, so no snapshot/restore)."""
        dummy = Request(rid=-1, prompt=np.zeros((prompt_len,), np.int32),
                        max_new=1)
        self.add_request(dummy)
        self.decode_step()
        self.cache = self._empty_cache(self.cache["k"].dtype)
        self.pos = jnp.zeros((self.B,), jnp.int32)
        self.tokens = jnp.zeros((self.B, 1), jnp.int32)
        self.active = np.zeros((self.B,), bool)
        self.slot_req = [None] * self.B
        self.decode_steps = 0

    def add_request(self, req: Request) -> bool:
        free = [i for i in range(self.B) if not self.active[i]]
        if not free:
            return False
        slot = free[0]
        S_p = req.prompt.shape[0]
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, cache = self._prefill(self.params, batch)
        # insert prefilled KV into the live cache at this slot
        k = jnp.zeros((self.api.cfg.n_layers, 1, self.S,
                       self.api.cfg.n_kv_heads, self.api.cfg.head_dim),
                      self.cache["k"].dtype)
        k = jax.lax.dynamic_update_slice(k, cache["k"], (0, 0, 0, 0, 0))
        v = jnp.zeros_like(k)
        v = jax.lax.dynamic_update_slice(v, cache["v"], (0, 0, 0, 0, 0))
        self.cache["k"] = jax.lax.dynamic_update_slice(
            self.cache["k"], k, (0, slot, 0, 0, 0))
        self.cache["v"] = jax.lax.dynamic_update_slice(
            self.cache["v"], v, (0, slot, 0, 0, 0))
        first = int(jnp.argmax(logits[:, -1, :], axis=-1)[0])
        req.out.append(first)
        req.slot = slot
        self.active[slot] = True
        self.slot_req[slot] = req
        self.pos = self.pos.at[slot].set(S_p)
        self.tokens = self.tokens.at[slot, 0].set(first)
        return True

    def decode_step(self):
        """One gang-schedulable decode quantum over all active slots."""
        if not self.active.any():
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt_host = np.asarray(nxt)
        self.decode_steps += 1
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            req.out.append(int(nxt_host[slot]))
            if len(req.out) >= req.max_new or \
                    int(self.pos[slot]) + 2 >= self.S:
                req.done = True
                self.active[slot] = False
                self.slot_req[slot] = None
        self.pos = self.pos + 1
        self.tokens = nxt[:, None]

    def run_until_done(self, reqs: List[Request], max_steps: int = 10_000):
        pending = list(reqs)
        done: List[Request] = []
        steps = 0
        while (pending or self.active.any()) and steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.decode_step()
            steps += 1
            done.extend([r for r in reqs if r.done and r not in done])
        return reqs
