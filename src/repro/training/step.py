"""Train-step builder: value_and_grad + optional microbatch accumulation +
optional int8 error-feedback gradient compression + optimizer update."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import ModelApi
from repro.training.optimizer import Optimizer, OptConfig


def make_train_state(api: ModelApi, opt: Optimizer, rng):
    params = api.init(rng)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_pspecs(api: ModelApi, opt: Optimizer):
    pspecs = api.param_pspecs()
    return {"params": pspecs,
            "opt": opt.state_pspecs(pspecs, api.param_shapes()),
            "step": P()}


# --------------------------------------------------------------------------
# int8 gradient compression with error feedback (beyond-paper feature):
# quantize -> dequantize around the (implicit) cross-pod reduction, keeping
# the quantization residual in an error-feedback buffer. On real hardware the
# collective itself runs on the int8 payload; numerics here are identical.
# --------------------------------------------------------------------------
def compress_grads(grads, ef_buf):
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq)

    out = jax.tree.map(one, grads, ef_buf)
    g_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    ef_new = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return g_new, ef_new


def make_train_step(api: ModelApi, opt: Optimizer):
    accum = api.parallel.grad_accum
    use_compress = api.parallel.grad_compress == "int8_ef"

    grad_fn = jax.value_and_grad(api.loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        def micro(carry, mb):
            gacc, lacc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (gacc, lacc + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)
        (gacc, lsum), ms = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
        grads = jax.tree.map(lambda g: g / accum, gacc)
        metrics = jax.tree.map(lambda m: m[-1], ms)
        return lsum / accum, metrics, grads

    def train_step(state, batch):
        if accum > 1:
            loss, metrics, grads = accumulate(state["params"], batch)
        else:
            loss, metrics, grads = single(state["params"], batch)
        opt_state = state["opt"]
        if use_compress:
            ef = opt_state.get("ef") if isinstance(opt_state, dict) else None
            if ef is None:
                ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state["params"])
            grads, ef = compress_grads(grads, ef)
            new_params, new_opt = opt.update(grads, {
                k: v for k, v in opt_state.items() if k != "ef"},
                state["params"])
            new_opt["ef"] = ef
        else:
            new_params, new_opt = opt.update(grads, opt_state, state["params"])
        metrics = dict(metrics)
        metrics["loss"] = loss
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
