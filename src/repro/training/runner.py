"""Fault-tolerant training runner: checkpoint/restart, deterministic data
resume, simulated failures, straggler accounting, async checkpointing.

This is the host-side control loop a pod worker runs; on a real fleet every
host executes it identically (single-controller-per-host JAX SPMD). Failure
recovery = process restart + ``resume()`` from the latest complete
checkpoint; elastic restarts may use a different mesh (ckpt re-shards).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import DataConfig, ShardedLoader, TokenSource
from repro.models.model import ModelApi
from repro.training.optimizer import OptConfig, Optimizer
from repro.training.step import (make_train_state, make_train_step,
                                 state_pspecs)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    fail_at_step: Optional[int] = None     # simulate a node failure
    straggler_factor: float = 3.0


class TrainRunner:
    def __init__(self, api: ModelApi, opt: Optimizer, data_cfg: DataConfig,
                 run_cfg: RunnerConfig, batch_axes=("data",)):
        self.api = api
        self.opt = opt
        self.run_cfg = run_cfg
        self.ckpt = CheckpointManager(run_cfg.ckpt_dir, keep=run_cfg.keep)
        self.loader = ShardedLoader(TokenSource(data_cfg), api.mesh,
                                    batch_axes)
        self.step_fn = jax.jit(make_train_step(api, opt),
                               donate_argnums=(0,))
        self.metrics_log: list = []
        self.straggler_steps: list = []
        self._ema_dur: Optional[float] = None

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        return make_train_state(self.api, self.opt, jax.random.key(seed))

    def resume_or_init(self, seed: int = 0):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state(seed), 0
        state = self.init_state(seed)  # structure donor
        state, extra = self.ckpt.restore(state)
        return state, int(extra.get("data_step", latest))

    # ------------------------------------------------------------------
    def run(self, state=None, start_step: Optional[int] = None):
        rc = self.run_cfg
        if state is None:
            state, start_step = self.resume_or_init()
        if start_step is None:
            start_step = int(np.asarray(state["step"]))
        it = self.loader.iterate(start_step)
        with self.api.mesh:
            for step, batch in it:
                if step >= rc.total_steps:
                    break
                if rc.fail_at_step is not None and step == rc.fail_at_step:
                    raise SimulatedFailure(f"injected failure at {step}")
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                dur = time.monotonic() - t0
                if self._ema_dur is not None and \
                        dur > rc.straggler_factor * self._ema_dur:
                    self.straggler_steps.append((step, dur))
                self._ema_dur = dur if self._ema_dur is None else \
                    0.9 * self._ema_dur + 0.1 * dur
                self.metrics_log.append({"step": step, "loss": loss,
                                         "dur_s": dur})
                if (step + 1) % rc.ckpt_every == 0:
                    self.ckpt.save(state, step + 1,
                                   extra={"data_step": step + 1})
        self.ckpt.wait()
        return state
