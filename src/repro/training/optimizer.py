"""Optimizers built from scratch: AdamW and Adafactor (factored 2nd moment).

State sharding mirrors parameter sharding (derived from the same logical
axes), so FSDP shards optimizer state for free. ``opt_state_dtype`` allows
bf16 moments for the trillion-param configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    prog = jnp.clip((step - cfg.warmup) / max(cfg.decay_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(
        x.dtype), grads), g


class Optimizer:
    """(init, update) pair; functional, pytree state."""

    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    # ---- adamw -------------------------------------------------------------
    def _adamw_init(self, params):
        dt = DTYPES[self.cfg.state_dtype]
        z = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def _adamw_update(self, grads, state, params):
        c = self.cfg
        cnt = state["count"] + 1
        lr = lr_at(c, cnt)
        b1c = 1 - c.b1 ** cnt.astype(jnp.float32)
        b2c = 1 - c.b2 ** cnt.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g32
            v_new = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g32 * g32
            step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + c.eps)
            if p.ndim >= 2:
                step = step + c.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return p_new.astype(p.dtype), m_new.astype(m.dtype), \
                v_new.astype(v.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        p_new = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return p_new, {"m": m_new, "v": v_new, "count": cnt}

    # ---- adafactor ----------------------------------------------------------
    def _adafactor_init(self, params):
        dt = DTYPES[self.cfg.state_dtype]

        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], dt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
            return {"v": jnp.zeros(p.shape, dt)}

        return {"f": jax.tree.map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def _adafactor_update(self, grads, state, params):
        c = self.cfg
        cnt = state["count"] + 1
        lr = lr_at(c, cnt)
        beta = 1.0 - (cnt.astype(jnp.float32) + 1) ** -0.8

        def upd(g, f, p):
            g32 = jnp.square(g.astype(jnp.float32)) + 1e-30
            if p.ndim >= 2:
                vr = beta * f["vr"].astype(jnp.float32) + (1 - beta) * \
                    g32.mean(axis=-1)
                vc = beta * f["vc"].astype(jnp.float32) + (1 - beta) * \
                    g32.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30))
                step = g.astype(jnp.float32) / (jnp.sqrt(denom) + 1e-12)
                newf = {"vr": vr.astype(f["vr"].dtype),
                        "vc": vc.astype(f["vc"].dtype)}
            else:
                v = beta * f["v"].astype(jnp.float32) + (1 - beta) * g32
                step = g.astype(jnp.float32) / (jnp.sqrt(v) + 1e-12)
                newf = {"v": v.astype(f["v"].dtype)}
            # relative step clipping (Shazeer & Stern)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)))
            step = step / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                step = step + c.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return p_new.astype(p.dtype), newf

        is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        out = jax.tree.map(upd, grads, state["f"], params,
                           is_leaf=lambda x: is_state(x))
        p_new = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        f_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return p_new, {"f": f_new, "count": cnt}

    # ---- sgd ---------------------------------------------------------------
    def _sgd_init(self, params):
        return {"count": jnp.zeros((), jnp.int32)}

    def _sgd_update(self, grads, state, params):
        lr = lr_at(self.cfg, state["count"] + 1)
        p_new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return p_new, {"count": state["count"] + 1}

    # ---- public -------------------------------------------------------------
    def init(self, params):
        return getattr(self, f"_{self.cfg.name}_init")(params)

    def update(self, grads, state, params):
        if self.cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, self.cfg.grad_clip)
        return getattr(self, f"_{self.cfg.name}_update")(grads, state, params)

    # ---- sharding of state ---------------------------------------------------
    def state_pspecs(self, param_pspecs, param_shapes):
        from jax.sharding import PartitionSpec as P
        if self.cfg.name == "adamw":
            return {"m": param_pspecs, "v": param_pspecs, "count": P()}
        if self.cfg.name == "adafactor":
            def st(spec, shape):
                dims = len(shape.shape if hasattr(shape, "shape") else shape)
                parts = list(spec) + [None] * (dims - len(spec))
                if dims >= 2:
                    return {"vr": P(*parts[:-1]),
                            "vc": P(*(parts[:-2] + parts[-1:]))}
                return {"v": P(*parts)}
            return {"f": jax.tree.map(st, param_pspecs, param_shapes,
                                      is_leaf=lambda x: isinstance(x, P)),
                    "count": P()}
        return {"count": P()}
