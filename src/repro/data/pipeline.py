"""Data pipeline: deterministic synthetic LM stream + memory-mapped token
files, sharded per data-parallel rank, with step-indexed sampling so a
checkpoint restart resumes the exact batch sequence (fault tolerance)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None        # None => synthetic
    n_vision_tokens: int = 0
    d_model: int = 0                  # for vlm/audio stub inputs
    n_frames: int = 0


class TokenSource:
    """step -> global batch of token ids, deterministically."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        if self._mm is None:
            rng = np.random.default_rng((cfg.seed << 32) ^ step)
            # markov-ish synthetic stream: makes loss measurably decrease
            base = rng.integers(0, cfg.vocab_size, size=(B, 1), dtype=np.int32)
            drift = rng.integers(0, 7, size=(B, S), dtype=np.int32)
            toks = (base + np.cumsum(drift, axis=1)) % cfg.vocab_size
            return toks.astype(np.int32)
        n_tok = self._mm.shape[0]
        n_seq = (n_tok - 1) // S
        idx = (step * B + np.arange(B)) % n_seq
        out = np.empty((B, S + 1), np.int32)
        for i, j in enumerate(idx):
            out[i] = self._mm[j * S: j * S + S + 1]
        return out

    def train_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self.batch_at(step)
        cfg = self.cfg
        if toks.shape[1] == cfg.seq_len + 1:
            tokens, labels = toks[:, :-1], toks[:, 1:]
        else:
            tokens = toks
            labels = np.concatenate(
                [toks[:, 1:], np.full((toks.shape[0], 1), -100, np.int32)],
                axis=1)
        batch = {"tokens": tokens, "labels": labels.astype(np.int32)}
        if cfg.n_vision_tokens:
            rng = np.random.default_rng((cfg.seed << 32) ^ (step + 7))
            batch["patches"] = rng.normal(
                size=(cfg.global_batch, cfg.n_vision_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
            batch["labels"][:, :cfg.n_vision_tokens] = -100
        if cfg.n_frames:
            rng = np.random.default_rng((cfg.seed << 32) ^ (step + 13))
            batch["frames"] = rng.normal(
                size=(cfg.global_batch, cfg.n_frames, cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch


class ShardedLoader:
    """Puts host batches onto the mesh with the right shardings; resumable
    from any step."""

    def __init__(self, source: TokenSource, mesh, batch_axes: Tuple[str, ...]):
        self.source = source
        self.mesh = mesh
        self.batch_axes = batch_axes

    def _shard(self, batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            spec = P(self.batch_axes if self.batch_axes else None,
                     *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def iterate(self, start_step: int = 0) -> Iterator:
        step = start_step
        while True:
            yield step, self._shard(self.source.train_batch(step))
            step += 1
