"""Response-time analysis for virtual-gang tasksets.

Under one-gang-at-a-time, a virtual gang is one scheduling unit, so the
RT-Gang transform (core/rta.py, paper §III-B) applies unchanged with the
virtual gang's *inflated* WCET standing in for the gang WCET:

    R_v = C_v + B_v + sum_{u in hp(v)} ceil(R_v / P_u) * C_u
    C_v = max_i C_i * max_{j != i} intf(i, j)      (formation.py)

Implementation is literal reuse: each virtual gang collapses to its
single-core-equivalent RTTask and the existing Audsley fixed point runs
verbatim. A real gang is the degenerate one-member virtual gang (C_v =
gang WCET exactly — the factor over zero co-members is 1.0), so
``schedulable_vgangs(singleton_vgangs(ts))`` reproduces
``core.rta.schedulable(ts)`` bit-for-bit; tests/test_vgang.py asserts
float equality.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.gang import RTTask
from repro.core import rta as core_rta
from repro.core.sim import PairwiseInterference, no_interference
from repro.vgang.formation import VirtualGang


def vgang_equivalent_task(
        vg: VirtualGang,
        interference: PairwiseInterference = no_interference) -> RTTask:
    """Collapse a virtual gang to the RTTask the single-core transform
    sees: inflated WCET, the virtual gang's period and priority."""
    return RTTask(name=vg.name, wcet=vg.inflated_wcet(interference),
                  period=vg.period, cores=tuple(range(max(1, vg.width))),
                  prio=vg.prio, mem_budget=vg.mem_budget)


def vgang_taskset(vgangs: Sequence[VirtualGang],
                  interference: PairwiseInterference = no_interference
                  ) -> List[RTTask]:
    """Collapse a formed set for analysis. Distinct priority per virtual
    gang is the gang-identity requirement — freshly formed vgangs all
    carry the default prio 0, and analyzing them that way would silently
    drop every inter-vgang interference term (hp() is strictly-higher
    priorities only), so duplicates are an error, not a verdict."""
    prios = [vg.prio for vg in vgangs]
    if len(set(prios)) != len(prios):
        raise ValueError(
            "virtual gangs must carry distinct priorities before RTA — "
            "run formation output through formation.assign_priorities()")
    return [vgang_equivalent_task(vg, interference) for vg in vgangs]


def response_time_vgang(
        vg: VirtualGang, vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0, crpd: float = 0.0) -> Optional[float]:
    """WCRT of one virtual gang within a formed taskset (None =
    divergent, as in core/rta.py). ``vg`` is matched by name, which is
    unique within a formed set (each gang joins exactly one vgang)."""
    eq = vgang_taskset(vgangs, interference)
    mine = [t for t in eq if t.name == vg.name]
    if not mine:
        raise ValueError(f"{vg.name!r} is not in the formed set "
                         f"{[v.name for v in vgangs]}")
    return core_rta.response_time(mine[0], eq, blocking=blocking,
                                  crpd=crpd)


def schedulable_vgangs(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0, crpd: float = 0.0) -> Dict[str, Dict]:
    """Per-virtual-gang response times vs deadlines, keyed by vgang name
    — same row shape as core.rta.schedulable."""
    return core_rta.schedulable(vgang_taskset(vgangs, interference),
                                blocking=blocking, crpd=crpd)


def accepts(vgangs: Sequence[VirtualGang],
            interference: PairwiseInterference = no_interference,
            blocking: float = 0.0, crpd: float = 0.0) -> bool:
    """Single-bit admission verdict for the evaluation grid."""
    res = schedulable_vgangs(vgangs, interference, blocking=blocking,
                             crpd=crpd)
    return all(v["ok"] for v in res.values())
