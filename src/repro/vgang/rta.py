"""Response-time analysis for virtual-gang tasksets.

Under one-gang-at-a-time, a virtual gang is one scheduling unit, so the
RT-Gang transform (core/rta.py, paper §III-B) applies unchanged with the
virtual gang's *inflated* WCET standing in for the gang WCET:

    R_v = C_v + B_v + sum_{u in hp(v)} ceil(R_v / P_u) * C_u
    C_v = max_i C_i * max_{j != i} intf(i, j)      (formation.py)

Implementation is literal reuse: each virtual gang collapses to its
single-core-equivalent RTTask and the existing Audsley fixed point runs
verbatim. A real gang is the degenerate one-member virtual gang (C_v =
gang WCET exactly — the factor over zero co-members is 1.0), so
``schedulable_vgangs(singleton_vgangs(ts))`` reproduces
``core.rta.schedulable(ts)`` bit-for-bit; tests/test_vgang.py asserts
float equality.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.gang import RTTask
from repro.core import rta as core_rta
from repro.core.rta import gang_wcet
from repro.core.sim import PairwiseInterference, no_interference
from repro.vgang.formation import (Partitioning, VirtualGang,
                                   critical_member, pair_factor,
                                   rtg_sibling_budget)


def vgang_equivalent_task(
        vg: VirtualGang,
        interference: PairwiseInterference = no_interference) -> RTTask:
    """Collapse a virtual gang to the RTTask the single-core transform
    sees: inflated WCET, the virtual gang's period and priority."""
    return RTTask(name=vg.name, wcet=vg.inflated_wcet(interference),
                  period=vg.period, cores=tuple(range(max(1, vg.width))),
                  prio=vg.prio, mem_budget=vg.mem_budget)


def vgang_taskset(vgangs: Sequence[VirtualGang],
                  interference: PairwiseInterference = no_interference
                  ) -> List[RTTask]:
    """Collapse a formed set for analysis. Distinct priority per virtual
    gang is the gang-identity requirement — freshly formed vgangs all
    carry the default prio 0, and analyzing them that way would silently
    drop every inter-vgang interference term (hp() is strictly-higher
    priorities only), so duplicates are an error, not a verdict."""
    prios = [vg.prio for vg in vgangs]
    if len(set(prios)) != len(prios):
        raise ValueError(
            "virtual gangs must carry distinct priorities before RTA — "
            "run formation output through formation.assign_priorities()")
    return [vgang_equivalent_task(vg, interference) for vg in vgangs]


def response_time_vgang(
        vg: VirtualGang, vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0, crpd: float = 0.0) -> Optional[float]:
    """WCRT of one virtual gang within a formed taskset (None =
    divergent, as in core/rta.py). ``vg`` is matched by name, which is
    unique within a formed set (each gang joins exactly one vgang)."""
    eq = vgang_taskset(vgangs, interference)
    mine = [t for t in eq if t.name == vg.name]
    if not mine:
        raise ValueError(f"{vg.name!r} is not in the formed set "
                         f"{[v.name for v in vgangs]}")
    return core_rta.response_time(mine[0], eq, blocking=blocking,
                                  crpd=crpd)


def schedulable_vgangs(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0, crpd: float = 0.0) -> Dict[str, Dict]:
    """Per-virtual-gang response times vs deadlines, keyed by vgang name
    — same row shape as core.rta.schedulable."""
    return core_rta.schedulable(vgang_taskset(vgangs, interference),
                                blocking=blocking, crpd=crpd)


def accepts(vgangs: Sequence[VirtualGang],
            interference: PairwiseInterference = no_interference,
            blocking: float = 0.0, crpd: float = 0.0) -> bool:
    """Single-bit admission verdict for the evaluation grid."""
    res = schedulable_vgangs(vgangs, interference, blocking=blocking,
                             crpd=crpd)
    return all(v["ok"] for v in res.values())


def schedulable_vgangs_enforced(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        enforcement=None,
        blocking: float = 0.0, crpd: float = 0.0) -> Dict[str, Dict]:
    """Admission with runtime overrun enforcement priced in
    (core/faults.py, DESIGN.md §11) — the enforcement-aware restoration
    of the paper's interference/blocking bound.

    Without enforcement the RTA is vacuous against misbehavior: a job
    that overruns its declared WCET occupies the machine for as long as
    it pleases (one-gang-at-a-time makes that occupancy everyone else's
    interference), so no bound computed from declarations survives a
    single lying task. With an ``Enforcement`` policy, *no* job —
    compliant or not — can occupy the machine for more than:

    * ``factor x C_v`` of executed work (the work budget cuts it
      there), and,
    * when the watchdog is armed, ``watchdog_factor x P_v`` of wall
      time since release (the watchdog aborts it there even if it
      executes nothing at all — e.g. a thread stalled forever by a
      lost wakeup, which no work budget can catch).

    Each virtual gang's equivalent-task WCET is therefore replaced by
    the tighter of the two occupancy bounds and the standard fixed
    point runs unchanged: the resulting per-gang response times hold
    for every *compliant* gang no matter how any other task misbehaves.
    With ``enforcement=None`` (or factor 1.0, no watchdog) this is
    exactly ``schedulable_vgangs`` — the declared-WCET bound, sound
    only when every task is honest."""
    factor = 1.0 if enforcement is None else enforcement.factor
    wd = None if enforcement is None else enforcement.watchdog_factor
    eq = []
    for t in vgang_taskset(vgangs, interference):
        w = t.wcet * factor
        if wd is not None:
            w = min(w, wd * t.period)
        eq.append(dataclasses.replace(t, wcet=w) if w != t.wcet else t)
    return core_rta.schedulable(eq, blocking=blocking, crpd=crpd)


# ---------------------------------------------------------------------
# RTG-throttle (arXiv:1912.10959 §IV-C): within a virtual gang the
# critical member runs unthrottled while sibling members' cores are
# bandwidth-capped (VirtualGangPolicy(rtg_throttle=True)). The engines
# realize this through RT-thread charging: a sibling runs from each
# regulation-window boundary until its per-core budget Q is exhausted
# (q_j = Q / traffic_rate_j wall-ms), then pauses — generating neither
# traffic nor interference — until the window ends. The per-window WCET
# bound below prices exactly that duty-cycle regime.
# ---------------------------------------------------------------------

def _window_runtimes(vg: VirtualGang, interference: PairwiseInterference,
                     interval: float) -> Dict[str, float]:
    """Unstalled run time per regulation window for every member: the
    critical member owns the whole window; a sibling with traffic rate
    r runs min(interval, Q / r)."""
    crit = critical_member(vg, interference)
    budget = rtg_sibling_budget(vg, interference, interval)
    run = {}
    for m in vg.members:
        r = m.traffic_rate
        if m is crit or r <= 0.0 or r * interval <= budget + 1e-12:
            run[m.name] = interval
        elif budget <= 0.0:
            run[m.name] = 0.0
        else:
            run[m.name] = budget / r
    return run


def _throttle_profile(vg: VirtualGang, m: RTTask, run: Dict[str, float],
                      interference: PairwiseInterference
                      ) -> List[Tuple[float, float]]:
    """Piecewise ``(seg_len, slowdown)`` profile of member ``m`` within
    one regulation window under the static duty cycle ``run`` — the
    exact profile ``rtg_throttle_wcet`` integrates, shared with the
    vectorized evaluator (analysis/batched_rta.window_eval) so both
    paths see identical segments."""
    q_m = run[m.name]
    cuts = sorted({min(run[o.name], q_m) for o in vg.members
                   if o is not m} | {q_m})
    profile: List[Tuple[float, float]] = []
    t_prev = 0.0
    for b in cuts:
        if b <= t_prev + 1e-15:
            continue
        s = 1.0
        for o in vg.members:
            if o is not m and run[o.name] > t_prev + 1e-15:
                f = interference(m.name, o.name)
                if f > s:
                    s = f
        profile.append((b - t_prev, s))
        t_prev = b
    return profile


def rtg_throttle_wcet(vg: VirtualGang,
                      interference: PairwiseInterference = no_interference,
                      interval: float = 1.0) -> float:
    """Stand-alone completion bound of a virtual gang under RTG-throttle
    (inf = a starved sibling can never finish).

    Per window, member m is unstalled over [0, q_m); its slowdown at
    offset t is the worst pairwise factor over co-members still
    unstalled at t (a stalled co-member is absent from the engines'
    MemoryModel occupancy), so its work per window is the piecewise
    integral of 1/s(t) over [0, q_m). Co-members are conservatively
    assumed present in every window (finishing early only removes
    interference), and the finish offset inside the last window follows
    the same piecewise profile. Sound against the engines for
    window-aligned releases (period a multiple of ``interval``, zero
    offset — the evaluation grid's regime); mid-window resumes after a
    preemption are priced separately by the per-preemption window slop
    in ``schedulable_rtg_throttle``."""
    if len(vg.members) == 1:
        return vg.inflated_wcet(interference)
    run = _window_runtimes(vg, interference, interval)
    worst = 0.0
    for m in vg.members:
        q_m = run[m.name]
        if q_m <= 0.0:
            return float("inf")
        profile = _throttle_profile(vg, m, run, interference)
        work_per_window = sum(d / s for d, s in profile)
        if work_per_window <= 1e-12:
            return float("inf")
        need = gang_wcet(m)
        full = int((need - 1e-12) / work_per_window)
        rem = need - full * work_per_window
        offset = 0.0
        for d, s in profile:              # finish offset in last window
            seg_work = d / s
            if rem <= seg_work + 1e-15:
                offset += rem * s
                break
            rem -= seg_work
            offset += d
        worst = max(worst, full * interval + offset)
    return worst


def _stall_prone(vg: VirtualGang, interference: PairwiseInterference,
                 interval: float) -> bool:
    run = _window_runtimes(vg, interference, interval)
    return any(q < interval - 1e-12 for q in run.values())


# ---------------------------------------------------------------------
# Dynamic reclaiming (DESIGN.md §7.5 / §9.3.2, after arXiv:1809.05921's
# analysis of dynamic regulation): a sibling that finishes its job
# mid-window leaves its per-window grant donatable, and a stalled
# co-sibling draws it — donor by donor, each drawn unit confined to the
# donor's own static window and factor-dominated by the donor (the
# engines' exchange gate, memmodel.py). The gate keeps the *static*
# duty-cycle bound sound under reclaiming; the bound below additionally
# tracks bounded completions and guaranteed donations for a usually
# tighter verdict. ``schedulable_rtg_throttle(..., reclaim=True)``
# prices min(static, reclaim) — both are sound for the reclaiming
# dispatch, so the rtgT+dr acceptance dominates plain rtgT.
# ---------------------------------------------------------------------


def _member_cores(vg: VirtualGang) -> Dict[str, range]:
    """The remapped core block of each member (vgang/sched.remap_members
    packs members onto consecutive cores in member order) — the engines'
    donor/drawer scan order, which the greedy below replicates."""
    out, cursor = {}, 0
    for m in vg.members:
        out[m.name] = range(cursor, cursor + m.n_threads)
        cursor += m.n_threads
    return out


def _reclaim_extensions(vg: VirtualGang,
                        interference: PairwiseInterference,
                        interval: float, Q: float,
                        run: Dict[str, float],
                        donors: Sequence[RTTask],
                        drawers: Sequence[RTTask],
                        victims: Sequence[RTTask]) -> Dict[str, float]:
    """Per-window unstalled time of each drawer after greedy donation:
    drawers claim in trip-offset order (ties: core order), donor cores
    scanned in core order, each donor funding only the sub-span inside
    its occupant's static window [0, Q / r_donor). A drawer's effective
    extension is the worst over its cores (its job waits for the
    slowest thread) — the engines' draw schedule in the window-aligned
    regime.

    The two gates point in opposite conservative directions: pool
    *consumption* ignores the dominance filter entirely (the runtime
    gate only checks the victims actually present, so a competitor the
    full-member check would block may still drain the pool first),
    while a drawer is *credited* extension only while contiguously
    funded by donors that dominate it over every ``victim`` — a
    superset of any runtime victim set, so credited draws never exceed
    actual ones even under contention."""
    cores = _member_cores(vg)
    # donor pool: (core, avail, offset cap, donor task), core order
    pool = []
    for o in sorted(donors, key=lambda m: cores[m.name].start):
        r_o = o.traffic_rate
        q_o = interval if r_o <= 0.0 else min(interval, Q / r_o)
        for c in cores[o.name]:
            pool.append([c, Q, q_o, o])
    covers: Dict[Tuple[str, str], bool] = {}

    def dominated(s: RTTask, o: RTTask) -> bool:
        key = (s.name, o.name)
        hit = covers.get(key)
        if hit is None:
            hit = all(interference(v.name, s.name)
                      <= interference(v.name, o.name) + 1e-12
                      for v in victims if v.name not in (s.name, o.name))
            covers[key] = hit
        return hit

    u = {m.name: run[m.name] for m in drawers}
    order = sorted((m for m in drawers if run[m.name] < interval - 1e-12
                    and m.traffic_rate > 0.0),
                   key=lambda m: (run[m.name], cores[m.name].start))
    for s in order:
        r_s = s.traffic_rate
        worst = interval
        for _ in cores[s.name]:          # each thread-core draws alone
            covered = run[s.name]
            credit = covered
            credit_open = True
            for entry in pool:
                c, avail, q_o, o = entry
                if avail <= 0.0 or q_o <= covered + 1e-15:
                    continue
                take = min(avail, r_s * (q_o - covered))
                entry[1] -= take
                covered += take / r_s
                if credit_open and dominated(s, o):
                    credit = covered
                else:
                    credit_open = False   # gap: credit must stay
                                          # contiguous from run[s]
                if covered >= interval - 1e-15:
                    break
            worst = min(worst, credit)
        u[s.name] = worst
    return u


def _presence_profile(m: RTTask, present: Dict[str, float], u_m: float,
                      interference: PairwiseInterference
                      ) -> List[Tuple[float, float]]:
    """Piecewise ``(seg_len, slowdown)`` profile of member ``m``
    unstalled over [0, u_m) against co-members present over
    [0, present[o]) — the profile ``reclaim_wcet`` integrates, shared
    with the vectorized evaluator so both paths see identical
    segments."""
    cuts = sorted({min(p, u_m) for o, p in present.items()} | {u_m})
    profile: List[Tuple[float, float]] = []
    t_prev = 0.0
    for b in cuts:
        if b <= t_prev + 1e-15:
            continue
        s = 1.0
        for o, p in present.items():
            if p > t_prev + 1e-15:
                f = interference(m.name, o)
                if f > s:
                    s = f
        profile.append((b - t_prev, s))
        t_prev = b
    return profile


def _window_work(m: RTTask, present: Dict[str, float], u_m: float,
                 interference: PairwiseInterference
                 ) -> Tuple[float, List[Tuple[float, float]]]:
    """Work member ``m`` completes per window when unstalled over
    [0, u_m) against co-members present over [0, present[o]): piecewise
    integral of 1/s(t), plus the profile for finish-offset pricing."""
    profile = _presence_profile(m, present, u_m, interference)
    return sum(d / s for d, s in profile), profile


def reclaim_wcet(vg: VirtualGang,
                 interference: PairwiseInterference = no_interference,
                 interval: float = 1.0) -> float:
    """Stand-alone completion bound of a virtual gang under RTG-throttle
    *with dynamic reclaiming* (inf = some member can never finish).

    Window-phase iteration: members complete one at a time (in bound
    order); within a phase the per-window schedule is constant, so the
    number of windows to the next completion is closed-form. Per phase:

    * progress — an alive capped member is guaranteed its static run
      q_m plus the greedy donation extension funded by *completed*
      members' cores (actual completions happen no later than the bound,
      so actual donors appear no later than assumed; dominance is
      checked against every member — a superset of the runtime victim
      set — so credited draws never exceed actual ones);
    * interference — an alive co-member is priced as present over its
      *supremum* extension (every other member's full grant offered to
      it, no dominance filter): whatever phase the real system is in,
      its extension never exceeds that, and completed members drop out
      of the profile only once their bounded completion has passed.

    Sound against the engines in the same window-aligned regime as
    ``rtg_throttle_wcet``; preemption realignment is priced by the same
    per-hp-job window surcharge in ``schedulable_rtg_throttle``."""
    members = list(vg.members)
    if len(members) == 1:
        return vg.inflated_wcet(interference)
    crit = critical_member(vg, interference)
    Q = rtg_sibling_budget(vg, interference, interval)
    run = _window_runtimes(vg, interference, interval)
    # supremum extension per member: everyone else's grant offered to it
    u_sup: Dict[str, float] = {}
    for m in members:
        if run[m.name] >= interval - 1e-12:
            u_sup[m.name] = interval
            continue
        # realizable supremum: every sibling grant offered to it alone,
        # no dominance filter (the critical member's core is uncapped
        # and can never donate, so it is not a donor here either)
        others = [o for o in members if o is not m and o is not crit]
        u_sup[m.name] = _reclaim_extensions(
            vg, interference, interval, Q, run,
            donors=others, drawers=[m], victims=[])[m.name]
    remaining = {m.name: gang_wcet(m) for m in members}
    alive = list(members)
    completion: Dict[str, float] = {}
    t = 0.0
    while alive:
        done = [m for m in members if m.name in completion]
        drawers = [m for m in alive if m is not crit]
        u_grt = _reclaim_extensions(
            vg, interference, interval, Q, run,
            donors=[m for m in done if m is not crit],
            drawers=drawers, victims=members)
        best = None
        phase_work: Dict[str, float] = {}
        for m in alive:
            u_m = interval if (m is crit or
                               run[m.name] >= interval - 1e-12) \
                else u_grt[m.name]
            present = {o.name: u_sup[o.name] for o in alive if o is not m}
            work, profile = _window_work(m, present, u_m, interference)
            phase_work[m.name] = work
            if work <= 1e-12:
                continue
            need = remaining[m.name]
            full = int((need - 1e-12) / work)
            rem = need - full * work
            offset = 0.0
            for d, s in profile:
                seg = d / s
                if rem <= seg + 1e-15:
                    offset += rem * s
                    break
                rem -= seg
                offset += d
            row = (full + 1, offset, m)
            if best is None or (row[0], row[1]) < (best[0], best[1]):
                best = row
        if best is None:
            return float("inf")
        k, offset, m = best
        completion[m.name] = t + (k - 1) * interval + offset
        for o in alive:
            if o is not m:
                remaining[o.name] = max(
                    0.0, remaining[o.name] - k * phase_work[o.name])
        t += k * interval
        alive.remove(m)
    return max(completion.values())


def schedulable_rtg_throttle(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        interval: float = 1.0, blocking: float = 0.0,
        reclaim: bool = False) -> Dict[str, Dict]:
    """Per-vgang response times under RTG-throttle dispatch: the RT-Gang
    single-core transform with ``rtg_throttle_wcet`` standing in for the
    inflated WCET. Preemptions realign members to mid-window resumes
    where a stalled sibling may find its budget already spent, wasting
    up to one regulation window per resume; every release of a
    higher-priority vgang causes at most one preemption machine-wide,
    so a per-hp-job ``crpd = interval`` (plus one initial window on the
    analyzed gang) prices all realignment waste. Vgangs no member of
    which can ever stall skip that surcharge.

    ``reclaim=True`` prices the reclaiming dispatch
    (``VirtualGangPolicy(rtg_throttle=True, reclaim=True)``): the
    per-window WCET becomes ``min(rtg_throttle_wcet, reclaim_wcet)`` —
    the engines' exchange gate keeps the static bound sound under
    donation, and the reclaim bound is sound by construction, so the
    tighter of the two holds and rtgT+dr acceptance dominates rtgT."""
    prios = [vg.prio for vg in vgangs]
    if len(set(prios)) != len(prios):
        raise ValueError(
            "virtual gangs must carry distinct priorities before RTA — "
            "run formation output through formation.assign_priorities()")
    for vg in vgangs:
        # the duty-cycle bound is only sound in the window-aligned
        # regime (see rtg_throttle_wcet): refuse to price anything else
        ratio = vg.period / interval
        if abs(ratio - round(ratio)) > 1e-9:
            raise ValueError(
                f"RTG-throttle RTA needs window-aligned releases: vgang "
                f"{vg.name!r} period {vg.period} is not a multiple of "
                f"the regulation interval {interval}")
        off = [m.release_offset for m in vg.members
               if m.release_offset != 0.0]
        if off:
            raise ValueError(
                f"RTG-throttle RTA needs zero release offsets: vgang "
                f"{vg.name!r} members carry offsets {off}")
    def wcet_of(vg: VirtualGang) -> float:
        w = rtg_throttle_wcet(vg, interference, interval)
        if reclaim:
            w = min(w, reclaim_wcet(vg, interference, interval))
        return w

    eq = [RTTask(name=vg.name, wcet=wcet_of(vg),
                 period=vg.period, cores=tuple(range(max(1, vg.width))),
                 prio=vg.prio, mem_budget=vg.mem_budget)
          for vg in vgangs]
    out = {}
    for vg, task in zip(vgangs, eq):
        if task.wcet == float("inf"):
            out[vg.name] = {"wcrt": None, "deadline": vg.period,
                            "ok": False}
            continue
        crpd = interval if _stall_prone(vg, interference, interval) \
            else 0.0
        R = core_rta.response_time(task, eq, blocking=blocking, crpd=crpd)
        out[vg.name] = {"wcrt": R, "deadline": vg.period,
                        "ok": R is not None and R <= vg.period + 1e-12}
    return out


def accepts_rtg_throttle(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference,
        interval: float = 1.0, blocking: float = 0.0,
        reclaim: bool = False) -> bool:
    """Single-bit RTG-throttle admission verdict for the grid
    (``reclaim=True``: the rtgT+dr column)."""
    res = schedulable_rtg_throttle(vgangs, interference,
                                   interval=interval, blocking=blocking,
                                   reclaim=reclaim)
    return all(v["ok"] for v in res.values())


# ---------------------------------------------------------------------------
# Batched entry points (analysis fast path, DESIGN.md §13)
#
# The single-core-equivalent collapse makes each formed set a dense row of
# (C_v, P_v, prio_v), so a shard of formed sets maps straight onto the
# masked batched fixed point in analysis/batched_rta.py.  Each wrapper is a
# drop-in for mapping its scalar counterpart over the shard: same
# validation errors (raised for the first offending set, in shard order),
# same result dicts, bit-identical WCRTs and accept bits.


def _collapse_rows(vgangs: Sequence[VirtualGang],
                   interference: PairwiseInterference
                   ) -> List[Tuple[str, float, float, float]]:
    """(name, C_v, P_v, prio_v) rows for one formed set, with the same
    distinct-priority validation as vgang_taskset (the RTTask
    construction itself is bypassed: gang_wcet of an equivalent task is
    its plain wcet, so the collapse value feeds the kernel directly)."""
    prios = [vg.prio for vg in vgangs]
    if len(set(prios)) != len(prios):
        raise ValueError(
            "virtual gangs must carry distinct priorities before RTA — "
            "run formation output through formation.assign_priorities()")
    return [(vg.name, vg.inflated_wcet(interference), vg.period,
             float(vg.prio)) for vg in vgangs]


def _per_set_interference(vgang_sets, interferences):
    if callable(interferences):
        return [interferences] * len(vgang_sets)
    if len(interferences) != len(vgang_sets):
        raise ValueError("need one interference model per vgang set")
    return list(interferences)


def batched_schedulable_vgangs(
        vgang_sets: Sequence[Sequence[VirtualGang]],
        interferences=no_interference,
        blocking: float = 0.0, crpd: float = 0.0,
        backend: str = "auto") -> List[Dict[str, Dict]]:
    """Shard-batched ``schedulable_vgangs``: one result dict per formed
    set, bit-identical to the scalar loop.  ``interferences`` is a single
    model shared by every set or one model per set."""
    from repro.analysis import batched_rta as _bat

    intfs = _per_set_interference(vgang_sets, interferences)
    rows = [_collapse_rows(vgs, intf)
            for vgs, intf in zip(vgang_sets, intfs)]
    batch = _bat.pad_rows(rows)
    R = _bat.fixed_point(batch, blocking=blocking, crpd=crpd,
                         backend=backend)
    out: List[Dict[str, Dict]] = []
    for s, vgs in enumerate(vgang_sets):
        res = {}
        for i, vg in enumerate(vgs):
            wcrt = None if R[s, i] != R[s, i] else float(R[s, i])
            res[vg.name] = {"wcrt": wcrt, "deadline": vg.period,
                            "ok": wcrt is not None
                            and wcrt <= vg.period + 1e-12}
        out.append(res)
    return out


def batched_accepts(vgang_sets: Sequence[Sequence[VirtualGang]],
                    interferences=no_interference,
                    blocking: float = 0.0, crpd: float = 0.0,
                    backend: str = "auto") -> List[bool]:
    """Shard-batched ``accepts``: one admission bit per formed set.
    Skips the per-task result dicts entirely — the bits come straight
    off the kernel's WCRT array."""
    from repro.analysis import batched_rta as _bat

    intfs = _per_set_interference(vgang_sets, interferences)
    rows = [_collapse_rows(vgs, intf)
            for vgs, intf in zip(vgang_sets, intfs)]
    batch = _bat.pad_rows(rows)
    R = _bat.fixed_point(batch, blocking=blocking, crpd=crpd,
                         backend=backend)
    return _bat.accept_bits(batch, R).tolist()


def _rtg_static_bounds(vg: VirtualGang, interference: PairwiseInterference,
                       interval: float, cache: Optional[dict]
                       ) -> Tuple[float, bool]:
    """(rtg_throttle_wcet, stall_prone) for one vgang, memoized so the
    rtgT and rtgT+dr columns of a grid cell price each vgang once.  The
    cache key retains the (vg, interference) objects, so id() reuse
    after garbage collection cannot alias entries."""
    if cache is None:
        return (rtg_throttle_wcet(vg, interference, interval),
                _stall_prone(vg, interference, interval))
    key = (id(vg), id(interference), interval)
    hit = cache.get(key)
    if hit is None:
        hit = (vg, interference,
               rtg_throttle_wcet(vg, interference, interval),
               _stall_prone(vg, interference, interval))
        cache[key] = hit
    return hit[2], hit[3]


def batched_schedulable_rtg_throttle(
        vgang_sets: Sequence[Sequence[VirtualGang]],
        interferences=no_interference,
        interval: float = 1.0, blocking: float = 0.0,
        reclaim: bool = False, backend: str = "auto",
        wcet_cache: Optional[dict] = None) -> List[Dict[str, Dict]]:
    """Shard-batched ``schedulable_rtg_throttle``.

    The per-window WCET bounds (``rtg_throttle_wcet`` /
    ``reclaim_wcet``) evaluate through the vectorized closed-form
    kernel (``analysis/batched_rta.window_eval``) across the whole
    shard, and every set's Audsley iteration runs in the batched
    fixed-point kernel with per-analyzed-lane ``crpd`` (the stall-prone
    realignment surcharge).  Infinite-WCET vgangs are excluded from
    analysis but still interfere, exactly like the scalar skip."""
    import numpy as _np

    from repro.analysis import batched_rta as _bat

    intfs = _per_set_interference(vgang_sets, interferences)
    rows, crpd_rows = _rtg_rows(vgang_sets, intfs, interval, reclaim,
                                wcet_cache)
    batch = _bat.pad_rows(rows)
    S, T = batch.shape
    crpd = _np.zeros((S, T))
    for s, cr in enumerate(crpd_rows):
        crpd[s, :len(cr)] = cr
    R = _bat.fixed_point(batch, blocking=blocking, crpd=crpd,
                         backend=backend)
    out: List[Dict[str, Dict]] = []
    for s, vgs in enumerate(vgang_sets):
        res = {}
        for i, vg in enumerate(vgs):
            wcrt = None if R[s, i] != R[s, i] else float(R[s, i])
            res[vg.name] = {"wcrt": wcrt, "deadline": vg.period,
                            "ok": wcrt is not None
                            and wcrt <= vg.period + 1e-12}
        out.append(res)
    return out


def _rtg_rows(vgang_sets, intfs, interval, reclaim, wcet_cache):
    """Validated ``(name, C, P, prio)`` rows plus per-set crpd lists for
    the rtgT / rtgT+dr columns, in shard order — same checks and error
    messages as scalar ``schedulable_rtg_throttle``.

    The per-window WCET bounds are priced through the vectorized
    closed-form evaluator (analysis/batched_rta) across the whole
    shard: static bounds for every cache-miss vgang in one batch, and
    (reclaim=True) every vgang's phase iteration in lockstep — both
    bit-identical to their scalar twins."""
    from repro.analysis.batched_rta import (batched_reclaim_wcet,
                                            batched_rtg_throttle_wcet)
    for vgs, intf in zip(vgang_sets, intfs):
        prios = [vg.prio for vg in vgs]
        if len(set(prios)) != len(prios):
            raise ValueError(
                "virtual gangs must carry distinct priorities before RTA "
                "— run formation output through "
                "formation.assign_priorities()")
        for vg in vgs:
            ratio = vg.period / interval
            if abs(ratio - round(ratio)) > 1e-9:
                raise ValueError(
                    f"RTG-throttle RTA needs window-aligned releases: "
                    f"vgang {vg.name!r} period {vg.period} is not a "
                    f"multiple of the regulation interval {interval}")
            off = [m.release_offset for m in vg.members
                   if m.release_offset != 0.0]
            if off:
                raise ValueError(
                    f"RTG-throttle RTA needs zero release offsets: vgang "
                    f"{vg.name!r} members carry offsets {off}")
    flat = [(vg, intf) for vgs, intf in zip(vgang_sets, intfs)
            for vg in vgs]
    # static bound + stall flag per vgang, batched over cache misses
    statics: Dict[int, Tuple[float, bool]] = {}
    miss_pairs, miss_pos = [], []
    for pos, (vg, intf) in enumerate(flat):
        if wcet_cache is not None:
            hit = wcet_cache.get((id(vg), id(intf), interval))
            if hit is not None:
                statics[pos] = (hit[2], hit[3])
                continue
        miss_pairs.append((vg, intf))
        miss_pos.append(pos)
    if miss_pairs:
        ws = batched_rtg_throttle_wcet([p[0] for p in miss_pairs],
                                       [p[1] for p in miss_pairs],
                                       interval)
        for (vg, intf), w, pos in zip(miss_pairs, ws, miss_pos):
            stall = _stall_prone(vg, intf, interval)
            statics[pos] = (w, stall)
            if wcet_cache is not None:
                # key retains the objects, see _rtg_static_bounds
                wcet_cache[(id(vg), id(intf), interval)] = \
                    (vg, intf, w, stall)
    reclaims = None
    if reclaim:
        reclaims = batched_reclaim_wcet([vg for vg, _ in flat],
                                        [i for _, i in flat], interval)
    rows, crpd_rows = [], []
    pos = 0
    for vgs, intf in zip(vgang_sets, intfs):
        row, crpd_row = [], []
        for vg in vgs:
            w, stall = statics[pos]
            if reclaim:
                w = min(w, reclaims[pos])
            row.append((vg.name, w, vg.period, float(vg.prio)))
            crpd_row.append(interval if stall else 0.0)
            pos += 1
        rows.append(row)
        crpd_rows.append(crpd_row)
    return rows, crpd_rows


def batched_accepts_rtg_throttle(
        vgang_sets: Sequence[Sequence[VirtualGang]],
        interferences=no_interference,
        interval: float = 1.0, blocking: float = 0.0,
        reclaim: bool = False, backend: str = "auto",
        wcet_cache: Optional[dict] = None) -> List[bool]:
    """Shard-batched ``accepts_rtg_throttle`` (``reclaim=True``: the
    rtgT+dr column), bits straight off the kernel's WCRT array."""
    import numpy as _np

    from repro.analysis import batched_rta as _bat

    intfs = _per_set_interference(vgang_sets, interferences)
    rows, crpd_rows = _rtg_rows(vgang_sets, intfs, interval, reclaim,
                                wcet_cache)
    batch = _bat.pad_rows(rows)
    S, T = batch.shape
    crpd = _np.zeros((S, T))
    for s, cr in enumerate(crpd_rows):
        crpd[s, :len(cr)] = cr
    R = _bat.fixed_point(batch, blocking=blocking, crpd=crpd,
                         backend=backend)
    return _bat.accept_bits(batch, R).tolist()


# ---------------------------------------------------------------------------
# Strict partitioning (arXiv:2403.10726): within a partition, gangs never
# co-run — a gang occupies its whole partition while executing — so the
# partition IS a uniprocessor whose tasks are the gangs with their plain
# (uninflated) WCETs, and core/rta.py applies verbatim. Partitions run
# concurrently, so a gang's WCET is inflated by the worst pairwise factor
# over the gangs of *other* partitions (the MemoryModel's occupancy max
# never exceeds that bound: present co-runners are always a subset of the
# other partitions' gangs). A single-partition machine has no co-runners
# at all, so the analysis collapses to core.rta.schedulable bit-for-bit
# (the inflation factor is exactly 1.0 and C * 1.0 == C in IEEE floats).


def _partition_rows(partitioning: Partitioning,
                    interference: PairwiseInterference
                    ) -> List[List[Tuple[str, float, float, float]]]:
    """One ``(name, C', P, prio)`` row per partition: C' is the gang's
    WCET inflated by the worst pairwise factor over all gangs of other
    partitions (placement-aware via ``pair_factor`` when the model is
    distance-aware — partitions are consecutive core blocks)."""
    parts = partitioning.partitions
    rows = []
    for p in parts:
        row = []
        for g in p.gangs:
            f = 1.0
            for q in parts:
                if q is p:
                    continue
                for o in q.gangs:
                    f = max(f, pair_factor(interference, g.name, o.name,
                                           p.cores, q.cores))
            row.append((g.name, gang_wcet(g) * f, g.period,
                        float(g.prio)))
        rows.append(row)
    return rows


def schedulable_partitions(
        partitioning: Partitioning,
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0) -> Dict[str, Dict]:
    """Per-gang response times under strict partitioning, keyed by gang
    name — same row shape as core.rta.schedulable plus the hosting
    partition. Each partition runs the classic uniprocessor Audsley
    fixed point (core/rta.py) over its own gangs only."""
    out: Dict[str, Dict] = {}
    for p, row in zip(partitioning.partitions,
                      _partition_rows(partitioning, interference)):
        eq = [RTTask(name=n, wcet=c, period=per, cores=(0,), prio=int(pr))
              for n, c, per, pr in row]
        res = core_rta.schedulable(eq, blocking=blocking)
        for n, v in res.items():
            v["partition"] = p.name
            out[n] = v
    return out


def accepts_partitioned(
        partitioning: Partitioning,
        interference: PairwiseInterference = no_interference,
        blocking: float = 0.0) -> bool:
    """Single-bit admission verdict for the grid's ``part`` column."""
    res = schedulable_partitions(partitioning, interference,
                                 blocking=blocking)
    return all(v["ok"] for v in res.values())


def batched_accepts_partitioned(
        partitionings: Sequence[Partitioning],
        interferences=no_interference,
        blocking: float = 0.0, backend: str = "auto") -> List[bool]:
    """Shard-batched ``accepts_partitioned``: every partition of every
    taskset becomes one lane-row of the masked batched fixed point
    (analysis/batched_rta.py, bit-identical to core/rta.py), and a
    taskset's bit is the AND over its partitions' rows."""
    from repro.analysis import batched_rta as _bat

    intfs = _per_set_interference(partitionings, interferences)
    flat_rows: List[List[Tuple[str, float, float, float]]] = []
    owners: List[int] = []
    for s, (pg, intf) in enumerate(zip(partitionings, intfs)):
        for row in _partition_rows(pg, intf):
            flat_rows.append(row)
            owners.append(s)
    out = [True] * len(partitionings)
    if not flat_rows:
        return out
    batch = _bat.pad_rows(flat_rows)
    R = _bat.fixed_point(batch, blocking=blocking, backend=backend)
    bits = _bat.accept_bits(batch, R).tolist()
    for s, b in zip(owners, bits):
        out[s] = out[s] and bool(b)
    return out
