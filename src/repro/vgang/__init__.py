"""Virtual-gang subsystem (arXiv:1912.10959).

RT-Gang's one-gang-at-a-time policy idles every core a gang does not
occupy. The Virtual-Gang follow-up recovers that utilization by packing
real-time gangs into fixed-composition *virtual gangs* that are scheduled
as single units. This package layers that idea on the existing core:

    formation.py  width-constrained bin packing of gangs into virtual
                  gangs (FFD, best-fit, interference-aware, exhaustive)
    rta.py        response-time analysis of virtual-gang tasksets by
                  collapsing each virtual gang to its single-core
                  equivalent and reusing core/rta.py verbatim
    sched.py      VirtualGangPolicy — dispatches the members of one
                  virtual gang as a unit on the simulator engines with
                  per-member throttle budgets (core/throttle.py)
    grid.py       the acceptance-ratio evaluation grid (cores x width
                  distribution x utilization x heuristic)

See DESIGN.md §9.
"""
from repro.vgang.formation import (VirtualGang, assign_priorities,
                                   best_fit_utilization, exhaustive_optimal,
                                   first_fit_decreasing, interference_aware,
                                   intensity_interference, singleton_vgangs,
                                   total_vgang_utilization)
from repro.vgang.rta import (response_time_vgang, schedulable_vgangs,
                             vgang_equivalent_task)
from repro.vgang.sched import VirtualGangPolicy, remap_members

__all__ = [
    "VirtualGang", "assign_priorities", "best_fit_utilization",
    "exhaustive_optimal", "first_fit_decreasing", "interference_aware",
    "intensity_interference", "singleton_vgangs",
    "total_vgang_utilization", "response_time_vgang", "schedulable_vgangs",
    "vgang_equivalent_task", "VirtualGangPolicy", "remap_members",
]
