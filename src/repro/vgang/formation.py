"""Virtual-gang formation: width-constrained bin packing of real-time
gangs into virtual gangs (arXiv:1912.10959 §IV-V).

A virtual gang is a fixed set of member gangs dispatched as one
scheduling unit: members share one release, one period and one RT
priority, and co-execute on disjoint cores. Packing constraints:

* members share the same period (the virtual gang is one periodic
  entity);
* the summed width of the members fits the machine (sum w_i <= M);
* the virtual gang must not be unschedulable by construction — its
  interference-inflated WCET must fit its period.

The *inflated* WCET models intra-gang interference exactly the way the
simulator engines do: a member is slowed by the worst pairwise factor
over its co-members, and the virtual gang runs until its slowest member
finishes:

    C_v = max_i  C_i * max_{j != i} intf(i, j)

Under one-gang-at-a-time the machine then behaves as a single core with
the virtual gangs as its tasks, so formation quality is measured by the
total inflated utilization sum C_v / P_v — lower is better — which
core/rta.py turns into acceptance verdicts (vgang/rta.py).

Heuristics (the evaluation grid compares all of them against the
singleton baseline = plain RT-Gang):

* ``first_fit_decreasing``  — sort by width, descending; place each gang
  in the first open virtual gang that fits.
* ``best_fit_utilization``  — sort by utilization, descending; place in
  the open virtual gang left tightest (least spare width) by the merge.
* ``interference_aware``    — the paper's pairing rule: co-locate
  low-memory-intensity gangs. Greedy cost comparison of "open a new
  virtual gang" (cost = solo utilization) vs "merge into an existing
  one" (cost = utilization increase, which embeds the pairwise
  interference inflation), taking the cheapest feasible option.
* ``exhaustive_optimal``    — exact minimizer of total inflated
  utilization by set-partition enumeration per period group; small-N
  cross-check baseline for the heuristics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.gang import RTTask
from repro.core.rta import gang_wcet
from repro.core.sim import PairwiseInterference, no_interference


def pair_factor(interference: PairwiseInterference,
                victim: str, aggressor: str,
                victim_cores: Optional[Sequence[int]] = None,
                aggressor_cores: Optional[Sequence[int]] = None) -> float:
    """Worst-case pairwise slowdown factor, placement-aware when the
    model is.

    Location-free models are called exactly as before —
    ``interference(victim, aggressor)`` — so every existing verdict is
    bit-identical. A ``distance_interference``-decorated model
    (core/memmodel.py, ``fn.distance_aware``) takes the core distance as
    a third argument; the analysis must then price the worst pair over
    the two units' core placements, matching the per-(victim, core)
    slowdown the MemoryModel applies at runtime."""
    if getattr(interference, "distance_aware", False):
        if not victim_cores or not aggressor_cores:
            raise ValueError(
                "distance-aware interference model needs core placements "
                "for both the victim and the aggressor")
        return max(interference(victim, aggressor, abs(v - a))
                   for v in victim_cores for a in aggressor_cores)
    return interference(victim, aggressor)


def member_core_blocks(members: Sequence[RTTask]) -> Dict[str, tuple]:
    """Member name -> consecutive core block, mirroring the layout
    ``sched.remap_members`` dispatches (cursor from core 0, members in
    list order). This is the placement the placement-aware analysis
    prices."""
    blocks: Dict[str, tuple] = {}
    cursor = 0
    for m in members:
        blocks[m.name] = tuple(range(cursor, cursor + m.n_threads))
        cursor += m.n_threads
    return blocks


@dataclasses.dataclass
class VirtualGang:
    """A fixed-composition set of member gangs scheduled as one unit."""
    name: str
    members: List[RTTask]
    prio: int = 0

    def __post_init__(self):
        periods = {m.period for m in self.members}
        if len(periods) != 1:
            raise ValueError(
                f"virtual gang {self.name!r} mixes periods {periods}")

    @property
    def period(self) -> float:
        return self.members[0].period

    @property
    def width(self) -> int:
        return sum(m.n_threads for m in self.members)

    @property
    def mem_budget(self) -> float:
        """Tolerable best-effort traffic while this virtual gang runs =
        the most sensitive member's budget."""
        return min(m.mem_budget for m in self.members)

    def inflated_wcet(self,
                      interference: PairwiseInterference = no_interference
                      ) -> float:
        """C_v: the gang runs until its slowest member finishes, each
        member slowed by the worst pairwise factor over co-members —
        the same max-of-pairwise model the simulator engines apply.

        Distance-aware models are priced over the consecutive core
        blocks ``sched.remap_members`` will dispatch; location-free
        models take the exact pre-existing call path."""
        blocks = (member_core_blocks(self.members)
                  if getattr(interference, "distance_aware", False)
                  else None)
        worst = 0.0
        for m in self.members:
            slow = 1.0
            for o in self.members:
                if o is not m:
                    if blocks is None:
                        slow = max(slow, interference(m.name, o.name))
                    else:
                        slow = max(slow, pair_factor(
                            interference, m.name, o.name,
                            blocks[m.name], blocks[o.name]))
            worst = max(worst, gang_wcet(m) * slow)
        return worst

    def utilization(self,
                    interference: PairwiseInterference = no_interference
                    ) -> float:
        return self.inflated_wcet(interference) / self.period


def total_vgang_utilization(
        vgangs: Sequence[VirtualGang],
        interference: PairwiseInterference = no_interference) -> float:
    """Single-core-equivalent utilization of the formed taskset — the
    formation objective (lower packs better)."""
    return sum(vg.utilization(interference) for vg in vgangs)


def intensity_interference(tasks: Sequence[RTTask],
                           gamma: float = 0.5) -> PairwiseInterference:
    """Pairwise interference derived from each gang's declared memory
    intensity: an aggressor at intensity s slows any victim by
    1 + gamma * s (slowdown tracks the co-runner's traffic,
    arXiv:1912.10959 §III)."""
    intensity = {t.name: t.mem_intensity for t in tasks}

    def f(victim: str, aggressor: str) -> float:
        return 1.0 + gamma * intensity.get(aggressor, 0.0)
    return f


def critical_member(vg: VirtualGang,
                    interference: PairwiseInterference = no_interference
                    ) -> RTTask:
    """RTG-throttle's protected member (arXiv:1912.10959 §IV-C): the
    member whose interference-inflated solo term C_i * max_j intf(i, j)
    bounds the virtual gang's WCET — the bottleneck whose timing the
    sibling regulation protects. Ties break by name (deterministic
    across the policy, the RTA and the evaluation grid)."""
    blocks = (member_core_blocks(vg.members)
              if getattr(interference, "distance_aware", False)
              else None)

    def key(m: RTTask):
        slow = 1.0
        for o in vg.members:
            if o is not m:
                if blocks is None:
                    slow = max(slow, interference(m.name, o.name))
                else:
                    slow = max(slow, pair_factor(
                        interference, m.name, o.name,
                        blocks[m.name], blocks[o.name]))
        return (-gang_wcet(m) * slow, m.name)
    return min(vg.members, key=key)


def rtg_sibling_budget(vg: VirtualGang,
                       interference: PairwiseInterference = no_interference,
                       interval: float = 1.0) -> float:
    """Per-core traffic budget RTG-throttle enforces on the critical
    member's sibling members (and best-effort fillers): the critical
    member's declared tolerable traffic when it has one, else its
    bandwidth headroom — a critical member of intensity s leaves
    (1 - s) * interval units per regulation window for everyone else."""
    crit = critical_member(vg, interference)
    if crit.mem_budget > 0.0:
        return crit.mem_budget
    return max(0.0, 1.0 - crit.mem_intensity) * interval


def singleton_vgangs(tasks: Sequence[RTTask]) -> List[VirtualGang]:
    """The degenerate formation: every real gang is its own virtual gang.
    This *is* plain RT-Gang — vgang RTA on it must reproduce core/rta.py
    verdicts exactly (tests/test_vgang.py)."""
    return [VirtualGang(name=t.name, members=[t], prio=t.prio)
            for t in tasks]


def _feasible(members: List[RTTask], extra: RTTask, n_cores: int,
              interference: PairwiseInterference) -> bool:
    """Capacity + self-schedulability guard for merging ``extra``."""
    cand = VirtualGang(name="_cand", members=members + [extra])
    if cand.width > n_cores:
        return False
    return cand.inflated_wcet(interference) <= cand.period + 1e-12


def _by_period(tasks: Sequence[RTTask]) -> Dict[float, List[RTTask]]:
    groups: Dict[float, List[RTTask]] = {}
    for t in tasks:
        groups.setdefault(t.period, []).append(t)
    return groups


def _finalize(bins: List[List[RTTask]]) -> List[VirtualGang]:
    out = []
    for members in bins:
        name = "+".join(m.name for m in members)
        out.append(VirtualGang(name=name, members=list(members)))
    return out


def first_fit_decreasing(
        tasks: Sequence[RTTask], n_cores: int,
        interference: PairwiseInterference = no_interference
        ) -> List[VirtualGang]:
    """FFD by gang width (ties: heavier utilization first)."""
    vgangs: List[VirtualGang] = []
    for period, group in sorted(_by_period(tasks).items()):
        bins: List[List[RTTask]] = []
        order = sorted(group, key=lambda t: (-t.n_threads,
                                             -gang_wcet(t) / t.period,
                                             t.name))
        for t in order:
            for b in bins:
                if _feasible(b, t, n_cores, interference):
                    b.append(t)
                    break
            else:
                bins.append([t])
        vgangs.extend(_finalize(bins))
    return vgangs


def best_fit_utilization(
        tasks: Sequence[RTTask], n_cores: int,
        interference: PairwiseInterference = no_interference
        ) -> List[VirtualGang]:
    """Best-fit by utilization: heaviest gangs placed first, each into
    the feasible virtual gang the merge leaves tightest (least spare
    width; ties broken toward the higher-utilization bin)."""
    vgangs: List[VirtualGang] = []
    for period, group in sorted(_by_period(tasks).items()):
        bins: List[List[RTTask]] = []
        order = sorted(group, key=lambda t: (-gang_wcet(t) / t.period,
                                             -t.n_threads, t.name))
        for t in order:
            best: Optional[List[RTTask]] = None
            best_key = None
            for b in bins:
                if not _feasible(b, t, n_cores, interference):
                    continue
                spare = n_cores - (sum(m.n_threads for m in b)
                                   + t.n_threads)
                util = sum(gang_wcet(m) / m.period for m in b)
                key = (spare, -util)
                if best_key is None or key < best_key:
                    best, best_key = b, key
            if best is None:
                bins.append([t])
            else:
                best.append(t)
        vgangs.extend(_finalize(bins))
    return vgangs


def interference_aware(
        tasks: Sequence[RTTask], n_cores: int,
        interference: PairwiseInterference = no_interference
        ) -> List[VirtualGang]:
    """The paper's pairing rule: co-locate low-memory-intensity gangs.

    Greedy over gangs in increasing memory intensity: merging task t
    into bin b costs util(b + t) - util(b) (the interference inflation
    is embedded in the inflated WCET), opening a new bin costs t's solo
    utilization; take the cheapest feasible option. Two memory-hungry
    gangs inflate each other, making their merge expensive — so they
    land in separate virtual gangs and the low-intensity gangs pack
    together."""
    vgangs: List[VirtualGang] = []
    for period, group in sorted(_by_period(tasks).items()):
        bins: List[List[RTTask]] = []
        order = sorted(group, key=lambda t: (t.mem_intensity,
                                             -t.n_threads, t.name))
        for t in order:
            solo_cost = gang_wcet(t) / t.period
            best: Optional[List[RTTask]] = None
            best_cost = solo_cost
            for b in bins:
                if not _feasible(b, t, n_cores, interference):
                    continue
                before = VirtualGang("_b", list(b)).utilization(interference)
                after = VirtualGang("_a", b + [t]).utilization(interference)
                cost = after - before
                if cost < best_cost - 1e-15:
                    best, best_cost = b, cost
            if best is None:
                bins.append([t])
            else:
                best.append(t)
        vgangs.extend(_finalize(bins))
    return vgangs


def _partitions(items: List[RTTask]) -> Iterable[List[List[RTTask]]]:
    """All set partitions (Bell-number enumeration, small N only)."""
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for p in _partitions(rest):
        for i in range(len(p)):
            yield p[:i] + [p[i] + [first]] + p[i + 1:]
        yield p + [[first]]


def exhaustive_optimal(
        tasks: Sequence[RTTask], n_cores: int,
        interference: PairwiseInterference = no_interference,
        max_group: int = 9) -> List[VirtualGang]:
    """Exact minimizer of total inflated utilization over all feasible
    partitions, per period group (groups pack independently). Bell(9) =
    21147 partitions per group — a cross-check baseline, not a scalable
    heuristic."""
    vgangs: List[VirtualGang] = []
    for period, group in sorted(_by_period(tasks).items()):
        if len(group) > max_group:
            raise ValueError(
                f"exhaustive formation capped at {max_group} same-period "
                f"gangs; got {len(group)} at period {period}")
        best_bins: Optional[List[List[RTTask]]] = None
        best_util = float("inf")
        for p in _partitions(list(group)):
            util = 0.0
            ok = True
            for members in p:
                vg = VirtualGang("_p", members)
                if vg.width > n_cores or \
                        vg.inflated_wcet(interference) > vg.period + 1e-12:
                    ok = False
                    break
                util += vg.utilization(interference)
            if ok and util < best_util - 1e-15:
                best_bins, best_util = p, util
        if best_bins is None:
            # no feasible grouping at all (some gang unschedulable solo):
            # fall back to singletons so RTA reports the failure
            best_bins = [[t] for t in group]
        vgangs.extend(_finalize(best_bins))
    return vgangs


HEURISTICS: Dict[str, Callable] = {
    "ffd": first_fit_decreasing,
    "bestfit": best_fit_utilization,
    "intfaware": interference_aware,
}


def assign_priorities(vgangs: Sequence[VirtualGang]) -> List[VirtualGang]:
    """Rate-monotonic priorities over virtual gangs — shorter period =
    higher priority, ties broken by name so every virtual gang gets a
    distinct priority (gang identity, RT-Gang §IV-E)."""
    order = sorted(vgangs, key=lambda vg: (vg.period, vg.name))
    out = []
    for rank, vg in enumerate(order):
        out.append(dataclasses.replace(vg, prio=len(order) - rank))
    return out


# --------------------------------------------------------------------------
# Strict partitioning (arXiv:2403.10726): instead of merging gangs into
# virtual gangs and inflating WCETs, carve the machine into static,
# disjoint core partitions and bin-pack whole gangs into them. Gangs of
# one partition never co-run (each occupies its whole partition while
# executing), so intra-partition interference vanishes and the analysis
# collapses to classic uniprocessor fixed-priority RTA per partition —
# while the partitions themselves run concurrently, paying only the
# cross-partition interference inflation.
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Partition:
    """A static block of cores and the gangs pinned to it."""
    name: str
    cores: Tuple[int, ...]
    gangs: List[RTTask]

    @property
    def size(self) -> int:
        return len(self.cores)

    def utilization(self) -> float:
        """Plain (uninflated) uniprocessor-equivalent utilization."""
        return sum(gang_wcet(g) / g.period for g in self.gangs)


@dataclasses.dataclass
class Partitioning:
    """A strict partitioning of the machine: disjoint consecutive core
    blocks, every gang assigned to exactly one."""
    n_cores: int
    partitions: List[Partition]

    @property
    def gangs(self) -> List[RTTask]:
        return [g for p in self.partitions for g in p.gangs]


def strict_partition(tasks: Sequence[RTTask], n_cores: int,
                     interference: PairwiseInterference = no_interference
                     ) -> Partitioning:
    """Bin-pack gangs into static core partitions (arXiv:2403.10726).

    Deterministic worst-fit decreasing: gangs sorted by (width desc,
    utilization desc, name) each go to the feasible option — an existing
    partition at least as wide as the gang, or a new partition carved
    from the remaining cores — that leaves the target partition least
    loaded. While spare cores remain this opens new partitions (maximal
    parallelism); once the machine is carved up, the remaining gangs
    balance load across the partitions wide enough to host them.

    Priorities are global rate-monotonic (period, name) — distinct
    everywhere, hence valid locally within each partition. Core blocks
    are consecutive, so a distance-aware interference model prices
    cross-partition pairs over real placements (``pair_factor``).

    The ``interference`` argument is accepted for signature parity with
    the virtual-gang heuristics; packing itself needs no factors because
    intra-partition interference is structurally zero.
    """
    del interference  # intra-partition interference is zero by design
    order = sorted(tasks, key=lambda t: (-t.n_threads,
                                         -gang_wcet(t) / t.period,
                                         t.name))
    bins: List[Tuple[int, List[RTTask]]] = []   # (size, members)
    used = 0
    for t in order:
        w = t.n_threads
        if w > n_cores:
            raise ValueError(
                f"gang {t.name!r} is wider ({w}) than the machine "
                f"({n_cores} cores)")
        u = gang_wcet(t) / t.period
        options = []
        for i, (size, members) in enumerate(bins):
            if w <= size:
                load = sum(gang_wcet(m) / m.period for m in members)
                options.append((load + u, 1, i))
        if used + w <= n_cores:
            # a fresh partition is always the least-loaded option; the
            # flag 0 prefers it on (impossible in practice) ties
            options.append((u, 0, len(bins)))
        _, is_existing, i = min(options)
        if is_existing:
            bins[i][1].append(t)
        else:
            bins.append((w, [t]))
            used += w
    # global RM priorities, distinct via name tiebreak
    ranked = sorted((g for _, members in bins for g in members),
                    key=lambda g: (g.period, g.name))
    prio_of = {g.uid: len(ranked) - r for r, g in enumerate(ranked)}
    partitions: List[Partition] = []
    cursor = 0
    for idx, (size, members) in enumerate(bins):
        cores = tuple(range(cursor, cursor + size))
        cursor += size
        gangs = [dataclasses.replace(g, prio=prio_of[g.uid])
                 for g in members]
        partitions.append(Partition(name=f"P{idx}", cores=cores,
                                    gangs=gangs))
    return Partitioning(n_cores=n_cores, partitions=partitions)
