"""The virtual-gang acceptance-ratio evaluation grid (arXiv:1912.10959
§VI) on the exact event engine — the headline artifact: RT-Gang vs
virtual-gang acceptance curves.

Grid axes:

* machine size M in {4, 8, 16} cores;
* gang-width distribution: ``light`` (narrow gangs, w <= M/4), ``mixed``
  (w <= M/2), ``heavy`` (M/2 <= w <= M);
* total gang utilization level (the single-core-equivalent sum C_i/P_i
  — note plain RT-Gang can never accept a set above 1.0, while packed
  virtual gangs can, which is the entire point of the follow-up paper);
* policy: ``rtgang`` (singletons = the baseline), the formation
  heuristics ``ffd``, ``bestfit``, ``intfaware`` (formation.py),
  ``rtgT`` — RTG-throttle (arXiv:1912.10959 §IV-C): interference-aware
  formation dispatched with per-member bandwidth regulation (critical
  member unthrottled, siblings capped; sched.py) and priced by the
  duty-cycle RTA bound (rta.accepts_rtg_throttle). Its curve shows the
  cost of intra-gang isolation: it trails ``intfaware`` where sibling
  stalls stretch the gang, and protects the critical member's WCET in
  exchange. ``rtgT+dr`` adds dynamic reclaiming (DESIGN.md §7.5): a
  sibling finishing its job mid-window donates its unspent quota to
  stalled co-siblings, and acceptance is priced by
  min(static, reclaim_wcet) — the exchange gate keeps the static bound
  sound under donation, so this column dominates ``rtgT`` at every
  utilization level while recovering part of the isolation cost.
  ``part`` is strict partitioning (arXiv:2403.10726, DESIGN.md §15):
  gangs bin-packed into static core partitions, priced by
  partition-local uniprocessor RTA with cross-partition inflation —
  a structurally different answer to the same underutilization
  problem, interesting exactly where it crosses ``rtgT+dr``.

Every policy column is a ``PolicyFamily`` from the registry
(vgang/family.py); this module only iterates whatever families the
requested column labels name.

Per (M, dist, util) cell — one batched worker process per cell, like the
per-level batching of launch/sweep.py --schedulability — n random
tasksets are drawn (UUniFast utilizations, per-distribution widths,
random memory intensities feeding ``intensity_interference``), each
heuristic forms virtual gangs, and vgang RTA (rta.py) yields the
acceptance verdict. The first ``sim_check`` tasksets of every cell are
also run through the event engine under VirtualGangPolicy and checked
against the RTA verdict (RTA accept must imply a miss-free simulation —
soundness violations are counted and must be zero).

    PYTHONPATH=src python -m repro.vgang.grid [--smoke] [--seed 0]
        [--cores 4,8,16] [--dists light,mixed,heavy] [--n 50]
        [--utils 0.4,0.8,...] [--heuristics ffd,bestfit,intfaware]
        [--sim-check 2] [--gamma 0.5] [--out results/vgang]

Writes results/vgang/grid_{M}c_{dist}.json per (M, dist) plus a
combined results/vgang/summary.json; plot/print the curves with
``python examples/schedulability_analysis.py --vgang``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing
import os
import random
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.gang import RTTask
from repro.experiment import (ExperimentConfig, GRID_SMOKE_OVERRIDES,
                              add_flags, cli_main, default_grid_config,
                              derive_flags)
from repro.launch.sweep import ROOT, taskset_seed, uunifast
from repro.obs.margins import merge_margins, overall
# re-exported for the pre-registry import sites (the canonical homes of
# the column labels are vgang/family.py and experiment.py)
from repro.vgang.family import (BASELINE_COLUMN, PART_COLUMN,  # noqa: F401
                                RECLAIM_COLUMN, RTG_COLUMN, get_family,
                                grid_columns)
from repro.vgang.formation import intensity_interference

OUT_DEFAULT = os.path.join(ROOT, "results", "vgang")

# gang-width distributions (paper §VI: light/mixed/heavy mixes)
def _width_light(rng: random.Random, m: int) -> int:
    return rng.randint(1, max(1, m // 4))


def _width_mixed(rng: random.Random, m: int) -> int:
    return rng.randint(1, max(1, m // 2))


def _width_heavy(rng: random.Random, m: int) -> int:
    return rng.randint(max(1, m // 2), m)


WIDTH_DISTS = {"light": _width_light, "mixed": _width_mixed,
               "heavy": _width_heavy}

PERIODS = (20.0, 40.0, 80.0)      # small pool -> same-period groups form


def random_vgang_taskset(rng: random.Random, n_cores: int, n_tasks: int,
                         total_util: float, dist: str = "mixed"
                         ) -> List[RTTask]:
    """Random gang taskset for the grid: UUniFast utilizations, widths
    from the named distribution, memory intensity in [0, 1] (drives the
    interference model and the interference-aware heuristic). Releases
    are synchronous (offset 0 = the critical instant) and priorities are
    provisional — formation reassigns them per virtual gang."""
    width_of = WIDTH_DISTS[dist]
    utils = uunifast(rng, n_tasks, total_util)
    tasks = []
    for i in range(n_tasks):
        period = rng.choice(PERIODS)
        width = width_of(rng, n_cores)
        wcet = max(utils[i] * period, 1e-3)
        tasks.append(RTTask(
            name=f"g{i}", wcet=wcet, period=period,
            cores=tuple(range(width)), prio=n_tasks - i,
            mem_intensity=rng.random()))
    return tasks


def n_tasks_for(n_cores: int) -> int:
    """More cores -> more gangs to pack (4 -> 5, 8 -> 7, 16 -> 11)."""
    return 3 + (n_cores + 1) // 2


@dataclasses.dataclass(frozen=True)
class GridCell:
    """One (cores, dist, util) pool-worker payload.  A typed payload —
    not a bare tuple — so a misspelled or stale field fails loudly at
    construction (``TypeError`` naming the unknown keyword) instead of
    silently shifting positional slots."""
    seed: int
    n_cores: int
    dist: str
    util: float
    n_sets: int
    # full ordered column list (family names, vgang/family.py); must
    # include the "rtgang" baseline — use grid_columns() to build it
    columns: Tuple[str, ...]
    sim_check: int
    gamma: float
    cycles: float
    scalar_rta: bool = False
    trace: bool = False
    dt: Optional[float] = None


def _grid_cell(cell: GridCell) -> Dict:
    """Pool worker: one (cores, dist, util) cell — all n tasksets, all
    heuristics, in one process.

    Three phases (DESIGN.md §13.3): (1) draw + form every taskset (the
    per-taskset rng streams are seeded by ``taskset_seed``, so the
    restructure cannot perturb them); (2) one shard-batched RTA call
    per policy column over all n tasksets at once
    (``batched_accepts`` / ``batched_accepts_rtg_throttle``,
    bit-identical to the scalar loop — ``cell.scalar_rta`` keeps the
    old per-taskset loop reachable for benchmarking); (3) the first
    ``sim_check`` tasksets get event-engine sim-checks (default
    ``trace=False`` — their verdicts come from the batched arrays, and
    the SimResult counters are trace-independent)."""
    (seed, n_cores, dist, util, n_sets, columns, sim_check, gamma,
     cycles, scalar_rta) = (
        cell.seed, cell.n_cores, cell.dist, cell.util, cell.n_sets,
        cell.columns, cell.sim_check, cell.gamma, cell.cycles,
        cell.scalar_rta)
    fams = {h: get_family(h) for h in columns}
    sim_accept = {h: 0 for h in columns}
    margins: Dict[str, Dict] = {h: {} for h in columns}
    sim_n = 0
    soundness_violations = 0
    util_gain = 0.0
    t0 = time.time()
    n_tasks = n_tasks_for(n_cores)
    # ---- phase 1: draw + form all n tasksets ------------------------
    drawn: List[Tuple[List[RTTask], object, Dict[str, object]]] = []
    for k in range(n_sets):
        rng = random.Random(taskset_seed(seed, k, util))
        tasks = random_vgang_taskset(rng, n_cores, n_tasks, util, dist)
        intf = intensity_interference(tasks, gamma)
        # form + assign once per distinct form_key: families sharing a
        # formation (rtgT and rtgT+dr both analyze the packed intfaware
        # set) share the *identical* assigned objects, so the rtgT
        # columns' static per-window bounds memoize across the two
        # columns (the cache keys on object identity)
        formed_of_key: Dict[str, object] = {}
        formed: Dict[str, object] = {}
        for h in columns:
            fam = fams[h]
            got = formed_of_key.get(fam.form_key)
            if got is None:
                got = fam.assign(fam.form(tasks, n_cores, intf))
                formed_of_key[fam.form_key] = got
            formed[h] = got
        # formation objective: utilization gain of the best packing vs
        # the singleton baseline (families without a comparable packing
        # objective — partition-kind — are excluded from the min)
        utils = {h: fams[h].utilization(formed[h], intf)
                 for h in columns if fams[h].utilization is not None}
        util_gain += utils["rtgang"] - min(utils.values())
        drawn.append((tasks, intf, formed))
    # ---- phase 2: one shard-batched RTA call per policy column ------
    # one-gang-at-a-time: only same-vgang members ever co-run, so intf
    # only enters through each vgang's inflated WCET (and inflates
    # nothing for the rtgang singleton baseline); the rtgT column
    # prices sibling regulation on top of that, rtgT+dr the reclaiming
    # dispatch (min(static, reclaim)), and part the partition-local
    # uniprocessor RTA with cross-partition inflation
    t_rta = time.time()
    intfs = [d[1] for d in drawn]
    wcet_cache: Dict = {}
    verdicts: Dict[str, List[bool]] = {}
    for h in columns:
        fam = fams[h]
        vsets = [d[2][h] for d in drawn]
        if scalar_rta:
            verdicts[h] = [bool(fam.verdict(v, i))
                           for v, i in zip(vsets, intfs)]
        else:
            verdicts[h] = fam.batched_verdict(vsets, intfs,
                                              wcet_cache=wcet_cache)
    accept = {h: sum(verdicts[h]) for h in columns}
    wall_rta = time.time() - t_rta
    # ---- phase 3: event-engine sim-checks (trace=False) -------------
    for k in range(min(sim_check, n_sets)):
        sim_n += 1
        tasks, intf, formed = drawn[k]
        for h in columns:
            fam = fams[h]
            rta_ok = verdicts[h][k]
            policy = fam.make_policy(formed[h], n_cores, intf)
            horizon = cycles * max(t.period for t in tasks)
            # accepted sets carry per-member analytic bounds into
            # the run: measured response vs bound (DESIGN.md §12.3)
            # rolls up into the per-cell rta_margin record, and a
            # negative margin is a soundness violation caught here
            bounds = policy.member_bounds() if rta_ok else None
            if bounds and any(b is None for b in bounds.values()):
                bounds = None
            sim_kw = {} if cell.dt is None else {"dt": cell.dt}
            r = policy.simulate(horizon, rta_bounds=bounds,
                                trace=cell.trace, **sim_kw)
            sim_ok = sum(r.deadline_misses.values()) == 0
            sim_accept[h] += sim_ok
            if rta_ok and not sim_ok:
                soundness_violations += 1
            if r.rta_margins:
                merge_margins(margins[h], r.rta_margins)
    return {
        "n_cores": n_cores, "dist": dist, "util": util, "n": n_sets,
        "accept": {h: c / n_sets for h, c in accept.items()},
        "sim_accept": ({h: c / sim_n for h, c in sim_accept.items()}
                       if sim_n else None),
        "sim_n": sim_n,
        "rta_margin": ({h: (overall(m) if m else None)
                        for h, m in margins.items()} if sim_n else None),
        "soundness_violations": soundness_violations,
        "mean_util_gain": round(util_gain / n_sets, 4),
        "wall_s": round(time.time() - t0, 3),
        "wall_rta_s": round(wall_rta, 4),
    }


def _skipped_row(cell: GridCell) -> Dict:
    """Placeholder row for a cell that failed/timed out twice: keeps the
    curve files structurally complete; consumers (print_curves, the
    plotting example) filter on the ``skipped`` flag."""
    n_cores, dist, util = cell.n_cores, cell.dist, cell.util
    return {"n_cores": n_cores, "dist": dist, "util": util, "n": 0,
            "accept": None, "sim_accept": None, "sim_n": 0,
            "rta_margin": None, "soundness_violations": 0,
            "mean_util_gain": None, "wall_s": None, "wall_rta_s": None,
            "skipped": True}


def _dispatch(cells: Sequence[GridCell], procs: int,
              cell_timeout: Optional[float],
              worker=_grid_cell) -> Tuple[List[Dict], List[Tuple]]:
    """Run the cell workers with per-cell hardening: a cell that exceeds
    ``cell_timeout`` seconds (or raises) is retried once in a fresh
    pool; a second failure skips the cell (placeholder row + log line)
    instead of hanging or killing the whole grid. ``worker`` is
    injectable for tests. With ``procs <= 1`` (in-process) a timeout
    cannot be enforced preemptively, so only the raise-retry applies."""
    out: Dict[int, Dict] = {}
    todo = list(range(len(cells)))
    pool = None
    try:
        for attempt in (0, 1):
            if not todo:
                break
            failed: List[int] = []
            if procs > 1:
                # the pool is reused across retry rounds; it is only
                # torn down and rebuilt when a cell *timed out* — a
                # timed-out worker is still running and must be reaped
                # (terminate), whereas a raising worker returned
                # normally and its process is fine to reuse
                timed_out = False
                if pool is None:
                    pool = multiprocessing.Pool(min(procs, len(todo)))
                asyncs = [(i, pool.apply_async(worker, (cells[i],)))
                          for i in todo]
                for i, a in asyncs:
                    try:
                        out[i] = a.get(cell_timeout)
                    except Exception as e:
                        is_to = isinstance(e, multiprocessing.TimeoutError)
                        timed_out = timed_out or is_to
                        print(f"grid: cell {cells[i].n_cores}c/"
                              f"{cells[i].dist}/u={cells[i].util} "
                              f"{'timed out' if is_to else f'failed ({e!r})'}"
                              f" (attempt {attempt + 1})",
                              file=sys.stderr)
                        failed.append(i)
                # a cell may have finished while we waited on a later
                # one: harvest before declaring it failed
                for i, a in asyncs:
                    if i in failed and a.ready():
                        try:
                            out[i] = a.get(0)
                            failed.remove(i)
                        except Exception:
                            pass
                if timed_out:
                    pool.terminate()
                    pool.join()
                    pool = None
            else:
                for i in todo:
                    try:
                        out[i] = worker(cells[i])
                    except Exception as e:
                        print(f"grid: cell {cells[i].n_cores}c/"
                              f"{cells[i].dist}/u={cells[i].util} "
                              f"failed ({e!r}) "
                              f"(attempt {attempt + 1})", file=sys.stderr)
                        failed.append(i)
            todo = failed
    finally:
        if pool is not None:
            pool.terminate()
            pool.join()
    skipped = [cells[i] for i in todo]
    for i in todo:
        out[i] = _skipped_row(cells[i])
        print(f"grid: cell {cells[i].n_cores}c/{cells[i].dist}/"
              f"u={cells[i].util} skipped after retry", file=sys.stderr)
    return [out[i] for i in range(len(cells))], skipped


def _margin_headline(results: Sequence[Dict]) -> Dict:
    """Grid-wide RTA-margin rollup for summary.json: jobs checked,
    worst observed margin (ms), and the negative count — which must be
    zero (a negative margin is a bound the measured run broke)."""
    recs = [rec for r in results if r.get("rta_margin")
            for rec in r["rta_margin"].values() if rec]
    worsts = [m["worst_margin"] for m in recs
              if m["worst_margin"] is not None]
    return {"jobs": sum(m["jobs"] for m in recs),
            "worst_margin": min(worsts) if worsts else None,
            "negative": sum(m["negative"] for m in recs)}


def _part_crossover(results: Sequence[Dict]) -> Dict:
    """The headline comparison for the strict-partitioning column:
    per-cell acceptance of ``part`` vs ``rtgT+dr`` — how many cells
    each side wins and the largest gaps either way (summary.json
    ``part_vs_reclaim``)."""
    wins, losses = [], []
    for r in results:
        acc = r.get("accept")
        if not acc or PART_COLUMN not in acc or RECLAIM_COLUMN not in acc:
            continue
        delta = acc[PART_COLUMN] - acc[RECLAIM_COLUMN]
        row = {"n_cores": r["n_cores"], "dist": r["dist"],
               "util": r["util"], PART_COLUMN: acc[PART_COLUMN],
               RECLAIM_COLUMN: acc[RECLAIM_COLUMN],
               "delta": round(delta, 4)}
        if delta > 1e-12:
            wins.append(row)
        elif delta < -1e-12:
            losses.append(row)
    wins.sort(key=lambda r: -r["delta"])
    losses.sort(key=lambda r: r["delta"])
    return {"cells_won": len(wins), "cells_lost": len(losses),
            "top_wins": wins[:3], "top_losses": losses[:3]}


def _grid_config(cores, dists, utils, heuristics, n_per_cell, sim_check,
                 gamma, cycles, seed, processes, out_dir, cell_timeout,
                 scalar_rta, trace, dt) -> ExperimentConfig:
    """The resolved ExperimentConfig a direct ``run_grid(...)`` call
    denotes — so programmatic runs stamp the same provenance digest a
    ``--config`` / legacy-CLI run with equal knobs would."""
    base = default_grid_config()
    return base.merged({
        "taskset": {"cores": list(cores), "dists": list(dists),
                    "utils": list(utils), "n_per_point": n_per_cell,
                    "gamma": gamma, "seed": seed},
        "policy": {"heuristics": list(heuristics)},
        "engine": {"sim_check": sim_check, "cycles": cycles,
                   "processes": processes or 0,
                   "cell_timeout": cell_timeout or 0.0,
                   "scalar_rta": scalar_rta, "trace": trace, "dt": dt},
        "output": {"out": None if out_dir == OUT_DEFAULT else out_dir},
    })


def run_grid(cores: Sequence[int] = (4, 8, 16),
             dists: Sequence[str] = ("light", "mixed", "heavy"),
             utils: Sequence[float] = (0.4, 0.7, 0.9, 1.0, 1.1, 1.2, 1.4,
                                       1.6, 2.0),
             heuristics: Sequence[str] = ("ffd", "bestfit", "intfaware",
                                          RTG_COLUMN, RECLAIM_COLUMN,
                                          PART_COLUMN),
             n_per_cell: int = 50, sim_check: int = 2, gamma: float = 0.5,
             cycles: float = 20.0, seed: int = 0,
             processes: Optional[int] = None,
             out_dir: str = OUT_DEFAULT,
             cell_timeout: Optional[float] = None,
             scalar_rta: bool = False,
             trace: bool = False, dt: Optional[float] = None,
             worker=_grid_cell,
             config: Optional[ExperimentConfig] = None) -> Dict:
    """Run the full grid; one batched worker per (cores, dist, util)
    cell; aggregate and write per-(cores, dist) curve files + summary.

    ``config`` is the resolved ExperimentConfig this run realizes (the
    CLI shell passes it down); when None one is synthesized from the
    arguments, so every summary/curve file carries a ``config_digest``
    regardless of entry point."""
    if config is None:
        config = _grid_config(cores, dists, utils, heuristics, n_per_cell,
                              sim_check, gamma, cycles, seed, processes,
                              out_dir, cell_timeout, scalar_rta, trace, dt)
    digest = config.content_digest()
    # resolve the requested labels against the family registry: the
    # singleton baseline always leads under its curve label "rtgang"
    # (so `--heuristics rtgang,ffd` means what it reads as), plain
    # formation heuristics keep request order, special policy columns
    # (rtgT, rtgT+dr, part) land last in canonical order; unknown
    # labels raise with the registered names
    columns = grid_columns(heuristics)
    cells = [GridCell(seed=seed, n_cores=m, dist=d, util=u,
                      n_sets=n_per_cell, columns=columns,
                      sim_check=sim_check, gamma=gamma, cycles=cycles,
                      scalar_rta=scalar_rta, trace=trace, dt=dt)
             for m in cores for d in dists for u in utils]
    procs = processes or min(multiprocessing.cpu_count(), 16, len(cells))
    procs = max(1, min(procs, len(cells)))
    t0 = time.time()
    results, skipped = _dispatch(cells, procs, cell_timeout, worker)

    summary = {"seed": seed, "gamma": gamma, "cycles": cycles,
               "n_per_cell": n_per_cell, "sim_check": sim_check,
               "heuristics": list(columns),
               "utils": list(utils),
               "config": config.to_dict(),
               "config_digest": digest,
               "soundness_violations": sum(r["soundness_violations"]
                                           for r in results),
               "rta_margin": _margin_headline(results),
               "skipped_cells": len(skipped),
               "wall_s": round(time.time() - t0, 3),
               "files": []}
    if PART_COLUMN in columns and RECLAIM_COLUMN in columns:
        summary["part_vs_reclaim"] = _part_crossover(results)
    os.makedirs(out_dir, exist_ok=True)
    for m in cores:
        for d in dists:
            rows = [r for r in results
                    if r["n_cores"] == m and r["dist"] == d]
            rows.sort(key=lambda r: r["util"])
            path = os.path.join(out_dir, f"grid_{m}c_{d}.json")
            with open(path, "w") as f:
                json.dump({"n_cores": m, "dist": d, "seed": seed,
                           "gamma": gamma, "config_digest": digest,
                           "rows": rows}, f, indent=1)
            summary["files"].append(os.path.relpath(path, ROOT))
    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    return {"summary": summary, "results": results}


def print_curves(results: List[Dict]) -> None:
    keys = sorted({(r["n_cores"], r["dist"]) for r in results})
    for m, d in keys:
        rows = sorted((r for r in results
                       if r["n_cores"] == m and r["dist"] == d
                       and not r.get("skipped")),
                      key=lambda r: r["util"])
        if not rows:
            continue
        heuristics = list(rows[0]["accept"])
        print(f"\n{m} cores, {d} widths (acceptance ratio per util):")
        header = "  util  " + "".join(f"{h:>10}" for h in heuristics)
        print(header)
        for r in rows:
            line = f"  {r['util']:<5.2f} " + "".join(
                f"{r['accept'][h]:>10.2f}" for h in heuristics)
            print(line)


# config fields this surface exposes as flags (DESIGN.md §14.2); the
# aliases preserve the legacy spellings
GRID_FLAG_PATHS = (
    "smoke", "taskset.cores", "taskset.dists", "taskset.utils",
    "policy.heuristics", "taskset.n_per_point", "engine.sim_check",
    "taskset.gamma", "engine.cycles", "taskset.seed", "engine.processes",
    "engine.cell_timeout", "engine.scalar_rta", "engine.trace",
    "engine.dt", "engine.backend", "output.out")
GRID_FLAG_ALIASES = {"taskset.n_per_point": "--n",
                     "engine.processes": "--procs"}
GRID_FLAG_HELPS = {
    "smoke": "CI cell: 2 utils x 6 policy columns x 4 cores (expands to "
             "explicit fields, then clears itself — a --smoke run and "
             "configs/experiments/grid_smoke.json resolve to the same "
             "axes)",
    "engine.cell_timeout": "per-cell wall-clock timeout in seconds (one "
                           "retry, then the cell is skipped); 0 = none",
    "engine.scalar_rta": "per-taskset scalar RTA loop instead of the "
                         "shard-batched kernel (DESIGN.md §13) — same "
                         "verdicts bit-for-bit, for benchmarking",
    "output.out": "output directory (default results/vgang)",
}


def resolve_grid_config(argv: Optional[Sequence[str]] = None
                        ) -> ExperimentConfig:
    """base grid config <- --config FILE <- explicit flags, with the
    --smoke sugar expanded into its explicit fields."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    base = default_grid_config()
    flags = derive_flags(ExperimentConfig, GRID_FLAG_PATHS,
                         aliases=GRID_FLAG_ALIASES, helps=GRID_FLAG_HELPS)
    add_flags(ap, flags, base)
    cfg = cli_main(ap, flags, base, argv, expected_kind="grid")
    if cfg.smoke:
        cfg = cfg.merged(GRID_SMOKE_OVERRIDES).merged({"smoke": False})
    return cfg


def main(argv: Optional[Sequence[str]] = None) -> int:
    cfg = resolve_grid_config(argv)
    if cfg.engine.backend != "auto":
        # pool workers inherit via fork; see analysis/batched_rta
        os.environ["REPRO_RTA_BACKEND"] = cfg.engine.backend
    out_dir = cfg.output.out or OUT_DEFAULT
    out = run_grid(
        cores=cfg.taskset.cores, dists=cfg.taskset.dists,
        utils=cfg.taskset.utils, heuristics=cfg.policy.heuristics,
        n_per_cell=cfg.taskset.n_per_point,
        sim_check=cfg.engine.sim_check, gamma=cfg.taskset.gamma,
        cycles=cfg.engine.cycles, seed=cfg.taskset.seed,
        processes=cfg.engine.processes or None, out_dir=out_dir,
        cell_timeout=cfg.engine.cell_timeout or None,
        scalar_rta=cfg.engine.scalar_rta, trace=cfg.engine.trace,
        dt=cfg.engine.dt, config=cfg)
    print_curves(out["results"])
    s = out["summary"]
    print(f"\nwrote {len(s['files'])} curve files + summary to "
          f"{out_dir} in {s['wall_s']}s "
          f"(soundness violations: {s['soundness_violations']}, "
          f"skipped cells: {s['skipped_cells']}, "
          f"config {s['config_digest'][:12]})")
    return 1 if s["soundness_violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
