"""PolicyFamily registry — the pluggable policy stack (DESIGN.md §15).

Every admission policy the evaluation surfaces compare (the rtgang
singleton baseline, the formation heuristics, RTG-throttle with and
without reclaiming, strict partitioning) is one ``PolicyFamily``: a
bundle of

* a formation strategy (``form``/``assign``) producing the policy's
  scheduling units from a raw taskset,
* an RTA verdict (``verdict`` scalar, ``batched_verdict`` through
  analysis/batched_rta.py — bit-identical pair),
* a Simulator policy constructor (``make_policy``) for the
  event-engine soundness cross-check, and
* the column label the grid/sweep/bench surfaces report under.

The consumers (vgang/grid.py, launch/sweep.py,
benchmarks/bench_executor_vgang.py, experiment.PolicyStackConfig
validation) iterate the registry instead of special-casing column
strings, so a new policy lands by registering one family here.

``form_key`` lets families share one formed object per taskset: the
rtgT and rtgT+dr columns both analyze the packed ``intfaware``
formation, and sharing the *identical* object (not an equal copy) keeps
the id()-keyed priority/WCET memoization in the grid exact — the same
sharing the pre-registry code did by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.vgang import rta as vrta
from repro.vgang.formation import (HEURISTICS, assign_priorities,
                                   singleton_vgangs, strict_partition,
                                   total_vgang_utilization)
from repro.vgang.sched import StrictPartitionPolicy, VirtualGangPolicy

BASELINE_COLUMN = "rtgang"
RTG_COLUMN = "rtgT"
RECLAIM_COLUMN = "rtgT+dr"
PART_COLUMN = "part"

# special policy columns appended after the plain formation heuristics,
# in this canonical report order (grid_columns)
SPECIAL_COLUMNS = (RTG_COLUMN, RECLAIM_COLUMN, PART_COLUMN)


def _identity(formed):
    return formed


@dataclasses.dataclass(frozen=True)
class PolicyFamily:
    """One admission policy as the evaluation surfaces consume it.

    ``formed`` below is whatever ``assign(form(tasks, n_cores, intf))``
    produced — a ``List[VirtualGang]`` for vgang-kind families, a
    ``Partitioning`` for partition-kind ones. Callable contracts:

    * ``form(tasks, n_cores, interference) -> units``
    * ``assign(units) -> units``  (priority assignment; identity when
      ``form`` already assigns)
    * ``verdict(formed, interference) -> bool``
    * ``batched_verdict(formed_sets, interferences, wcet_cache=None)
      -> List[bool]``  (bit-identical to mapping ``verdict``; families
      without a per-unit WCET memo ignore ``wcet_cache``)
    * ``bounds(formed, interference, interval=, blocking=) ->
      Dict[name, row]``  (per-unit WCRT rows, row["wcrt"]/"ok")
    * ``make_policy(formed, n_cores, interference) -> policy`` with
      ``.simulate(horizon, rta_bounds=, trace=, dt=)`` and
      ``.member_bounds()`` — the soundness cross-check driver
    * ``utilization(formed, interference) -> float`` or None — the
      formation objective (single-core-equivalent utilization); None
      means the family has no comparable packing objective and is
      excluded from the grid's best-formation utilization gain
    """
    name: str
    form_key: str
    form: Callable
    verdict: Callable
    batched_verdict: Callable
    bounds: Callable
    make_policy: Callable
    assign: Callable = _identity
    utilization: Optional[Callable] = None
    kind: str = "vgang"
    throttled: bool = False
    aligned_releases_only: bool = False


FAMILIES: Dict[str, PolicyFamily] = {}


def register_family(family: PolicyFamily) -> PolicyFamily:
    """Add a family to the registry (its ``name`` becomes the column)."""
    if family.name in FAMILIES:
        raise ValueError(
            f"policy family {family.name!r} is already registered")
    FAMILIES[family.name] = family
    return family


def family_names() -> Tuple[str, ...]:
    """Registered column labels, registration order."""
    return tuple(FAMILIES)


def get_family(name: str) -> PolicyFamily:
    f = FAMILIES.get(name)
    if f is None:
        raise ValueError(
            f"unknown policy family {name!r}; "
            f"known: {list(FAMILIES)}")
    return f


def grid_columns(heuristics: Sequence[str]) -> Tuple[str, ...]:
    """Canonical grid column order for a requested heuristics list: the
    rtgang baseline first, plain formation heuristics in request order,
    then the special policy columns (rtgT, rtgT+dr, part) in canonical
    order — exactly the ordering the pre-registry grid produced."""
    for h in heuristics:
        get_family(h)
    plain = [h for h in heuristics
             if h != BASELINE_COLUMN and h not in SPECIAL_COLUMNS]
    specials = [s for s in SPECIAL_COLUMNS if s in heuristics]
    return (BASELINE_COLUMN, *plain, *specials)


# ---------------------------------------------------------------------------
# The built-in families


def _vgang_family(name: str, form: Callable, form_key: Optional[str] = None,
                  rtg: bool = False, dr: bool = False) -> PolicyFamily:
    """Family over virtual-gang formation: plain vgang RTA, or the
    RTG-throttle duty-cycle pricing (``rtg``, with reclaim credit under
    ``dr``), simulated through VirtualGangPolicy."""
    if rtg:
        def verdict(formed, intf):
            return vrta.accepts_rtg_throttle(formed, intf, reclaim=dr)

        def batched_verdict(formed_sets, intfs, wcet_cache=None):
            return vrta.batched_accepts_rtg_throttle(
                formed_sets, intfs, reclaim=dr, wcet_cache=wcet_cache)

        def bounds(formed, intf, interval=1.0, blocking=0.0):
            return vrta.schedulable_rtg_throttle(
                formed, intf, interval=interval, blocking=blocking,
                reclaim=dr)
    else:
        def verdict(formed, intf):
            return vrta.accepts(formed, intf)

        def batched_verdict(formed_sets, intfs, wcet_cache=None):
            del wcet_cache
            return vrta.batched_accepts(formed_sets, intfs)

        def bounds(formed, intf, interval=1.0, blocking=0.0):
            del interval
            return vrta.schedulable_vgangs(formed, intf,
                                           blocking=blocking)

    def make_policy(formed, n_cores, intf):
        return VirtualGangPolicy(formed, n_cores, intf, auto_prio=False,
                                 rtg_throttle=rtg, reclaim=dr)

    return PolicyFamily(
        name=name, form_key=form_key or name, form=form,
        assign=assign_priorities, verdict=verdict,
        batched_verdict=batched_verdict, bounds=bounds,
        make_policy=make_policy, utilization=total_vgang_utilization,
        kind="vgang", throttled=rtg, aligned_releases_only=rtg)


def _rtgang_form(tasks, n_cores, interference):
    del n_cores, interference
    return singleton_vgangs(tasks)


register_family(_vgang_family(BASELINE_COLUMN, _rtgang_form))
for _h, _fn in HEURISTICS.items():
    register_family(_vgang_family(_h, _fn))
register_family(_vgang_family(RTG_COLUMN, HEURISTICS["intfaware"],
                              form_key="intfaware", rtg=True))
register_family(_vgang_family(RECLAIM_COLUMN, HEURISTICS["intfaware"],
                              form_key="intfaware", rtg=True, dr=True))


def _part_verdict(formed, intf):
    return vrta.accepts_partitioned(formed, intf)


def _part_batched(formed_sets, intfs, wcet_cache=None):
    del wcet_cache
    return vrta.batched_accepts_partitioned(formed_sets, intfs)


def _part_bounds(formed, intf, interval=1.0, blocking=0.0):
    del interval
    return vrta.schedulable_partitions(formed, intf, blocking=blocking)


def _part_policy(formed, n_cores, intf):
    del n_cores  # the Partitioning carries the machine size
    return StrictPartitionPolicy(formed, intf)


register_family(PolicyFamily(
    name=PART_COLUMN, form_key=PART_COLUMN, form=strict_partition,
    verdict=_part_verdict, batched_verdict=_part_batched,
    bounds=_part_bounds, make_policy=_part_policy,
    kind="partition"))
