"""VirtualGangPolicy — run formed virtual gangs on the simulator engines.

The glock state machine already *is* the virtual-gang mechanism: gangs
sharing one RT priority join the running gang (Algorithm 1 line 14-15,
RT-Gang §IV-E). The policy therefore:

* flattens the formed virtual gangs into member RTTasks sharing the
  virtual gang's priority, remapped onto disjoint core blocks and
  released synchronously — so the event engine (core/events.py)
  dispatches each virtual gang as a unit;
* acts as the Simulator's ``budget_policy``: while a virtual gang holds
  the lock, every core's throttle budget is the minimum over the
  *currently co-running* members (per-member budgets — when a short
  member finishes its job mid-gang, the surviving members' higher
  tolerance is applied immediately). Cores occupied by members carry no
  best-effort work, so they are set unconstrained. This replaces the
  engine's default leader-budget rule, which would arbitrarily pick
  whichever member acquired the lock first.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.gang import BETask, RTTask
from repro.core.rta import gang_wcet
from repro.core.sim import (PairwiseInterference, SimResult, Simulator,
                            no_interference)
from repro.vgang.formation import (Partitioning, VirtualGang,
                                   assign_priorities, critical_member,
                                   rtg_sibling_budget)
from repro.vgang.rta import (schedulable_partitions,
                             schedulable_rtg_throttle, schedulable_vgangs)


def remap_members(vg: VirtualGang) -> List[RTTask]:
    """Flatten one virtual gang's members onto a disjoint core/lane
    block starting at 0: members share the vgang's priority and a
    synchronous release (zero offset), so the glock dispatches them as
    one unit. uids are preserved across the remap, so per-member tables
    keyed by uid (budgets, critical-member choice) remain valid. Shared
    by the simulator policy below and GangExecutor.submit_vgang
    (DESIGN.md §2.4)."""
    out = []
    cursor = 0
    for m in vg.members:
        cores = tuple(range(cursor, cursor + m.n_threads))
        cursor += m.n_threads
        wpc = None
        if m.wcet_per_core:
            wpc = {new: m.wcet_per_core.get(old, m.wcet)
                   for old, new in zip(m.cores, cores)}
        out.append(dataclasses.replace(
            m, prio=vg.prio, cores=cores, release_offset=0.0,
            wcet_per_core=wpc))
    return out


class VirtualGangPolicy:
    """Budget policy + taskset builder for a formed virtual-gang set.

    ``vgangs`` need distinct priorities; pass ``auto_prio=True`` (default)
    to (re)assign rate-monotonic priorities via formation.assign_priorities.

    ``rtg_throttle=True`` enables RTG-throttle (arXiv:1912.10959 §IV-C):
    while a virtual gang runs, its *critical* member (formation.
    critical_member — the interference-inflated bottleneck) executes
    unthrottled, and every sibling member's cores are capped at the
    critical member's tolerable traffic (formation.rtg_sibling_budget).
    Sibling RT threads charge their ``traffic_rate`` against that cap
    through the engines' MemoryModel and pause mid-job when they trip;
    once the critical member finishes its job, the surviving members run
    unthrottled (the protection target is gone). vgang/rta.py prices
    this regime with a per-window duty-cycle WCET bound
    (``rtg_throttle_wcet``).
    """

    def __init__(self, vgangs: Sequence[VirtualGang], n_cores: int,
                 interference: PairwiseInterference = no_interference,
                 auto_prio: bool = True, rtg_throttle: bool = False,
                 reclaim: bool = False, **unknown):
        if unknown:
            raise TypeError(
                f"VirtualGangPolicy: unknown option(s) {sorted(unknown)}; "
                f"valid options: interference, auto_prio, rtg_throttle, "
                f"reclaim")
        prios = [vg.prio for vg in vgangs]
        if auto_prio and len(set(prios)) != len(prios):
            vgangs = assign_priorities(vgangs)
        self.vgangs: List[VirtualGang] = list(vgangs)
        self.n_cores = n_cores
        self.interference = interference
        self.rtg_throttle = rtg_throttle
        # mid-window donation (DESIGN.md §7.5): completed sibling cores
        # keep their per-window grant so stalled co-siblings can draw it
        self.reclaim = reclaim
        for vg in self.vgangs:
            if vg.width > n_cores:
                raise ValueError(f"virtual gang {vg.name!r} needs "
                                 f"{vg.width} cores, machine has {n_cores}")
        self._by_prio: Dict[int, VirtualGang] = {
            vg.prio: vg for vg in self.vgangs}
        if len(self._by_prio) != len(self.vgangs):
            raise ValueError("virtual gangs must have distinct priorities")
        self._members: List[RTTask] = []
        self._budget: Dict[int, float] = {}       # member uid -> budget
        self._critical: Dict[int, int] = {}       # vgang prio -> member uid
        # vgang prio -> remapped core footprint of its sibling members
        # (reclaim: a completed sibling's cores keep the cap so their
        # unspent window quota stays donatable)
        self._sibling_cores: Dict[int, tuple] = {}
        # (vgang prio, regulation interval) -> sibling cap: the headroom
        # fallback scales with the interval, and one policy object may
        # drive both a simulator (interval in sim-ms) and an executor
        # (interval in wall-s)
        self._sibling_budget: Dict[tuple, float] = {}
        for vg in self.vgangs:
            self._critical[vg.prio] = critical_member(
                vg, self.interference).uid
        for vg in self.vgangs:
            # members of one virtual gang release together (one unit)
            sib_cores = []
            for member in remap_members(vg):
                self._members.append(member)
                self._budget[member.uid] = member.mem_budget
                if member.uid != self._critical[vg.prio]:
                    sib_cores.extend(member.cores)
            self._sibling_cores[vg.prio] = tuple(sib_cores)

    # ---- taskset --------------------------------------------------------
    def taskset(self) -> List[RTTask]:
        """Flattened member gangs: shared per-vgang priority, disjoint
        core blocks, synchronous release — feed to Simulator."""
        return list(self._members)

    # ---- BudgetPolicy interface (Simulator.budget_policy) ---------------
    def apply(self, g, reg):
        """Set throttle budgets from the running virtual gang's live
        members (called by both engines whenever scheduling settles).
        Returns the cores whose throttle regime changed (the event
        engine folds them into its dirty-core set)."""
        if not g.held_flag or g.leader is None:
            return reg.set_gang_budget(None)
        vg = self._by_prio.get(g.leader.prio)
        if vg is None:                   # foreign gang: default rule
            occupied = {th.core for th in g.gthreads if th is not None}
            return reg.set_core_budgets({c: None for c in occupied},
                                        default=g.leader.mem_budget)
        live_uids = {th.task.uid for th in g.gthreads if th is not None}
        budgets = [self._budget[u] for u in live_uids if u in self._budget]
        if not budgets:                  # hand-off instant: whole gang
            budgets = [m.mem_budget for m in vg.members]
        floor = min(budgets)
        occupied = {th.core for th in g.gthreads if th is not None}
        crit_uid = self._critical.get(vg.prio)
        if self.rtg_throttle and crit_uid in live_uids:
            # RTG-throttle: the critical member runs unthrottled, every
            # other live member's cores (and the best-effort fillers)
            # are capped at the critical member's tolerable traffic
            cap = self._sibling_budget.get((vg.prio, reg.interval))
            if cap is None:
                cap = rtg_sibling_budget(vg, self.interference,
                                         reg.interval)
                self._sibling_budget[(vg.prio, reg.interval)] = cap
            per_core = {th.core: (None if th.task.uid == crit_uid
                                  else cap)
                        for th in g.gthreads if th is not None}
            if self.reclaim:
                # a completed sibling's cores keep the cap: the static
                # bound granted them Q per window, and that unspent
                # grant is exactly what the donation pool hands to
                # stalled co-siblings (DESIGN.md §7.5)
                for c in self._sibling_cores[vg.prio]:
                    per_core.setdefault(c, cap)
            return reg.set_core_budgets(per_core,
                                        default=min(floor, cap))
        return reg.set_core_budgets({c: None for c in occupied},
                                    default=floor)

    # ---- drivers --------------------------------------------------------
    def build_simulator(self, be_tasks: Sequence[BETask] = (),
                        interference: Optional[PairwiseInterference] = None,
                        dt: Optional[float] = None,
                        **kwargs) -> Simulator:
        """Simulator over the flattened members with this policy wired in
        (dt=None: exact event engine)."""
        interval = kwargs.get("regulation_interval", 1.0)
        if self.rtg_throttle and interval > 0.0:
            # declaration sanity on the *intensity* scale (every sibling
            # traffic_rate <= 1, so a core generates at most ``interval``
            # units per window): a sibling cap above that can never trip
            # — almost certainly a bytes-scale budget (executor units)
            # fed to a simulator. The executor's byte-scale caps are
            # deliberately exempt: there the comparison is meaningless.
            for vg in self.vgangs:
                sibs = [m for m in vg.members
                        if m.uid != self._critical[vg.prio]]
                if not sibs or any(m.traffic_rate > 1.0 for m in sibs):
                    continue
                cap = rtg_sibling_budget(vg, self.interference, interval)
                if cap > interval + 1e-12:
                    raise ValueError(
                        f"virtual gang {vg.name!r}: RTG-throttle sibling "
                        f"budget {cap} exceeds the regulation interval "
                        f"{interval} — on the intensity scale "
                        f"(traffic_rate <= 1) a core cannot generate "
                        f"that much traffic per window, so the cap can "
                        f"never take effect; declare the critical "
                        f"member's mem_budget in simulator units")
        return Simulator(self.n_cores, self.taskset(), be_tasks=be_tasks,
                         interference=interference or self.interference,
                         rt_gang_enabled=True, dt=dt,
                         budget_policy=self, reclaim=self.reclaim,
                         **kwargs)

    def simulate(self, horizon: float, **kwargs) -> SimResult:
        return self.build_simulator(**kwargs).run(horizon)

    def build_executor(self, fns, *, n_lanes: Optional[int] = None,
                       n_jobs: Optional[int] = None,
                       time_scale: float = 1e-3,
                       bytes_per_quantum=None, **kwargs):
        """GangExecutor (core/executor.py) over the formed set: each
        virtual gang's members land on disjoint lane blocks via
        ``remap_members`` and this policy is installed as the executor's
        ``budget_policy``, so the glock's gang-change hook enforces
        min-over-live-member lane budgets — and, under ``rtg_throttle``,
        uncaps the critical member's lanes while admission-capping
        sibling lanes (and their best-effort fillers) at
        ``rtg_sibling_budget``. Give sibling jobs a ``bytes_per_quantum``
        (name -> bytes) to have their quanta admission-charged against
        that cap. ``fns`` maps member task name -> callable(lane, idx);
        ``time_scale`` converts task-time (sim ms) to wall seconds.

        Note: executor-side RTG-throttle wants members with a declared
        positive ``mem_budget`` (bytes per regulation window); the
        headroom fallback ``(1 - intensity) * interval`` is in simulator
        units."""
        from repro.core.executor import GangExecutor
        ex = GangExecutor(
            self.n_cores if n_lanes is None else n_lanes,
            budget_policy=self, reclaim=self.reclaim, **kwargs)
        for vg in self.vgangs:
            ex.submit_vgang(vg, fns, n_jobs=n_jobs,
                            time_scale=time_scale,
                            bytes_per_quantum=bytes_per_quantum)
        return ex

    def rta(self) -> Dict[str, Dict]:
        """Vgang RTA verdicts for the formed set (vgang/rta.py)."""
        return schedulable_vgangs(self.vgangs, self.interference)

    def member_bounds(self, interval: float = 1.0,
                      blocking: float = 0.0) -> Dict[str, float]:
        """Per-*member* analytic response-time bounds (ms) for this
        policy's regime — the vgang-level WCRT from the pricing the
        policy actually enforces (plain vgang RTA, or the RTG-throttle
        duty-cycle bound with reclaim credit when armed). Every member
        of a virtual gang completes within the vgang's WCRT (members
        release together and the vgang retires as a unit), so the vgang
        bound is a sound per-member bound. Feed the result to
        ``Simulator(rta_bounds=...)`` for measured-margin accounting
        (DESIGN.md §12.3)."""
        if self.rtg_throttle:
            verdicts = schedulable_rtg_throttle(
                self.vgangs, self.interference, interval=interval,
                blocking=blocking, reclaim=self.reclaim)
        else:
            verdicts = schedulable_vgangs(self.vgangs, self.interference,
                                          blocking=blocking)
        out: Dict[str, float] = {}
        for vg in self.vgangs:
            wcrt = verdicts[vg.name]["wcrt"]
            for m in vg.members:
                out[m.name] = wcrt
        return out


class StrictPartitionPolicy:
    """Run a strict ``Partitioning`` (formation.strict_partition) on the
    simulator engines — the runtime counterpart of
    ``rta.schedulable_partitions``.

    Dispatch model: RT-Gang's global one-gang-at-a-time lock is *off*
    (``rt_gang_enabled=False`` — plain per-core preemptive fixed
    priority). Each gang is widened to occupy its entire partition with
    a uniform per-thread WCET of ``gang_wcet`` and a synchronous zero
    release offset. Within a partition all gangs then share the same
    core set with globally distinct RM priorities, so on every core of
    the partition the highest-priority pending gang wins — the gangs of
    one partition serialize exactly one-after-another, which is the
    uniprocessor the partition RTA analyzes. The widened threads stay in
    lockstep (identical WCET, release, priority on every core, and the
    MemoryModel never slows a thread by its own gang's occupancy), so
    widening adds no execution time: a gang's threads finish exactly
    when its critical thread would.

    Soundness of the RTA cross-check: at any instant the co-runners a
    gang observes are a subset of the gangs of *other* partitions, so
    the engines' occupancy slowdown never exceeds the cross-partition
    inflation factor the analysis prices into C'.
    """

    def __init__(self, partitioning: Partitioning,
                 interference: PairwiseInterference = no_interference,
                 **unknown):
        if unknown:
            raise TypeError(
                f"StrictPartitionPolicy: unknown option(s) "
                f"{sorted(unknown)}; valid options: interference")
        if getattr(interference, "distance_aware", False):
            raise ValueError(
                "StrictPartitionPolicy cannot dispatch a distance-aware "
                "interference model: gangs are widened to their whole "
                "partition, so runtime distances differ from the declared "
                "member placements — price placement analytically via "
                "rta.schedulable_partitions/pair_factor instead")
        self.partitioning = partitioning
        self.n_cores = partitioning.n_cores
        self.interference = interference
        self._members: List[RTTask] = []
        for p in partitioning.partitions:
            for g in p.gangs:
                self._members.append(dataclasses.replace(
                    g, cores=tuple(p.cores), release_offset=0.0,
                    wcet=gang_wcet(g), wcet_per_core=None))

    def taskset(self) -> List[RTTask]:
        """Widened gangs: each pinned to its whole partition, distinct
        global RM priorities — feed to Simulator."""
        return list(self._members)

    def build_simulator(self, be_tasks: Sequence[BETask] = (),
                        interference: Optional[PairwiseInterference] = None,
                        dt: Optional[float] = None,
                        **kwargs) -> Simulator:
        """Simulator over the widened gangs, RT-Gang lock disabled —
        per-core preemptive FP is exactly partition-local uniprocessor
        scheduling here (dt=None: exact event engine)."""
        return Simulator(self.n_cores, self.taskset(), be_tasks=be_tasks,
                         interference=interference or self.interference,
                         rt_gang_enabled=False, dt=dt, **kwargs)

    def simulate(self, horizon: float, **kwargs) -> SimResult:
        return self.build_simulator(**kwargs).run(horizon)

    def rta(self) -> Dict[str, Dict]:
        """Partition RTA verdicts for this partitioning (vgang/rta.py)."""
        return schedulable_partitions(self.partitioning, self.interference)

    def member_bounds(self, interval: float = 1.0,
                      blocking: float = 0.0) -> Dict[str, float]:
        """Per-gang analytic response-time bounds from the partition RTA
        — same contract as VirtualGangPolicy.member_bounds (the
        ``interval`` argument is accepted for signature parity; strict
        partitioning has no regulation windows)."""
        del interval
        verdicts = schedulable_partitions(self.partitioning,
                                          self.interference,
                                          blocking=blocking)
        return {name: v["wcrt"] for name, v in verdicts.items()}
