"""Declarative config base (DESIGN.md §14), after OLMo-core's ``Config``
/ praxis ``base_model`` idiom: every experiment knob is a field on a
small frozen-at-validation dataclass, serialization is total and stable,
and a wrong key or value fails loudly with the dotted path that caused
it instead of being silently absorbed.

The base class supplies, for any ``@dataclass`` subclass:

* ``to_dict`` / ``from_dict`` — declaration-order dicts; tuples render
  as JSON lists and hydrate back to tuples; nested ``Config`` fields
  hydrate recursively; unknown keys raise ``ConfigurationError`` naming
  the offending dotted path and the valid keys.
* ``to_json`` / ``from_json`` / ``save`` / ``load`` — the JSON faces of
  the same contract (round-trip stable byte-for-byte).
* ``content_digest`` — sha256 over the canonical (sorted-key, compact)
  JSON of ``to_dict()``; the provenance stamp every results artifact
  carries, so a result file names exactly the resolved config that
  produced it regardless of whether it came from ``--config`` or legacy
  flags.
* ``merged`` — overlay a partial dict (e.g. a ``--config`` file) onto a
  base config, re-running validation; ``with_value`` — replace one
  dotted-path field (the CLI-override primitive).

Validation: subclasses override ``validate`` and raise
``ConfigurationError`` with the *local* field path; nested hydration /
``merged`` / ``with_value`` prefix the enclosing path, so the user
always sees e.g. ``policy.reclaim: ...`` no matter how deep the field
sits.  ``__post_init__`` coerces list->tuple and int->float by
annotation and then validates, so directly constructed configs obey the
same contract as hydrated ones.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import typing
from typing import Any, Dict, List, Type, TypeVar, Union

C = TypeVar("C", bound="Config")


class ConfigurationError(ValueError):
    """A config field is missing, unknown, ill-typed, or invalid.

    ``path`` is the dotted field path (``"policy.reclaim"``); the
    message is rendered as ``"<path>: <problem>"``."""

    def __init__(self, message: str, path: str = ""):
        self.bare_message = message
        self.path = path
        super().__init__(f"{path}: {message}" if path else message)

    def at(self, prefix: str) -> "ConfigurationError":
        """The same error, re-anchored under ``prefix`` (used by nested
        hydration so the full dotted path survives re-raising)."""
        sub = f"{prefix}.{self.path}" if self.path else prefix
        return ConfigurationError(self.bare_message, sub)


def _type_hints(cls: type) -> Dict[str, Any]:
    # cached per class: get_type_hints resolves the postponed
    # annotations (from __future__ import annotations) once
    hints = getattr(cls, "_config_hints", None)
    if hints is None or hints[0] is not cls:
        hints = (cls, typing.get_type_hints(cls))
        cls._config_hints = hints
    return hints[1]


def _coerce(value: Any, ann: Any, path: str) -> Any:
    """Coerce ``value`` to annotation ``ann`` (the closed field-type set
    configs use: scalars, Optional[scalar], Tuple[scalar, ...], nested
    Config) or raise ConfigurationError at ``path``."""
    origin = typing.get_origin(ann)
    if origin is Union:                       # Optional[T]
        args = [a for a in typing.get_args(ann) if a is not type(None)]
        if value is None:
            return None
        return _coerce(value, args[0], path)
    if origin is tuple:
        if not isinstance(value, (list, tuple)):
            raise ConfigurationError(
                f"expected a list, got {value!r}", path)
        elem = typing.get_args(ann)[0]
        return tuple(_coerce(v, elem, f"{path}[{i}]")
                     for i, v in enumerate(value))
    if isinstance(ann, type) and issubclass(ann, Config):
        if isinstance(value, ann):
            return value
        if isinstance(value, dict):
            return ann.from_dict(value, _path=path)
        raise ConfigurationError(
            f"expected a {ann.__name__} mapping, got {value!r}", path)
    if ann is bool:
        if not isinstance(value, bool):
            raise ConfigurationError(
                f"expected a bool, got {value!r}", path)
        return value
    if ann is int:
        if isinstance(value, bool) or not isinstance(value, int):
            raise ConfigurationError(
                f"expected an int, got {value!r}", path)
        return value
    if ann is float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ConfigurationError(
                f"expected a number, got {value!r}", path)
        return float(value)
    if ann is str:
        if not isinstance(value, str):
            raise ConfigurationError(
                f"expected a string, got {value!r}", path)
        return value
    raise ConfigurationError(
        f"unsupported config field type {ann!r}", path)


@dataclasses.dataclass
class Config:
    """Base for all experiment configs; subclasses are ``@dataclass``es
    whose fields use the closed type set documented in ``_coerce``."""

    def __post_init__(self):
        hints = _type_hints(type(self))
        for f in dataclasses.fields(self):
            object.__setattr__(self, f.name, _coerce(
                getattr(self, f.name), hints[f.name], f.name))
        self.validate()

    def validate(self) -> None:
        """Override: raise ConfigurationError with the *local* field
        path; enclosing configs prefix their own."""

    # ---- serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Declaration-order dict; nested configs and tuples collapse to
        plain dicts and lists (JSON-total by construction)."""
        out: Dict[str, Any] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, Config):
                v = v.to_dict()
            elif isinstance(v, tuple):
                v = list(v)
            out[f.name] = v
        return out

    @classmethod
    def from_dict(cls: Type[C], data: Dict[str, Any],
                  _path: str = "") -> C:
        """Hydrate, rejecting unknown keys and re-anchoring any nested
        validation error under ``_path``."""
        if not isinstance(data, dict):
            raise ConfigurationError(
                f"expected a mapping, got {data!r}", _path)
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - fields)
        if unknown:
            raise ConfigurationError(
                f"unknown key(s) {unknown}; valid keys: {sorted(fields)}",
                _path or cls.__name__)
        try:
            return cls(**data)
        except ConfigurationError as e:
            raise (e.at(_path) if _path else e) from None

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1) + "\n"

    @classmethod
    def from_json(cls: Type[C], text: str) -> C:
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls: Type[C], path: str) -> C:
        with open(path) as f:
            return cls.from_json(f.read())

    # ---- provenance -----------------------------------------------------
    def content_digest(self) -> str:
        """sha256 hex digest of the canonical JSON rendering — the
        provenance stamp in BENCH_*.json / results/vgang headers.  Two
        runs resolve to the same digest iff every field (after defaults,
        file overlay, and CLI overrides) is equal."""
        canon = json.dumps(self.to_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()

    # ---- overlay / override ---------------------------------------------
    def merged(self: C, overrides: Dict[str, Any], _path: str = "") -> C:
        """A copy with ``overrides`` (a possibly-partial nested dict,
        e.g. a parsed ``--config`` file) overlaid; unknown keys rejected
        and validation re-run at every level."""
        if not isinstance(overrides, dict):
            raise ConfigurationError(
                f"expected a mapping, got {overrides!r}", _path)
        fields = {f.name for f in dataclasses.fields(self)}
        unknown = sorted(set(overrides) - fields)
        if unknown:
            raise ConfigurationError(
                f"unknown key(s) {unknown}; valid keys: {sorted(fields)}",
                _path or type(self).__name__)
        kwargs: Dict[str, Any] = {}
        for k, v in overrides.items():
            sub = f"{_path}.{k}" if _path else k
            cur = getattr(self, k)
            if isinstance(cur, Config) and isinstance(v, dict):
                kwargs[k] = cur.merged(v, _path=sub)
            else:
                kwargs[k] = v
        try:
            return dataclasses.replace(self, **kwargs)
        except ConfigurationError as e:
            raise (e.at(_path) if _path else e) from None

    def with_value(self: C, path: str, value: Any) -> C:
        """A copy with the dotted-path field replaced (the CLI-override
        primitive); validation re-runs on every enclosing config."""
        head, _, rest = path.partition(".")
        if head not in {f.name for f in dataclasses.fields(self)}:
            raise ConfigurationError(f"unknown field {head!r}", path)
        if rest:
            child = getattr(self, head)
            if not isinstance(child, Config):
                raise ConfigurationError(
                    f"{head!r} is not a nested config", path)
            try:
                new_child = child.with_value(rest, value)
            except ConfigurationError as e:
                raise e.at(head) from None
            return dataclasses.replace(self, **{head: new_child})
        return dataclasses.replace(self, **{head: value})

    def value_at(self, path: str) -> Any:
        """Read the field at a dotted path (CLI help defaults)."""
        obj: Any = self
        for part in path.split("."):
            obj = getattr(obj, part)
        return obj

    @classmethod
    def annotation_at(cls, path: str) -> Any:
        """Resolved type annotation of the field at a dotted path."""
        node: type = cls
        parts = path.split(".")
        for i, part in enumerate(parts):
            hints = _type_hints(node)
            if part not in hints:
                raise ConfigurationError(f"unknown field {part!r}", path)
            ann = hints[part]
            if i + 1 < len(parts):
                if not (isinstance(ann, type) and issubclass(ann, Config)):
                    raise ConfigurationError(
                        f"{part!r} is not a nested config", path)
                node = ann
        return ann
