"""CLI derivation for experiment surfaces (DESIGN.md §14.2).

Every surface declares *which* ``ExperimentConfig`` fields it exposes
(a list of dotted paths plus legacy-spelling aliases) and this module
derives the argparse flags from the field annotations — tuple fields
parse comma lists, bools become store-true flags, Optional scalars
parse their inner type.  Resolution order (later wins):

    surface base config  <  --config FILE (partial overlay)  <
    explicitly-passed flags

Flags not passed on the command line never touch the config (an UNSET
sentinel distinguishes "absent" from "passed the default value"), so a
``--config`` file's values survive unless explicitly overridden — and a
legacy invocation with no ``--config`` resolves to exactly the surface
base config plus its flags, making the two spellings digest-identical
when they describe the same run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import typing
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.experiment.config import Config, ConfigurationError
from repro.experiment.experiment import ExperimentConfig


class _Unset:
    def __repr__(self):
        return "<unset>"


UNSET = _Unset()


@dataclasses.dataclass(frozen=True)
class Flag:
    path: str                       # dotted config path
    option: str                     # e.g. "--sim-check"
    dest: str                       # argparse dest
    parse: Optional[Callable]       # None for store-true bools
    help: str = ""


def _tuple_parser(elem: type) -> Callable:
    def parse(text: str):
        if text == "":
            return ()
        return tuple(elem(part) for part in text.split(","))
    return parse


def _parser_for(ann: Any, path: str) -> Optional[Callable]:
    """Command-line string parser for an annotation; None = store-true
    bool."""
    origin = typing.get_origin(ann)
    if origin is Union:             # Optional[T]
        inner = [a for a in typing.get_args(ann) if a is not type(None)]
        return _parser_for(inner[0], path)
    if origin is tuple:
        return _tuple_parser(typing.get_args(ann)[0])
    if ann is bool:
        return None
    if ann in (int, float, str):
        return ann
    raise ConfigurationError(
        f"cannot derive a CLI flag for field type {ann!r}", path)


def derive_flags(config_cls: type, include: Sequence[str],
                 aliases: Optional[Dict[str, str]] = None,
                 helps: Optional[Dict[str, str]] = None) -> List[Flag]:
    """One Flag per dotted path in ``include``, named after the leaf
    field (``engine.sim_check`` -> ``--sim-check``) unless aliased
    (``taskset.n_per_point`` -> ``--n`` preserves the legacy CLI)."""
    aliases = aliases or {}
    helps = helps or {}
    flags: List[Flag] = []
    seen: Dict[str, str] = {}
    for path in include:
        ann = config_cls.annotation_at(path)
        option = aliases.get(
            path, "--" + path.split(".")[-1].replace("_", "-"))
        if option in seen:
            raise ConfigurationError(
                f"flag {option} for {path!r} collides with {seen[option]!r}"
                " — alias one of them", path)
        seen[option] = path
        flags.append(Flag(path=path, option=option,
                          dest="cfg_" + path.replace(".", "__"),
                          parse=_parser_for(ann, path),
                          help=helps.get(path, "")))
    return flags


def add_flags(parser: argparse.ArgumentParser, flags: Sequence[Flag],
              base: Config, config_flag: bool = True) -> None:
    """Register the derived flags (all defaulting to UNSET) plus the
    ``--config`` overlay flag; help strings show the surface defaults."""
    if config_flag:
        parser.add_argument(
            "--config", default=None, metavar="FILE",
            help="experiment config JSON (configs/experiments/); "
                 "explicitly-passed flags override its fields")
    for f in flags:
        default = base.value_at(f.path)
        helptext = f.help or f"{f.path}"
        if isinstance(default, tuple):
            shown = ",".join(str(v) for v in default)
        else:
            shown = default
        if f.parse is None:
            parser.add_argument(f.option, dest=f.dest, default=UNSET,
                                action="store_const", const=True,
                                help=f"{helptext} (default: {shown})")
        else:
            parser.add_argument(f.option, dest=f.dest, default=UNSET,
                                type=str, metavar=f.path.split(".")[-1]
                                .upper(),
                                help=f"{helptext} (default: {shown})")


def resolve_config(base: ExperimentConfig, args: argparse.Namespace,
                   flags: Sequence[Flag],
                   expected_kind: Optional[str] = None
                   ) -> ExperimentConfig:
    """base config <- --config file overlay <- explicit flags."""
    cfg = base
    config_path = getattr(args, "config", None)
    if config_path:
        with open(config_path) as fh:
            try:
                data = json.load(fh)
            except json.JSONDecodeError as e:
                raise ConfigurationError(
                    f"{config_path}: not valid JSON ({e})") from None
        cfg = cfg.merged(data)
    for f in flags:
        raw = getattr(args, f.dest)
        if raw is UNSET:
            continue
        value = raw if f.parse is None else f.parse(raw)
        cfg = cfg.with_value(f.path, value)
    if expected_kind is not None and cfg.kind != expected_kind:
        raise ConfigurationError(
            f"this surface runs kind={expected_kind!r} experiments, "
            f"got {cfg.kind!r}"
            + (f" (from {config_path})" if config_path else ""), "kind")
    return cfg


def cli_main(parser: argparse.ArgumentParser, flags: Sequence[Flag],
             base: ExperimentConfig, argv: Optional[Sequence[str]],
             expected_kind: str) -> ExperimentConfig:
    """Parse + resolve in one step, converting config errors into the
    parser's standard error exit (message on stderr, status 2)."""
    args = parser.parse_args(argv)
    try:
        return resolve_config(base, args, flags, expected_kind)
    except ConfigurationError as e:
        parser.error(str(e))
        raise AssertionError("unreachable")  # parser.error raises SystemExit
