"""The unified experiment description (DESIGN.md §14): one validated
``ExperimentConfig`` consumed by every experiment surface — the vgang
acceptance grid (``repro.vgang.grid``), the Monte-Carlo schedulability
sweep (``repro.launch.sweep --schedulability``), and the three BENCH
drivers — instead of five bespoke argparse stacks.

Composition (all fields serialize through ``Config``):

* ``TasksetConfig``  — the random-workload knobs shared by grid and
  sweep: seed, machine sizes, width distributions, utilization levels,
  tasksets per point, gangs per taskset, interference gamma.  The
  per-taskset rng streams derive from ``seed`` via
  ``launch.sweep.taskset_seed`` — the reproducibility contract.
* ``PolicyStackConfig`` — which policy columns/modes run and how the
  dispatch is configured (formation heuristics, RTG-throttle, dynamic
  reclaiming, overrun enforcement), with the cross-field rules the
  runtime stack requires (reclaim ⇒ rtg_throttle; a watchdog needs an
  enforcement action).
* ``EngineConfig``   — how verdicts and sims execute: quantum dt (None
  = exact event engine), trace recording, batched-RTA backend, horizon
  in task periods (``cycles``), sim-check count, scalar-RTA fallback,
  worker processes, per-cell timeout.
* ``OutputConfig``   — where results land and which optional sections
  are recorded.

Surfaces that only use a subset of the fields (e.g. ``bench_sim`` has a
fixed workload) simply ignore the rest — the stamped ``content_digest``
still covers every field, so two runs share a digest only if their full
resolved configs match.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.experiment.config import Config, ConfigurationError

# policy-column names the grid understands beyond the formation
# heuristics proper.  The authoritative set is the PolicyFamily registry
# (vgang/family.py) — PolicyStackConfig.validate consults it lazily so a
# registry-added family is accepted here without a parallel edit; this
# static tuple mirrors the built-ins for import-light callers.
RTG_COLUMN = "rtgT"
RECLAIM_COLUMN = "rtgT+dr"
PART_COLUMN = "part"
FORMATION_HEURISTICS = ("ffd", "bestfit", "intfaware")
KNOWN_COLUMNS = ("rtgang",) + FORMATION_HEURISTICS \
    + (RTG_COLUMN, RECLAIM_COLUMN, PART_COLUMN)

WIDTH_DIST_NAMES = ("light", "mixed", "heavy", "uniform")

KINDS = ("grid", "sweep", "bench_sim", "bench_executor", "bench_faults")

ENFORCEMENT_ACTIONS = ("abort", "demote", "degrade")

RTA_BACKENDS = ("auto", "numpy", "jax")


@dataclasses.dataclass
class TasksetConfig(Config):
    """Random-workload axes (UUniFast utilizations; widths per
    distribution for the grid, uniform widths for the sweep)."""

    seed: int = 0
    cores: Tuple[int, ...] = (4, 8, 16)
    dists: Tuple[str, ...] = ("light", "mixed", "heavy")
    utils: Tuple[float, ...] = (0.4, 0.7, 0.9, 1.0, 1.1, 1.2, 1.4,
                                1.6, 2.0)
    n_per_point: int = 50
    # gangs per taskset; None = derived from the machine size
    # (grid.n_tasks_for) — the sweep requires an explicit count
    n_tasks: Optional[int] = None
    gamma: float = 0.5              # intensity_interference strength

    def validate(self):
        if self.seed < 0:
            raise ConfigurationError(
                f"must be >= 0, got {self.seed}", "seed")
        if not self.cores or any(c <= 0 for c in self.cores):
            raise ConfigurationError(
                f"need positive core counts, got {list(self.cores)}",
                "cores")
        for d in self.dists:
            if d not in WIDTH_DIST_NAMES:
                raise ConfigurationError(
                    f"unknown width distribution {d!r}; known: "
                    f"{list(WIDTH_DIST_NAMES)}", "dists")
        if not self.utils or any(u <= 0.0 for u in self.utils):
            raise ConfigurationError(
                f"need positive utilization levels, got "
                f"{list(self.utils)}", "utils")
        if self.n_per_point <= 0:
            raise ConfigurationError(
                f"must be > 0, got {self.n_per_point}", "n_per_point")
        if self.n_tasks is not None and self.n_tasks <= 0:
            raise ConfigurationError(
                f"must be > 0 (or null = derived), got {self.n_tasks}",
                "n_tasks")
        if self.gamma < 0.0:
            raise ConfigurationError(
                f"must be >= 0, got {self.gamma}", "gamma")


@dataclasses.dataclass
class PolicyStackConfig(Config):
    """Which policy columns/modes run, and the dispatch flag bundle."""

    heuristics: Tuple[str, ...] = ("ffd", "bestfit", "intfaware",
                                   RTG_COLUMN, RECLAIM_COLUMN,
                                   PART_COLUMN)
    rtg_throttle: bool = False      # mode surfaces (executor bench)
    reclaim: bool = False           # requires rtg_throttle
    enforcement: Optional[str] = None          # None | abort | demote |
    enforcement_factor: float = 1.2            # degrade (core/faults.py)
    watchdog_factor: Optional[float] = None

    def validate(self):
        # the PolicyFamily registry (vgang/family.py) is the one source
        # of truth for valid columns; imported lazily to keep config
        # loading import-light and cycle-free
        from repro.vgang.family import family_names
        known = family_names()
        for h in self.heuristics:
            if h not in known:
                raise ConfigurationError(
                    f"unknown policy column {h!r}; known: "
                    f"{list(known)}", "heuristics")
        if self.reclaim and not self.rtg_throttle:
            raise ConfigurationError(
                "dynamic reclaiming donates sibling window quota, which "
                "only exists under RTG-throttle — set rtg_throttle=true",
                "reclaim")
        if self.enforcement is not None \
                and self.enforcement not in ENFORCEMENT_ACTIONS:
            raise ConfigurationError(
                f"unknown action {self.enforcement!r}; known: "
                f"{list(ENFORCEMENT_ACTIONS)} (or null)", "enforcement")
        if self.enforcement_factor < 1.0:
            raise ConfigurationError(
                f"must be >= 1.0 (1.0 = declared WCET), got "
                f"{self.enforcement_factor}", "enforcement_factor")
        if self.watchdog_factor is not None:
            if self.enforcement is None:
                raise ConfigurationError(
                    "a watchdog needs an enforcement action to fire — "
                    "set enforcement", "watchdog_factor")
            if self.watchdog_factor <= 0.0:
                raise ConfigurationError(
                    f"must be > 0, got {self.watchdog_factor}",
                    "watchdog_factor")


@dataclasses.dataclass
class EngineConfig(Config):
    """How verdicts and simulations execute."""

    dt: Optional[float] = None      # quantum ms; None = event engine
    trace: bool = False             # timeline recording in sim-checks
    backend: str = "auto"           # batched-RTA backend
    cycles: float = 20.0            # horizon = cycles * max period
    sim_check: int = 2              # tasksets sim-checked per cell
    scalar_rta: bool = False        # per-taskset scalar RTA loop
    processes: int = 0              # worker pool size; 0 = auto
    cell_timeout: float = 0.0       # per-cell seconds; 0 = none

    def validate(self):
        if self.dt is not None and self.dt <= 0.0:
            raise ConfigurationError(
                f"must be > 0 (or null = event engine), got {self.dt}",
                "dt")
        if self.backend not in RTA_BACKENDS:
            raise ConfigurationError(
                f"unknown backend {self.backend!r}; known: "
                f"{list(RTA_BACKENDS)}", "backend")
        if self.cycles <= 0.0:
            raise ConfigurationError(
                f"must be > 0, got {self.cycles}", "cycles")
        if self.sim_check < 0:
            raise ConfigurationError(
                f"must be >= 0, got {self.sim_check}", "sim_check")
        if self.processes < 0:
            raise ConfigurationError(
                f"must be >= 0 (0 = auto), got {self.processes}",
                "processes")
        if self.cell_timeout < 0.0:
            raise ConfigurationError(
                f"must be >= 0 (0 = none), got {self.cell_timeout}",
                "cell_timeout")


@dataclasses.dataclass
class OutputConfig(Config):
    """Result sinks and optional recorded sections."""

    out: Optional[str] = None       # file or directory; None = the
                                    # surface's historical default
    stage: Optional[str] = None     # bench_sim persistent entries label
    profile: bool = False           # bench_sim phase breakdown


@dataclasses.dataclass
class ExperimentConfig(Config):
    """One experiment, fully described.  ``kind`` names the surface that
    runs it; kind-specific cross-field rules live here so an invalid
    combination fails at load time, not at dispatch."""

    kind: str = "grid"
    name: str = ""
    taskset: TasksetConfig = dataclasses.field(
        default_factory=TasksetConfig)
    policy: PolicyStackConfig = dataclasses.field(
        default_factory=PolicyStackConfig)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    output: OutputConfig = dataclasses.field(default_factory=OutputConfig)
    smoke: bool = False
    # bench_executor knobs (ignored by the other kinds)
    duration_s: Optional[float] = None    # seconds per mode
    margin: float = 8.0                   # WCET factor over calibration
    jitter_ms: float = 60.0               # dispatch-jitter allowance

    def validate(self):
        if self.kind not in KINDS:
            raise ConfigurationError(
                f"unknown experiment kind {self.kind!r}; known: "
                f"{list(KINDS)}", "kind")
        if self.kind == "sweep":
            if len(self.taskset.cores) != 1:
                raise ConfigurationError(
                    "the schedulability sweep runs one machine size; "
                    f"got {list(self.taskset.cores)}", "taskset.cores")
            if self.taskset.n_tasks is None:
                raise ConfigurationError(
                    "the sweep needs an explicit gang count (the grid "
                    "derives it from the machine size)", "taskset.n_tasks")
        if self.kind == "grid":
            bad = [d for d in self.taskset.dists if d == "uniform"]
            if bad:
                raise ConfigurationError(
                    "the grid draws widths from the named distributions "
                    "light/mixed/heavy; 'uniform' is the sweep's regime",
                    "taskset.dists")
        if self.duration_s is not None and self.duration_s <= 0.0:
            raise ConfigurationError(
                f"must be > 0 (or null = derived), got {self.duration_s}",
                "duration_s")
        if self.margin <= 0.0:
            raise ConfigurationError(
                f"must be > 0, got {self.margin}", "margin")
        if self.jitter_ms < 0.0:
            raise ConfigurationError(
                f"must be >= 0, got {self.jitter_ms}", "jitter_ms")


# ---------------------------------------------------------------------
# Per-surface base configs: each surface's historical CLI defaults,
# spelled once.  CLI resolution overlays --config and explicit flags on
# top of these, so legacy invocations resolve to identical configs (and
# identical digests) as the equivalent config file.
# ---------------------------------------------------------------------

def default_grid_config() -> ExperimentConfig:
    return ExperimentConfig(kind="grid", name="vgang-grid")


GRID_SMOKE_OVERRIDES = {
    "taskset": {"cores": [4], "dists": ["mixed"], "utils": [0.8, 1.6],
                "n_per_point": 10},
    "policy": {"heuristics": ["ffd", "intfaware", RTG_COLUMN,
                              RECLAIM_COLUMN, PART_COLUMN]},
    "engine": {"sim_check": 1},
}


def default_sweep_config() -> ExperimentConfig:
    return ExperimentConfig(
        kind="sweep", name="sched-sweep",
        taskset=TasksetConfig(cores=(4,), dists=("uniform",),
                              utils=(0.3, 0.5, 0.7, 0.9),
                              n_per_point=100, n_tasks=4),
        policy=PolicyStackConfig(heuristics=()),
        engine=EngineConfig(sim_check=0))


def default_bench_sim_config() -> ExperimentConfig:
    return ExperimentConfig(kind="bench_sim", name="bench-sim",
                            policy=PolicyStackConfig(heuristics=()))


def default_bench_executor_config() -> ExperimentConfig:
    return ExperimentConfig(
        kind="bench_executor", name="bench-executor-vgang",
        taskset=TasksetConfig(cores=(4,), dists=("mixed",), utils=(1.0,),
                              n_per_point=1),
        policy=PolicyStackConfig(heuristics=("intfaware",),
                                 rtg_throttle=True))


def default_bench_faults_config() -> ExperimentConfig:
    return ExperimentConfig(
        kind="bench_faults", name="bench-faults",
        taskset=TasksetConfig(cores=(8,), dists=("mixed",), utils=(1.0,),
                              n_per_point=1, seed=42),
        policy=PolicyStackConfig(heuristics=(), enforcement="abort",
                                 enforcement_factor=1.2,
                                 watchdog_factor=2.0))
