"""Composable experiment configs (DESIGN.md §14): a validated
declarative description of every experiment surface, serializable to
the checked-in files under ``configs/experiments/`` and stamped (as a
content digest) into every results artifact."""
from repro.experiment.config import Config, ConfigurationError
from repro.experiment.experiment import (ExperimentConfig, TasksetConfig,
                                         PolicyStackConfig, EngineConfig,
                                         OutputConfig,
                                         GRID_SMOKE_OVERRIDES,
                                         default_grid_config,
                                         default_sweep_config,
                                         default_bench_sim_config,
                                         default_bench_executor_config,
                                         default_bench_faults_config)
from repro.experiment.cli import (Flag, UNSET, derive_flags, add_flags,
                                  resolve_config, cli_main)

__all__ = [
    "Config", "ConfigurationError", "ExperimentConfig", "TasksetConfig",
    "PolicyStackConfig", "EngineConfig", "OutputConfig",
    "GRID_SMOKE_OVERRIDES", "default_grid_config", "default_sweep_config",
    "default_bench_sim_config", "default_bench_executor_config",
    "default_bench_faults_config", "Flag", "UNSET", "derive_flags",
    "add_flags", "resolve_config", "cli_main",
]
