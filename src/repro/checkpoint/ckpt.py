"""Checkpointing: sharded-pytree save/restore with async writes, atomic
publication, retention, deterministic data resume, and ELASTIC restore
(a checkpoint saved on one mesh restores onto any other mesh/device count —
leaves are stored as full logical arrays and re-sharded at load).

Format: <dir>/step_<N>/manifest.json + leaf_<i>.npy files;
<dir>/step_<N>.done marks a complete checkpoint (atomic publication).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    # ------------------------------------------------------------------
    def _save_sync(self, state, step: int, extra: Dict[str, Any]):
        leaves, paths, _ = _flatten_with_paths(state)
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(final + ".done", "w") as f:
            f.write(str(time.time()))
        self.save_count += 1
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, f"step_{s}.done"))
            except OSError:
                pass

    def save(self, state, step: int, extra: Optional[Dict[str, Any]] = None,
             blocking: bool = False):
        """Async by default: snapshot to host, then write in a thread."""
        extra = extra or {}
        # snapshot to host synchronously (cheap vs training step), write async
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()
        if blocking:
            self._save_sync(host_state, step, extra)
        else:
            self._thread = threading.Thread(
                target=self._save_sync, args=(host_state, step, extra),
                daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.endswith(".done") and name.startswith("step_"):
                steps.append(int(name[len("step_"):-len(".done")]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Restore onto the current mesh. ``like``: pytree of arrays or
        ShapeDtypeStructs defining the structure; ``shardings``: optional
        matching pytree of NamedShardings (elastic re-shard happens here —
        the stored full arrays are device_put with the new shardings)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, paths, treedef = _flatten_with_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        out_leaves = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        for leaf, path, sh in zip(leaves_like, paths, shard_leaves):
            entry = by_path[path]
            arr = np.load(os.path.join(final, entry["file"]))
            expected = tuple(leaf.shape)
            if tuple(arr.shape) != expected:
                raise ValueError(f"shape mismatch at {path}: "
                                 f"{arr.shape} vs {expected}")
            if sh is not None:
                out_leaves.append(jax.device_put(arr, sh))
            else:
                out_leaves.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out_leaves), manifest["extra"]
