"""Discrete-event (fixed-quantum) scheduler simulator.

Drives the faithful ``GangScheduler`` state machine over N cores with:
* periodic parallel RT tasks (threads pinned to cores, no migration),
* best-effort tasks under a CFS-like fair scheduler on idle cores,
* a pluggable pairwise interference model (co-scheduled task X slows task Y
  by factor f(Y, X) — the paper's DNN/BwWrite case gives f = 10.33),
* BWLOCK-style bandwidth throttling of best-effort cores.

``enabled=False`` turns RT-Gang off: each core independently runs its
highest-priority ready RT thread (Linux SCHED_FIFO baseline = the paper's
"Co-Sched" configuration). This reproduces Fig.4(a)/(c); enabling RT-Gang
reproduces Fig.4(b) and Fig.5(b).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.faults import Enforcement, FaultManager, FaultPlan
from repro.core.gang import (BETask, RTTask, Thread, validate_declared,
                             validate_taskset)
from repro.core.glock import GangScheduler
from repro.core.memmodel import BE, MemoryModel
from repro.core.throttle import BandwidthRegulator
from repro.core.tracing import NullTrace, Trace
from repro.obs.margins import margin_summary
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class Job:
    task: RTTask
    release: float
    remaining: Dict[int, float]          # core -> remaining work
    index: int
    start: Optional[float] = None
    finish: Optional[float] = None
    aborted: bool = False                # enforcement killed this job

    @property
    def done(self) -> bool:
        return all(r <= 1e-12 for r in self.remaining.values())

    def response_time(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.release


PairwiseInterference = Callable[[str, str], float]


def no_interference(victim: str, aggressor: str) -> float:
    return 1.0


def matrix_interference(table: Dict[Tuple[str, str], float]
                        ) -> PairwiseInterference:
    def f(victim: str, aggressor: str) -> float:
        return table.get((victim, aggressor), 1.0)
    return f


@dataclasses.dataclass
class SimResult:
    trace: Trace
    response_times: Dict[str, List[float]]
    deadline_misses: Dict[str, int]
    be_progress: Dict[str, float]
    throttle_events: int
    ipis: int
    preemptions: int
    slack_time: float                    # core-ms of idle+BE time
    horizon: float
    events: int = 0                      # event-engine: events processed
    engine: str = "quantum"              # "quantum" (dt-stepped) | "event"
    reclaimed: float = 0.0               # traffic units drawn from donors
    # absolute times of each deadline miss (including enforcement
    # aborts, stamped at the abort instant) — keyed like deadline_misses
    miss_times: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)
    faults: Optional[Dict] = None        # FaultManager.summary() when armed
    # observability (DESIGN.md §12): RTA-margin summary per task (when
    # the run was given analytic bounds), and the metric snapshots
    # (when the run was given a MetricsRegistry)
    rta_margins: Optional[Dict] = None   # obs.margins.margin_summary()
    metrics: Optional[Dict] = None       # MetricsRegistry.snapshot()
    parity_metrics: Optional[Dict] = None  # engine-parity counters only

    def wcrt(self, name: str) -> float:
        rs = self.response_times.get(name) or [float("nan")]
        return max(rs)

    def percentile(self, name: str, q: float) -> float:
        """q-th percentile (0..100) of the task's response times, linear
        interpolation between order statistics (numpy's default rule, but
        dependency-free — SimResult is consumed by pure-python sweeps)."""
        rs = sorted(self.response_times.get(name) or ())
        if not rs:
            return float("nan")
        k = (len(rs) - 1) * q / 100.0
        lo = math.floor(k)
        hi = min(lo + 1, len(rs) - 1)
        return rs[lo] + (rs[hi] - rs[lo]) * (k - lo)

    def percentiles(self, name: str) -> Dict[str, float]:
        """p50/p95/p99/p999 latency summary for long-horizon CDF runs
        (Fig.6-style statistics at >= 10^6 ms horizons, ROADMAP item 2)."""
        return {"p50": self.percentile(name, 50.0),
                "p95": self.percentile(name, 95.0),
                "p99": self.percentile(name, 99.0),
                "p999": self.percentile(name, 99.9),
                "max": self.wcrt(name),
                "n": len(self.response_times.get(name) or ())}


class Simulator:
    def __init__(self, n_cores: int, rt_tasks: Sequence[RTTask],
                 be_tasks: Sequence[BETask] = (),
                 interference: PairwiseInterference = no_interference,
                 rt_gang_enabled: bool = True,
                 throttle_mode: str = "reactive",
                 regulation_interval: float = 1.0,
                 dt: Optional[float] = 0.05,
                 budget_policy: Optional["BudgetPolicy"] = None,
                 reclaim: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 enforcement: Optional[Enforcement] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 rta_bounds: Optional[Dict[str, float]] = None,
                 record_counters: bool = False,
                 trace: bool = True, **unknown):
        """``dt``: quantum length in ms for the fixed-quantum engine, or
        ``None`` to run the exact event-driven engine (core/events.py) —
        same SimResult, O(events) instead of O(horizon/dt).

        ``reclaim``: enable mid-window bandwidth donation (DESIGN.md
        §7.5): idle cores' unspent window quota is drawn — through the
        MemoryModel's dominance gate — by RT threads that would
        otherwise trip, in both engines identically.

        ``budget_policy``: optional object with ``apply(glock, regulator)``
        called whenever scheduling settles to set throttle budgets,
        replacing the default leader-budget rule. ``apply`` must return
        the set of cores whose throttle regime it changed (what
        ``BandwidthRegulator.set_core_budgets`` returns) — the event
        engine re-predicts trip/stall events only for those cores — or
        ``None`` to force a conservative all-cores refresh. Virtual
        gangs use it to enforce the minimum budget over co-running
        member gangs, and RTG-throttle to cap sibling members
        (vgang/sched.py).

        ``fault_plan`` / ``enforcement``: seeded fault injection and
        runtime overrun enforcement (core/faults.py, DESIGN.md §11) —
        both engines drive the same FaultManager, so injected faults
        and enforcement decisions are engine-identical. Passing an
        ``enforcement`` policy additionally runs the strict
        ``validate_declared`` check: enforcement budgets are derived
        from declarations, so the declarations must be trustworthy.

        Observability (DESIGN.md §12): ``metrics`` plumbs one
        MetricsRegistry through the scheduler, regulator and fault
        layer and stamps its snapshots into the SimResult (None = the
        components run detached instruments, the bare mode).
        ``rta_bounds`` maps task name -> analytic response-time bound
        (ms); every completed job's margin against it is summarized in
        ``SimResult.rta_margins``.

        ``trace=False`` skips timeline recording entirely (a no-op
        NullTrace): identical SimResult counters, misses, percentiles
        and margins, but ``result.trace`` stays empty — the analysis
        fast path for Monte-Carlo sim-checks (DESIGN.md §13.4).

        ``record_counters`` keeps the
        regulator's per-window history and the gang-change log for
        Perfetto counter tracks (obs.perfetto.export_sim)."""
        if unknown:
            raise TypeError(
                f"Simulator: unknown option(s) {sorted(unknown)}; valid "
                f"options: be_tasks, budget_policy, dt, enforcement, "
                f"fault_plan, interference, metrics, reclaim, "
                f"record_counters, regulation_interval, rt_gang_enabled, "
                f"rta_bounds, throttle_mode, trace")
        validate_taskset(rt_tasks)
        if not regulation_interval > 0.0:
            raise ValueError(
                f"regulation_interval must be > 0, "
                f"got {regulation_interval}")
        if dt is not None and not dt > 0.0:
            raise ValueError(f"dt must be > 0 (or None), got {dt}")
        if enforcement is not None:
            validate_declared(rt_tasks)
        self.n_cores = n_cores
        self.rt_tasks = list(rt_tasks)
        self.be_tasks = list(be_tasks)
        self.interference = interference
        self.dt = dt
        self.budget_policy = budget_policy
        self.metrics = metrics
        self.rta_bounds = dict(rta_bounds) if rta_bounds else None
        self.record_counters = record_counters
        mreg = metrics if metrics is not None \
            else MetricsRegistry(enabled=False)
        self._mreg = mreg
        self.sched = GangScheduler(n_cores, enabled=rt_gang_enabled,
                                   metrics=mreg)
        self.reg = BandwidthRegulator(n_cores, interval=regulation_interval,
                                      mode=throttle_mode, reclaim=reclaim,
                                      metrics=mreg,
                                      record_history=record_counters)
        self.mm = MemoryModel(n_cores, interference, self.reg)
        # trace=False swaps in a no-op recorder: Segment construction is
        # the top allocator on the hot path and Monte-Carlo sim-checks
        # never read the timeline (DESIGN.md §13.4)
        self.trace = Trace(n_cores) if trace else NullTrace(n_cores)
        self.profile = False        # event engine: record phase breakdown
        # per-core best-effort fair-share tables, shared by both engines
        # (candidates, their names, and the aggregate sum(mem_rate)/n
        # traffic a free core charges — DESIGN.md §8.3)
        self.be_cands: List[Tuple[BETask, ...]] = [
            tuple(b for b in self.be_tasks if c in b.cores)
            for c in range(n_cores)]
        self.be_names = [tuple(b.name for b in cands)
                         for cands in self.be_cands]
        # fault injection + enforcement state machine (shared by both
        # engines; a no-op shell when neither plan nor policy is given)
        self.fm = FaultManager(rt_tasks, fault_plan, enforcement,
                               metrics=mreg)
        self.fm.install(self.reg)
        # per-task parity counters, pre-created at construction so both
        # engines' registries index identical series even for tasks
        # that never release or complete within the horizon; trued up
        # from the authoritative result dicts in ``finalize_result``
        self._task_counters = {t.name: (
            mreg.counter("task.releases", parity=True, gang=t.name),
            mreg.counter("task.completions", parity=True, gang=t.name),
            mreg.counter("task.misses", parity=True, gang=t.name))
            for t in self.rt_tasks}
        # gang-change log for the Perfetto glock-hold counter track:
        # (t, event, leader name) — filled only when record_counters
        self.gang_events: List[Tuple[float, str, Optional[str]]] = []
        # a lying BE task charges its *actual* (inflated) traffic — the
        # regulator contains the overrun by construction
        bef = self.fm.plan.be_factor
        self.be_share_rate = [
            sum(b.mem_rate * bef(b.name) for b in cands) / len(cands)
            if cands else 0.0
            for cands in self.be_cands]

    def apply_budget_rule(self):
        """Refresh throttle budgets from the gang-lock state: the
        ``budget_policy`` when given, else the paper's rule — the
        leader's declared budget on every core not occupied by the
        running gang; gang-occupied cores run unthrottled (RT threads
        charge their own traffic since the MemoryModel refactor, so the
        default rule must not turn a gang's budget on itself — only an
        explicit policy such as RTG-throttle regulates RT members).
        Returns the cores whose throttle regime changed."""
        g = self.sched.g
        if self.sched.enabled and self.budget_policy is not None:
            changed = self.budget_policy.apply(g, self.reg)
            return changed if changed is not None else \
                set(range(self.n_cores))
        if self.sched.enabled and g.held_flag and g.leader is not None:
            occupied = {th.core for th in g.gthreads if th is not None}
            return self.reg.set_core_budgets(
                {c: None for c in occupied}, default=g.leader.mem_budget)
        return self.reg.set_gang_budget(None)

    def gang_hook(self, time_cell: List[float]):
        """Compose the gang-change callbacks a run needs: reclaim-grant
        voiding on acquire, and the gang-event log (Perfetto glock-hold
        counter track) stamped at the driving engine's current time —
        the engine keeps ``time_cell[0]`` current. Returns None when
        there is nothing to observe."""
        hooks = []
        if self.reg.reclaim:
            hooks.append(lambda ev, ldr: self.reg.reset_reclaim()
                         if ev == "acquire" else None)
        if self.record_counters:
            log = self.gang_events
            hooks.append(lambda ev, ldr: log.append(
                (time_cell[0], ev, None if ldr is None else ldr.name)))
        if not hooks:
            return None
        if len(hooks) == 1:
            return hooks[0]

        def fire(ev, ldr):
            for h in hooks:
                h(ev, ldr)
        return fire

    def finalize_result(self, trace: Trace,
                        response: Dict[str, List[float]],
                        misses: Dict[str, int],
                        miss_times: Dict[str, List[float]],
                        be_progress: Dict[str, float],
                        slack: float, horizon: float,
                        releases: Dict[str, int],
                        events: int = 0,
                        engine: str = "quantum") -> SimResult:
        """Shared result assembly for both engines: true up the
        per-task parity counters from the authoritative result dicts
        (releases/completions/misses — including enforcement aborts and
        late demoted completions, which the FaultManager folds into the
        same dicts), compute RTA margins against any declared bounds,
        and stamp the metric snapshots."""
        fm = self.fm
        for name, (c_rel, c_comp, c_miss) in self._task_counters.items():
            c_rel.value = releases.get(name, 0)
            c_comp.value = len(response.get(name) or ())
            c_miss.value = misses.get(name, 0)
        rta_margins = None
        if self.rta_bounds:
            rta_margins = margin_summary(response, self.rta_bounds,
                                         metrics=self.metrics)
        throttle_events = sum(st.throttle_events
                              for st in self.reg.cores.values())
        return SimResult(
            trace=trace, response_times=response, deadline_misses=misses,
            be_progress=be_progress, throttle_events=throttle_events,
            ipis=self.sched.g.ipis_sent,
            preemptions=self.sched.g.preemptions,
            slack_time=slack, horizon=horizon,
            events=events, engine=engine,
            reclaimed=self.reg.total_reclaimed,
            miss_times=miss_times,
            faults=fm.summary()
            if (fm.enf is not None or fm.plan.faults) else None,
            rta_margins=rta_margins,
            metrics=self.metrics.snapshot()
            if self.metrics is not None else None,
            parity_metrics=self.metrics.parity_snapshot()
            if self.metrics is not None else None)

    # -----------------------------------------------------------------
    def run(self, horizon: float) -> SimResult:
        if self.dt is None:
            from repro.core.events import EventEngine
            eng = EventEngine(self)
            self.last_engine = eng       # bench_sim.py reads phase_wall
            return eng.run(horizon)
        dt = self.dt
        nsteps = int(round(horizon / dt))
        jobs: Dict[int, List[Job]] = {t.uid: [] for t in self.rt_tasks}
        threads: Dict[Tuple[int, int], Thread] = {}
        for t in self.rt_tasks:
            for i, c in enumerate(t.cores):
                threads[(t.uid, c)] = Thread(task=t, core=c, index=i)

        current: List[Optional[Thread]] = [None] * self.n_cores
        be_progress = {b.name: 0.0 for b in self.be_tasks}
        be_cands, be_names = self.be_cands, self.be_names
        be_agg = self.be_share_rate
        mm = self.mm
        response: Dict[str, List[float]] = {t.name: [] for t in self.rt_tasks}
        misses = {t.name: 0 for t in self.rt_tasks}
        miss_times: Dict[str, List[float]] = {t.name: []
                                              for t in self.rt_tasks}
        fm = self.fm
        fm.bind(misses, miss_times, response)
        slack = 0.0

        def release_jobs(now: float):
            for t in self.rt_tasks:
                done_jobs = len(jobs[t.uid])
                if t.n_jobs is not None and done_jobs >= t.n_jobs:
                    continue
                next_rel = t.release_offset + done_jobs * t.period
                if now + 1e-9 >= next_rel:
                    j = Job(task=t, release=next_rel, index=done_jobs,
                            remaining={c: t.thread_wcet(c) for c in t.cores})
                    fm.on_release(j)
                    jobs[t.uid].append(j)

        def active_job(t: RTTask) -> Optional[Job]:
            for j in jobs[t.uid]:
                if not j.done:
                    return j
            return None

        def has_work(uid: int, core: int) -> bool:
            j = active_job(fm.tasks[uid])
            return j is not None and j.remaining.get(core, 0.0) > 1e-12

        def ready_thread(core: int) -> Optional[Thread]:
            best: Optional[Thread] = None
            for t in self.rt_tasks:
                if core not in t.cores or t.uid in fm.suspended:
                    continue
                j = active_job(t)
                if j is None or j.remaining.get(core, 0) <= 1e-12:
                    continue
                if best is None or t.prio > best.task.prio:
                    best = threads[(t.uid, core)]
            return best

        dirty = set(range(self.n_cores))
        self.sched.reschedule_cpus = lambda cores: dirty.update(cores)
        time_cell = [0.0]
        self.sched.on_gang_change = self.gang_hook(time_cell)

        for step in range(nsteps):
            now = step * dt
            time_cell[0] = now
            release_jobs(now)

            # ---- scheduling passes until fixed point --------------------
            dirty.update(range(self.n_cores))
            for _ in range(4 + len(self.rt_tasks)):
                if not dirty:
                    break
                todo = sorted(dirty)
                dirty.clear()
                for c in todo:
                    prev = current[c]
                    nxt = ready_thread(c)
                    picked = self.sched.pick_next_task_rt(c, prev, nxt)
                    current[c] = picked
            # preempted cores cleared by do_gang_preemption: sync with glock
            for c in range(self.n_cores):
                if current[c] is not None and \
                        self.sched.enabled and \
                        self.sched.g.gthreads[c] is not current[c]:
                    current[c] = self.sched.g.gthreads[c]
            # lock-leak audit: an abort/demote in the previous step must
            # have left the gang lock by the time this step's pass settles
            if fm.pending_audit:
                fm.audit(self.sched.g, has_work)

            # set throttle budgets from the running gang / budget policy
            self.apply_budget_rule()

            # ---- occupancy (MemoryModel): who runs, who is stalled ------
            # Best-effort candidates share a free core fractionally (the
            # event engine's fair-sharing semantics, the dt -> 0 limit of
            # the old per-step round-robin): every unstalled candidate is
            # present for interference and the core charges the aggregate
            # traffic sum(mem_rate)/n. RT threads with traffic charge too
            # and pause mid-job while their core's budget is tripped.
            rt_stalled = set()
            for c in range(self.n_cores):
                # a demoted residual occupies an otherwise-free core as
                # an RT-kind occupant (charges its own traffic, stalls
                # under the ambient budget)
                occ = current[c] if current[c] is not None \
                    else fm.dem_thread(c)
                if mm.refresh_core(c, occ, be_names[c], be_agg[c],
                                   now):
                    rt_stalled.add(c)
            if self.reg.reclaim and rt_stalled:
                # mid-window donation: a stalled RT thread retries the
                # pool (a donor may have gone idle); a granted draw
                # lifts the stall and the thread resumes this quantum —
                # the same instant the event engine resumes it
                # (demoted residuals never claim: they are best-effort)
                for c in sorted(rt_stalled):
                    if current[c] is not None and \
                            mm.claim_lift(c, current[c].task, now):
                        rt_stalled.discard(c)
                        mm.refresh_core(c, current[c], be_names[c],
                                        be_agg[c], now)

            # ---- advance RT work + best-effort progress ------------------
            for c in range(self.n_cores):
                th = current[c]
                if th is None:
                    d = fm.dem_head(c)
                    if d is not None:
                        # demoted residual: drains ahead of BE fillers
                        # whenever the core is free, under the ambient
                        # throttle budget; not counted as slack
                        if c in rt_stalled:
                            self.trace.record(
                                c, "throttled:" + d.task.name, now,
                                now + dt)
                            continue
                        frac = mm.charge_quantum(c, dt, now)
                        if frac <= 0.0:
                            self.trace.record(
                                c, "throttled:" + d.task.name, now,
                                now + dt)
                            continue
                        slow = mm.slowdown(d.task.name, c)
                        d.residual[c] = max(
                            0.0, d.residual[c] - dt * frac / slow)
                        self.trace.record(c, "dem:" + d.task.name, now,
                                          now + dt * frac)
                        if frac < 1.0:
                            self.trace.record(
                                c, "throttled:" + d.task.name,
                                now + dt * frac, now + dt)
                        if d.residual[c] <= 1e-12:
                            fm.dem_finish_core(c, now + dt)
                        continue
                    slack += dt
                    cands = be_cands[c]
                    if mm.kind[c] == BE:
                        frac = mm.charge_quantum(c, dt, now)
                        run = dt * frac
                        if frac > 0.0:
                            sub = run / len(cands)
                            for i, b in enumerate(cands):
                                be_progress[b.name] += sub
                                self.trace.record(c, b.name, now + i * sub,
                                                  now + (i + 1) * sub)
                        if frac < 1.0:
                            heavy = max(cands, key=lambda b: b.mem_rate)
                            self.trace.record(c, "throttled:" + heavy.name,
                                              now + run, now + dt)
                    elif cands:
                        heavy = max(cands, key=lambda b: b.mem_rate)
                        self.trace.record(c, "throttled:" + heavy.name,
                                          now, now + dt)
                    else:
                        self.trace.record(c, None, now, now + dt)
                    continue
                j = active_job(th.task)
                if j is None:
                    continue
                if j.start is None:
                    j.start = now
                if c in rt_stalled:
                    self.trace.record(c, "throttled:" + th.task.name,
                                      now, now + dt)
                    continue
                frac = mm.charge_quantum(c, dt, now)
                if frac <= 0.0:
                    self.trace.record(c, "throttled:" + th.task.name,
                                      now, now + dt)
                    continue
                # budget tripping mid-quantum: the thread pauses mid-job
                # after the admitted fraction and stays stalled until the
                # regulation window ends
                slow = mm.slowdown(th.task.name, c)
                j.remaining[c] = max(0.0, j.remaining[c] - dt * frac / slow)
                self.trace.record(c, th.task.name, now, now + dt * frac)
                if frac < 1.0:
                    self.trace.record(c, "throttled:" + th.task.name,
                                      now + dt * frac, now + dt)
                if j.done and j.finish is None:
                    j.finish = now + dt
                    rt = j.response_time()
                    response[th.task.name].append(rt)
                    if rt > th.task.deadline + 1e-9:
                        misses[th.task.name] += 1
                        miss_times[th.task.name].append(now + dt)
                    # if this was the degrading job, lift the suspension
                    fm.maybe_restore(th.task.uid, j.index)

            # ---- overrun enforcement (work budgets + watchdog) ----------
            if fm.enf is not None:
                t_end = now + dt
                for t in self.rt_tasks:
                    for j in jobs[t.uid]:
                        if j.done or j.aborted:
                            continue
                        via = fm.due(j, t_end)
                        if via is None:
                            continue
                        action = fm.fire(j, t_end, via)
                        if action is None:
                            continue
                        if action == "degrade":
                            fm.begin_degrade(j, self.rt_tasks)
                            continue
                        if action == "demote":
                            # snapshot the residual before zeroing
                            fm.begin_demote(j, t_end)
                        else:
                            j.aborted = True
                            fm.record_abort(j, t_end)
                        for c in j.remaining:
                            j.remaining[c] = 0.0
                        if j.aborted:
                            fm.maybe_restore(t.uid, j.index)

        return self.finalize_result(
            self.trace, response, misses, miss_times, be_progress,
            slack, horizon,
            releases={t.name: len(jobs[t.uid]) for t in self.rt_tasks})
