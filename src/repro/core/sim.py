"""Discrete-event (fixed-quantum) scheduler simulator.

Drives the faithful ``GangScheduler`` state machine over N cores with:
* periodic parallel RT tasks (threads pinned to cores, no migration),
* best-effort tasks under a CFS-like fair scheduler on idle cores,
* a pluggable pairwise interference model (co-scheduled task X slows task Y
  by factor f(Y, X) — the paper's DNN/BwWrite case gives f = 10.33),
* BWLOCK-style bandwidth throttling of best-effort cores.

``enabled=False`` turns RT-Gang off: each core independently runs its
highest-priority ready RT thread (Linux SCHED_FIFO baseline = the paper's
"Co-Sched" configuration). This reproduces Fig.4(a)/(c); enabling RT-Gang
reproduces Fig.4(b) and Fig.5(b).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gang import BETask, RTTask, Thread, validate_taskset
from repro.core.glock import GangScheduler
from repro.core.throttle import BandwidthRegulator
from repro.core.tracing import Trace


@dataclasses.dataclass
class Job:
    task: RTTask
    release: float
    remaining: Dict[int, float]          # core -> remaining work
    index: int
    start: Optional[float] = None
    finish: Optional[float] = None

    @property
    def done(self) -> bool:
        return all(r <= 1e-12 for r in self.remaining.values())

    def response_time(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.release


PairwiseInterference = Callable[[str, str], float]


def no_interference(victim: str, aggressor: str) -> float:
    return 1.0


def matrix_interference(table: Dict[Tuple[str, str], float]
                        ) -> PairwiseInterference:
    def f(victim: str, aggressor: str) -> float:
        return table.get((victim, aggressor), 1.0)
    return f


@dataclasses.dataclass
class SimResult:
    trace: Trace
    response_times: Dict[str, List[float]]
    deadline_misses: Dict[str, int]
    be_progress: Dict[str, float]
    throttle_events: int
    ipis: int
    preemptions: int
    slack_time: float                    # core-ms of idle+BE time
    horizon: float
    events: int = 0                      # event-engine: events processed
    engine: str = "quantum"              # "quantum" (dt-stepped) | "event"

    def wcrt(self, name: str) -> float:
        rs = self.response_times.get(name) or [float("nan")]
        return max(rs)

    def percentile(self, name: str, q: float) -> float:
        """q-th percentile (0..100) of the task's response times, linear
        interpolation between order statistics (numpy's default rule, but
        dependency-free — SimResult is consumed by pure-python sweeps)."""
        rs = sorted(self.response_times.get(name) or ())
        if not rs:
            return float("nan")
        k = (len(rs) - 1) * q / 100.0
        lo = math.floor(k)
        hi = min(lo + 1, len(rs) - 1)
        return rs[lo] + (rs[hi] - rs[lo]) * (k - lo)

    def percentiles(self, name: str) -> Dict[str, float]:
        """p50/p95/p99/p999 latency summary for long-horizon CDF runs
        (Fig.6-style statistics at >= 10^6 ms horizons, ROADMAP item 2)."""
        return {"p50": self.percentile(name, 50.0),
                "p95": self.percentile(name, 95.0),
                "p99": self.percentile(name, 99.0),
                "p999": self.percentile(name, 99.9),
                "max": self.wcrt(name),
                "n": len(self.response_times.get(name) or ())}


class Simulator:
    def __init__(self, n_cores: int, rt_tasks: Sequence[RTTask],
                 be_tasks: Sequence[BETask] = (),
                 interference: PairwiseInterference = no_interference,
                 rt_gang_enabled: bool = True,
                 throttle_mode: str = "reactive",
                 regulation_interval: float = 1.0,
                 dt: Optional[float] = 0.05,
                 budget_policy: Optional["BudgetPolicy"] = None):
        """``dt``: quantum length in ms for the fixed-quantum engine, or
        ``None`` to run the exact event-driven engine (core/events.py) —
        same SimResult, O(events) instead of O(horizon/dt).

        ``budget_policy``: optional object with ``apply(glock, regulator)``
        that sets throttle budgets whenever the gang lock is held, replacing
        the default leader-budget rule. Virtual gangs use it to enforce the
        minimum budget over co-running member gangs (vgang/sched.py)."""
        validate_taskset(rt_tasks)
        self.n_cores = n_cores
        self.rt_tasks = list(rt_tasks)
        self.be_tasks = list(be_tasks)
        self.interference = interference
        self.dt = dt
        self.budget_policy = budget_policy
        self.sched = GangScheduler(n_cores, enabled=rt_gang_enabled)
        self.reg = BandwidthRegulator(n_cores, interval=regulation_interval,
                                      mode=throttle_mode)
        self.trace = Trace(n_cores)

    # -----------------------------------------------------------------
    def run(self, horizon: float) -> SimResult:
        if self.dt is None:
            from repro.core.events import EventEngine
            return EventEngine(self).run(horizon)
        dt = self.dt
        nsteps = int(round(horizon / dt))
        jobs: Dict[int, List[Job]] = {t.uid: [] for t in self.rt_tasks}
        threads: Dict[Tuple[int, int], Thread] = {}
        for t in self.rt_tasks:
            for i, c in enumerate(t.cores):
                threads[(t.uid, c)] = Thread(task=t, core=c, index=i)

        current: List[Optional[Thread]] = [None] * self.n_cores
        cur_job: Dict[int, Job] = {}                 # task uid -> active job
        be_progress = {b.name: 0.0 for b in self.be_tasks}
        be_rr = 0
        response: Dict[str, List[float]] = {t.name: [] for t in self.rt_tasks}
        misses = {t.name: 0 for t in self.rt_tasks}
        slack = 0.0

        def release_jobs(now: float):
            for t in self.rt_tasks:
                done_jobs = len(jobs[t.uid])
                if t.n_jobs is not None and done_jobs >= t.n_jobs:
                    continue
                next_rel = t.release_offset + done_jobs * t.period
                if now + 1e-9 >= next_rel:
                    jobs[t.uid].append(Job(
                        task=t, release=next_rel, index=done_jobs,
                        remaining={c: t.thread_wcet(c) for c in t.cores}))

        def active_job(t: RTTask) -> Optional[Job]:
            for j in jobs[t.uid]:
                if not j.done:
                    return j
            return None

        def ready_thread(core: int) -> Optional[Thread]:
            best: Optional[Thread] = None
            for t in self.rt_tasks:
                if core not in t.cores:
                    continue
                j = active_job(t)
                if j is None or j.remaining.get(core, 0) <= 1e-12:
                    continue
                if best is None or t.prio > best.task.prio:
                    best = threads[(t.uid, core)]
            return best

        dirty = set(range(self.n_cores))
        self.sched.reschedule_cpus = lambda cores: dirty.update(cores)

        for step in range(nsteps):
            now = step * dt
            release_jobs(now)

            # ---- scheduling passes until fixed point --------------------
            dirty.update(range(self.n_cores))
            for _ in range(4 + len(self.rt_tasks)):
                if not dirty:
                    break
                todo = sorted(dirty)
                dirty.clear()
                for c in todo:
                    prev = current[c]
                    nxt = ready_thread(c)
                    picked = self.sched.pick_next_task_rt(c, prev, nxt)
                    current[c] = picked
            # preempted cores cleared by do_gang_preemption: sync with glock
            for c in range(self.n_cores):
                if current[c] is not None and \
                        self.sched.enabled and \
                        self.sched.g.gthreads[c] is not current[c]:
                    current[c] = self.sched.g.gthreads[c]

            # set throttle budget from the running gang
            if self.sched.enabled:
                if self.budget_policy is not None:
                    self.budget_policy.apply(self.sched.g, self.reg)
                elif self.sched.g.held_flag and \
                        self.sched.g.leader is not None:
                    self.reg.set_gang_budget(self.sched.g.leader.mem_budget)
                else:
                    self.reg.set_gang_budget(None)
            else:
                self.reg.set_gang_budget(None)

            # ---- best-effort filling ------------------------------------
            be_running: Dict[int, BETask] = {}
            free_cores = [c for c in range(self.n_cores) if current[c] is None]
            if self.be_tasks and free_cores:
                for c in free_cores:
                    cands = [b for b in self.be_tasks if c in b.cores]
                    if not cands:
                        continue
                    b = cands[(be_rr + c) % len(cands)]
                    if self.reg.is_stalled(c, now):
                        self.trace.record(c, "throttled:" + b.name, now,
                                          now + dt)
                        continue
                    be_running[c] = b
                be_rr += 1

            # ---- who is actually running (for interference) -------------
            running_names = {}
            for c in range(self.n_cores):
                if current[c] is not None:
                    running_names[c] = current[c].task.name
                elif c in be_running:
                    running_names[c] = be_running[c].name

            # ---- advance RT work -----------------------------------------
            for c in range(self.n_cores):
                th = current[c]
                if th is None:
                    if c in be_running:
                        b = be_running[c]
                        ok = self.reg.charge(c, b.mem_rate * dt, now)
                        if ok:
                            be_progress[b.name] += dt
                            self.trace.record(c, b.name, now, now + dt)
                        else:
                            self.trace.record(c, "throttled:" + b.name, now,
                                              now + dt)
                        slack += dt
                    else:
                        slack += dt
                        self.trace.record(c, None, now, now + dt)
                    continue
                j = active_job(th.task)
                if j is None:
                    continue
                if j.start is None:
                    j.start = now
                co = {n for cc, n in running_names.items()
                      if cc != c and n != th.task.name}
                slow = 1.0
                for other in co:
                    slow = max(slow, self.interference(th.task.name, other))
                rate = 1.0 / slow
                j.remaining[c] = max(0.0, j.remaining[c] - dt * rate)
                self.trace.record(c, th.task.name, now, now + dt)
                if j.done and j.finish is None:
                    j.finish = now + dt
                    response[th.task.name].append(j.response_time())
                    if j.response_time() > th.task.deadline + 1e-9:
                        misses[th.task.name] += 1

        throttle_events = sum(st.throttle_events
                              for st in self.reg.cores.values())
        return SimResult(
            trace=self.trace, response_times=response,
            deadline_misses=misses, be_progress=be_progress,
            throttle_events=throttle_events,
            ipis=self.sched.g.ipis_sent,
            preemptions=self.sched.g.preemptions,
            slack_time=slack, horizon=horizon)
