"""Response-time analysis for RT-Gang tasksets.

The paper's central analytical claim (§III-B): under one-gang-at-a-time,
multicore parallel RT scheduling collapses to the classical single-core
fixed-priority problem, so Audsley-style RTA applies with *solo* WCETs:

    R_i = C_i + B_i + gamma_i + sum_{j in hp(i)} ceil(R_i / P_j) * (C_j + gamma_j)

* C_i  — the gang's WCET measured in isolation (threads run in parallel, so
  the gang's C is the max thread WCET; the paper's taskset tables list it).
* B_i  — blocking from non-preemptible quanta of lower-priority gangs
  (0 in the paper's kernel implementation, which preempts at tick
  granularity; our TPU executor preempts at quantum boundaries, so
  B_i = max lower-priority quantum — see DESIGN.md §2.1).
* gamma_i — CRPD-style re-warm penalty per resume (paper §V-C observes CRPD
  on the Pi 3; classic single-core CRPD analysis becomes valid again).

Best-effort interference is bounded by the task's declared budget and does
not enter hp() (strict prioritization).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.core.gang import RTTask


def gang_wcet(task: RTTask) -> float:
    """A gang's sequential-equivalent WCET = max thread WCET (threads are
    co-scheduled and the gang occupies the machine until its last thread
    finishes; the paper's C values are per-gang)."""
    if task.wcet_per_core:
        return max(task.wcet_per_core.values())
    return task.wcet


def _fixed_point(base: float, hp_terms, period: float,
                 max_iter: int) -> Optional[float]:
    """The Audsley iteration on precomputed ``(P_j, C_j + crpd)`` terms.
    ``gang_wcet(t) + crpd`` is loop-invariant, so hoisting it out of the
    iteration (and the hp scan out of the taskset loop in
    ``schedulable``) cannot change a bit: the same floats are summed in
    the same order."""
    R = base
    cutoff = 1000 * period
    for _ in range(max_iter):
        interference = sum(math.ceil(R / p) * c for p, c in hp_terms)
        R_new = base + interference
        if abs(R_new - R) < 1e-12:
            return R_new
        if R_new > cutoff:
            return None
        R = R_new
    return None


def response_time(task: RTTask, taskset: Sequence[RTTask],
                  blocking: float = 0.0, crpd: float = 0.0,
                  max_iter: int = 10_000) -> Optional[float]:
    """Fixed-point RTA; returns None if divergent (> 1000 periods)."""
    C = gang_wcet(task) + crpd
    hp_terms = [(t.period, gang_wcet(t) + crpd) for t in taskset
                if t.prio > task.prio]
    return _fixed_point(C + blocking, hp_terms, task.period, max_iter)


def schedulable(taskset: Sequence[RTTask], blocking: float = 0.0,
                crpd: float = 0.0) -> Dict[str, Dict]:
    """Per-task response times vs deadlines (deadline = period)."""
    # gang_wcet memoized across the taskset and hp terms hoisted per
    # task: one O(n) pass each instead of O(n^2) recomputes per
    # fixed-point iteration, bit-identical results.
    gws = [gang_wcet(t) + crpd for t in taskset]
    out = {}
    for t, C in zip(taskset, gws):
        hp_terms = [(o.period, gw) for o, gw in zip(taskset, gws)
                    if o.prio > t.prio]
        R = _fixed_point(C + blocking, hp_terms, t.period, 10_000)
        out[t.name] = {
            "wcrt": R,
            "deadline": t.period,
            "ok": R is not None and R <= t.period + 1e-12,
        }
    return out


def total_utilization(taskset: Sequence[RTTask]) -> float:
    """Gang utilization sum C_i / P_i (single-core equivalent after the
    RT-Gang transform)."""
    return sum(gang_wcet(t) / t.period for t in taskset)


def co_sched_wcet(task: RTTask, taskset: Sequence[RTTask],
                  interference) -> float:
    """Pessimistic co-scheduling WCET: solo WCET times the worst pairwise
    slowdown over tasks that can overlap (the 10x-100x factors of paper §II).
    Used to contrast RTA under co-scheduling vs RT-Gang."""
    worst = 1.0
    for other in taskset:
        if other.uid == task.uid:
            continue
        if set(other.cores) & set(task.cores):
            continue  # same cores -> serialized by fixed-priority, not co-run
        worst = max(worst, interference(task.name, other.name))
    return gang_wcet(task) * worst
