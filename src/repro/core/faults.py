"""Fault injection and runtime overrun enforcement (DESIGN.md §11).

RT-Gang is a framework for *safety-critical* systems, yet the base
scheduler trusts every declared parameter: a job running past its
declared WCET holds the gang lock until it finishes, a permanently
stalled thread holds it forever, and a best-effort task generating more
traffic than declared eats into every window. This module supplies the
two missing halves:

* **Seeded, declarative fault plans** (``FaultPlan``) that make a task
  misbehave on purpose: WCET overruns (a job's actual work is a factor
  of its declaration), busy-hung member threads (a thread that never
  finishes its job), lost budget-lift wakeups (a throttle stall whose
  window-end wakeup is delayed or dropped), and best-effort tasks
  exceeding their declared traffic rate. Plans are resolved
  deterministically from ``(seed, task name, job index)`` so the
  quantum and event engines inject the *same* faults.

* **Runtime enforcement** (``Enforcement``): every RT job carries an
  enforcement budget derived from its declared WCET — ``factor`` x the
  declared per-thread work — and crossing it triggers a configurable
  action:

  - ``abort``:  count the miss, zero the job, release the gang lock and
    every held core immediately;
  - ``demote``: take the job off the RT path and run its remaining work
    as best-effort on its own (otherwise idle) cores, under whatever
    throttle budget the then-running gang enforces;
  - ``degrade``: mixed-criticality fallback — suspend every gang with
    lower declared ``criticality`` until the overrunning gang's job
    completes (or its wall-clock watchdog aborts it), then restore.

  The work budget is *isolation work*, not wall time: a legitimate job
  slowed by interference executes exactly its declared work and is
  never spuriously enforced, while a lying job is cut the moment it has
  executed ``factor x C_i`` — so the wall time it can hold the machine
  is bounded by ``factor x C_i x slowdown``, restoring the paper's
  ``B_i`` blocking bound (vgang/rta.py prices this as
  ``schedulable_vgangs_enforced``).

  ``watchdog_factor`` arms a wall-clock watchdog per job: at
  ``release + watchdog_factor x deadline`` an unfinished job is aborted
  regardless of the declared action (the wall clock is the last line of
  defense — it is the only thing that catches a job making *no*
  progress, e.g. one stalled forever by a lost wakeup, which never
  crosses a work budget).

``FaultManager`` is the per-run state machine both engines drive; the
executor (core/executor.py) implements the wall-clock watchdog natively
with real timers instead.
"""
from __future__ import annotations

import dataclasses
import random
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.gang import RTTask, Thread
from repro.obs.metrics import MetricsRegistry

_EPS = 1e-9

# A busy-hung thread is modeled as a job with this much remaining work:
# effectively infinite for any horizon, but finite so closed-form
# remaining-work arithmetic (executed = total - remaining) stays exact.
HUNG_WORK = 1e9


# ---------------------------------------------------------------------
# fault specs
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WcetOverrun:
    """Selected jobs of ``task`` execute ``factor`` x their declared
    per-thread work. ``jobs``: explicit job indices; None = every job
    independently with probability ``prob`` (seeded, engine-stable)."""
    task: str
    factor: float = 2.0
    jobs: Optional[Tuple[int, ...]] = None
    prob: float = 1.0


@dataclasses.dataclass(frozen=True)
class HungThread:
    """Thread ``thread`` (index into task.cores) of job ``job`` never
    finishes: it keeps executing — generating traffic and interference
    and holding the gang lock — forever (a runaway loop)."""
    task: str
    job: int = 0
    thread: int = 0


@dataclasses.dataclass(frozen=True)
class LostWakeup:
    """The ``nth`` throttle stall on ``core`` loses its window-end
    wakeup: the stall extends by ``extra`` ms past the scheduled
    release (``float('inf')`` = the wakeup never arrives)."""
    core: int
    nth: int = 1
    extra: float = float("inf")


@dataclasses.dataclass(frozen=True)
class BeOverrun:
    """Best-effort task ``task`` generates ``factor`` x its declared
    memory traffic rate (it lied about its bytes). The regulator
    contains this by construction — the *charged* rate is the actual
    one — so the fault shows up as earlier trips, never as RT misses."""
    task: str
    factor: float = 2.0


_FAULT_TYPES = (WcetOverrun, HungThread, LostWakeup, BeOverrun)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, seeded fault plan. Resolution is a pure function of
    ``(seed, task name, job index)``, so both engines — and repeated
    runs — inject identical faults."""
    faults: Tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        for sp in self.faults:
            if not isinstance(sp, _FAULT_TYPES):
                raise ValueError(f"unknown fault spec {sp!r}")
            if isinstance(sp, (WcetOverrun, BeOverrun)) and not sp.factor > 0:
                raise ValueError(f"{sp!r}: factor must be > 0")
            if isinstance(sp, WcetOverrun) and not 0.0 <= sp.prob <= 1.0:
                raise ValueError(f"{sp!r}: prob must be in [0, 1]")
            if isinstance(sp, HungThread) and (sp.job < 0 or sp.thread < 0):
                raise ValueError(f"{sp!r}: job/thread must be >= 0")
            if isinstance(sp, LostWakeup) and (sp.nth < 1 or
                                               not sp.extra > 0):
                raise ValueError(f"{sp!r}: nth >= 1 and extra > 0 required")

    # -- resolution (deterministic per (seed, name, index)) -----------
    def _hit(self, sp: WcetOverrun, idx: int) -> bool:
        if sp.jobs is not None:
            return idx in sp.jobs
        if sp.prob >= 1.0:
            return True
        # string seeding hashes via sha512: stable across processes
        return random.Random(
            f"{self.seed}:{sp.task}:{idx}").random() < sp.prob

    def overrun_factor(self, name: str, idx: int) -> float:
        f = 1.0
        for sp in self.faults:
            if isinstance(sp, WcetOverrun) and sp.task == name and \
                    self._hit(sp, idx):
                f = max(f, sp.factor)
        return f

    def hung_threads(self, name: str, idx: int) -> Tuple[int, ...]:
        return tuple(sp.thread for sp in self.faults
                     if isinstance(sp, HungThread) and sp.task == name
                     and sp.job == idx)

    def be_factor(self, name: str) -> float:
        f = 1.0
        for sp in self.faults:
            if isinstance(sp, BeOverrun) and sp.task == name:
                f = max(f, sp.factor)
        return f

    def lost_wakeups(self) -> List[LostWakeup]:
        return [sp for sp in self.faults if isinstance(sp, LostWakeup)]

    def faulty_rt_names(self) -> Set[str]:
        """Names of RT tasks this plan makes misbehave (the containment
        benchmarks compare every *other* task against the baseline)."""
        return {sp.task for sp in self.faults
                if isinstance(sp, (WcetOverrun, HungThread))}


# ---------------------------------------------------------------------
# enforcement config
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Enforcement:
    """Runtime enforcement policy (see module docstring).

    action:          "abort" | "demote" | "degrade".
    factor:          work budget = factor x declared per-thread WCET.
    watchdog_factor: arm a wall-clock watchdog at
                     ``release + watchdog_factor x deadline``; an
                     unfinished job is aborted there regardless of
                     ``action``. None = no watchdog.
    """
    action: str = "abort"
    factor: float = 1.0
    watchdog_factor: Optional[float] = None

    def __post_init__(self):
        if self.action not in ("abort", "demote", "degrade"):
            raise ValueError(f"unknown enforcement action {self.action!r}")
        if not self.factor > 0:
            raise ValueError("enforcement factor must be > 0")
        if self.watchdog_factor is not None and not self.watchdog_factor > 0:
            raise ValueError("watchdog_factor must be > 0 (or None)")


class _JobRecord:
    __slots__ = ("over", "watchdog_at", "enforced")

    def __init__(self, over: Dict[int, float],
                 watchdog_at: Optional[float]):
        # over[c]: remaining-work level at which the work budget is
        # crossed on core c (actual total - cap); <= 0 = cannot cross
        self.over = over
        self.watchdog_at = watchdog_at
        self.enforced: Optional[str] = None   # action taken, if any


class _DemJob:
    """A demoted job's best-effort residual, drained per core."""
    __slots__ = ("task", "index", "release", "residual", "finished")

    def __init__(self, task: RTTask, index: int, release: float,
                 residual: Dict[int, float]):
        self.task = task
        self.index = index
        self.release = release
        self.residual = residual
        self.finished = False


# ---------------------------------------------------------------------
# per-run state machine
# ---------------------------------------------------------------------

class FaultManager:
    """Injects a FaultPlan and enforces an Enforcement policy; one
    instance per Simulator run, driven identically by both engines.

    The engines own the mechanics (descheduling, event re-prediction);
    this object owns the decisions and the bookkeeping: actual-work
    inflation at release, work-budget / watchdog due checks, the
    demoted-residual pool, the criticality suspension set, and the
    lock-leak audit."""

    def __init__(self, tasks: Sequence[RTTask],
                 plan: Optional[FaultPlan],
                 enforcement: Optional[Enforcement],
                 metrics: Optional[MetricsRegistry] = None):
        self.plan = plan or FaultPlan()
        self.enf = enforcement
        self.tasks = {t.uid: t for t in tasks}
        self._rec: Dict[Tuple[int, int], _JobRecord] = {}
        # demoted residuals: core -> FIFO of _DemJob; threads cached so
        # the MemoryModel sees a stable occupant identity per (task, core)
        self._dem: Dict[int, deque] = {}
        self._dem_threads: Dict[Tuple[int, int], Thread] = {}
        # degraded mode
        self.suspended: Set[int] = set()          # suspended task uids
        self.degrading: Optional[Tuple[int, int]] = None   # (uid, job idx)
        self._parked: Dict[int, list] = {}        # event engine ready entries
        self.pending_audit: List[RTTask] = []
        # bound by the engine at run start
        self._misses: Optional[Dict[str, int]] = None
        self._miss_times: Optional[Dict[str, List[float]]] = None
        self._response: Optional[Dict[str, List[float]]] = None
        # fault counts are obs.metrics parity counters — both engines
        # must inject and enforce identically (tests/test_obs.py)
        reg = metrics if metrics is not None \
            else MetricsRegistry(enabled=False)
        self.metrics = metrics
        self._inj = {k: reg.counter("faults.injected", parity=True, kind=k)
                     for k in ("overrun", "hang", "lost_wakeup")}
        self._enf_counts = {a: reg.counter("faults.enforced", parity=True,
                                           action=a)
                            for a in ("abort", "demote", "degrade")}
        self._watchdog = reg.counter("faults.watchdog_fires", parity=True)
        self._leaks = reg.counter("faults.lock_leaks", parity=True)
        self._aborted_jobs: List[Tuple[str, int, float]] = []
        self._by_task: Dict[str, Dict[str, int]] = {}

    @property
    def stats(self) -> Dict:
        """The historical stats-dict shape, assembled from the metric
        counters (``aborted_jobs``/``by_task`` are shared references —
        ``summary()`` is the copying accessor)."""
        return {
            "injected_overruns": int(self._inj["overrun"].value),
            "injected_hangs": int(self._inj["hang"].value),
            "injected_lost_wakeups": int(self._inj["lost_wakeup"].value),
            "enforced": {a: int(c.value)
                         for a, c in self._enf_counts.items()},
            "watchdog_fires": int(self._watchdog.value),
            "lock_leaks": int(self._leaks.value),
            "aborted_jobs": self._aborted_jobs,
            "by_task": self._by_task,
        }

    # -- wiring -------------------------------------------------------
    def bind(self, misses: Dict[str, int],
             miss_times: Dict[str, List[float]],
             response: Dict[str, List[float]]) -> None:
        self._misses = misses
        self._miss_times = miss_times
        self._response = response

    def install(self, regulator) -> None:
        """Install the lost-wakeup fault as the regulator's
        ``stall_fault`` hook (throttle.py): the nth stall on a faulty
        core has its stall-until extended by the spec's ``extra``."""
        specs = self.plan.lost_wakeups()
        if not specs:
            return
        counts: Dict[int, int] = {}

        def hook(core: int, until: float) -> float:
            k = counts.get(core, 0) + 1
            counts[core] = k
            for sp in specs:
                if sp.core == core and sp.nth == k:
                    self._inj["lost_wakeup"].value += 1
                    return until + sp.extra
            return until

        regulator.stall_fault = hook

    # -- injection at release ----------------------------------------
    def on_release(self, job) -> None:
        """Inflate the job's actual work per the plan and register its
        enforcement record. Must run right after Job construction,
        before any engine prediction reads ``remaining``."""
        t = job.task
        f = self.plan.overrun_factor(t.name, job.index)
        hung = self.plan.hung_threads(t.name, job.index)
        if f > 1.0:
            self._inj["overrun"].value += 1
        if hung:
            self._inj["hang"].value += len(hung)
        if f > 1.0 or hung:
            for i, c in enumerate(t.cores):
                if i in hung:
                    job.remaining[c] = HUNG_WORK
                elif f > 1.0:
                    job.remaining[c] = job.remaining[c] * f
        if self.enf is None:
            return
        over = {c: job.remaining[c] - t.thread_wcet(c) * self.enf.factor
                for c in t.cores}
        wd = None
        if self.enf.watchdog_factor is not None:
            wd = job.release + self.enf.watchdog_factor * t.deadline
        if wd is not None or any(v > _EPS for v in over.values()):
            self._rec[(t.uid, job.index)] = _JobRecord(over, wd)

    # -- due checks ---------------------------------------------------
    def over_threshold(self, uid: int, idx: int,
                       core: int) -> Optional[float]:
        """Remaining-work level at which the work budget is crossed on
        ``core`` (the event engine predicts an _ENFORCE event there), or
        None if it cannot cross / was already enforced."""
        r = self._rec.get((uid, idx))
        if r is None or r.enforced is not None:
            return None
        ov = r.over.get(core, 0.0)
        return ov if ov > _EPS else None

    def watchdog_at(self, uid: int, idx: int) -> Optional[float]:
        r = self._rec.get((uid, idx))
        return r.watchdog_at if r is not None else None

    def due(self, job, now: float) -> Optional[str]:
        """Quantum-engine poll: is enforcement due for this job at
        ``now``? Returns "cap", "watchdog", or None."""
        r = self._rec.get((job.task.uid, job.index))
        if r is None:
            return None
        if r.enforced is None:
            for c, ov in r.over.items():
                if ov > _EPS and job.remaining.get(c, 0.0) <= ov + _EPS:
                    return "cap"
        if r.watchdog_at is not None and now >= r.watchdog_at - _EPS and \
                r.enforced in (None, "degrade"):
            return "watchdog"
        return None

    # -- firing -------------------------------------------------------
    def fire(self, job, now: float, via: str = "cap") -> Optional[str]:
        """Decide the enforcement action for ``job``. Returns the action
        the engine must apply, or None (already handled / nothing to
        do). The wall-clock watchdog always aborts: it is the last line
        of defense, and under ``degrade`` it is the escalation path that
        bounds how long lower-criticality gangs stay suspended."""
        r = self._rec.get((job.task.uid, job.index))
        if r is None or self.enf is None:
            return None
        if via == "watchdog":
            if r.enforced in ("abort", "demote"):
                return None          # already off the RT path
            self._watchdog.value += 1
            action = "abort"
        else:
            if r.enforced is not None:
                return None
            action = self.enf.action
        r.enforced = action
        self._enf_counts[action].value += 1
        per = self._by_task.setdefault(
            job.task.name, {"abort": 0, "demote": 0, "degrade": 0})
        per[action] += 1
        if action in ("abort", "demote"):
            # the gang lock must leave this job's cores once the
            # engine's scheduling round settles — audited there
            self.pending_audit.append(job.task)
        return action

    def record_abort(self, job, now: float) -> None:
        """An aborted job is a counted deadline miss at the abort
        instant (it will never complete)."""
        name = job.task.name
        self._misses[name] += 1
        self._miss_times[name].append(now)
        self._aborted_jobs.append((name, job.index, now))

    def audit(self, g, has_work) -> None:
        """Called by the engine after the scheduling round that follows
        an abort/demote settles: the glock may hold a core for the task
        only if a live successor job still has work there. ``has_work``:
        callable(uid, core) -> bool, engine-specific."""
        pending, self.pending_audit = self.pending_audit, []
        for t in pending:
            for th in g.gthreads:
                if th is not None and th.task.uid == t.uid and \
                        not has_work(t.uid, th.core):
                    self._leaks.value += 1

    # -- demoted-residual pool ---------------------------------------
    def begin_demote(self, job, now: float) -> None:
        """Snapshot the job's remaining work as a best-effort residual
        on its own cores (call *before* the engine zeroes
        ``remaining``). The residual runs whenever its core is free,
        ahead of best-effort fillers, under the ambient throttle budget;
        the late completion is recorded as the job's response."""
        t = job.task
        residual = {c: r for c, r in job.remaining.items() if r > _EPS}
        if not residual:
            return
        d = _DemJob(t, job.index, job.release, residual)
        for c in residual:
            self._dem.setdefault(c, deque()).append(d)
            if (t.uid, c) not in self._dem_threads:
                self._dem_threads[(t.uid, c)] = Thread(
                    task=t, core=c, index=t.cores.index(c))

    def dem_head(self, core: int) -> Optional[_DemJob]:
        q = self._dem.get(core)
        return q[0] if q else None

    def dem_thread(self, core: int) -> Optional[Thread]:
        q = self._dem.get(core)
        if not q:
            return None
        return self._dem_threads[(q[0].task.uid, core)]

    def dem_finish_core(self, core: int, now: float) -> bool:
        """Core ``core`` drained its share of the head residual. Returns
        True when the whole demoted job just completed (response and —
        inevitably — the miss are recorded then)."""
        q = self._dem[core]
        d = q.popleft()
        d.residual[core] = 0.0
        if d.finished or any(v > _EPS for v in d.residual.values()):
            return False
        d.finished = True
        rt = now - d.release
        self._response[d.task.name].append(rt)
        if rt > d.task.deadline + 1e-9:
            self._misses[d.task.name] += 1
            self._miss_times[d.task.name].append(now)
        return True

    # -- degraded mode ------------------------------------------------
    def begin_degrade(self, job, tasks: Sequence[RTTask]) -> Set[int]:
        """Suspend every task with strictly lower criticality than the
        overrunning job's until that job completes (or its watchdog
        aborts it). Returns the suspended uids (the engine dirties
        their cores)."""
        crit = job.task.criticality
        sus = {t.uid for t in tasks
               if t.uid != job.task.uid and t.criticality < crit}
        self.suspended = sus
        self.degrading = (job.task.uid, job.index)
        return sus

    def park(self, core: int, entry) -> None:
        """Event engine: a suspended task's ready-heap entry, popped on
        peek; re-pushed verbatim on restore."""
        self._parked.setdefault(core, []).append(entry)

    def maybe_restore(self, uid: int, idx: int):
        """Called on any job completion/abort: if it was the degrading
        job, lift the suspension. Returns (parked entries by core,
        previously suspended uids) for the engine to re-arm, or None."""
        if self.degrading != (uid, idx):
            return None
        self.degrading = None
        sus, self.suspended = self.suspended, set()
        parked, self._parked = self._parked, {}
        return parked, sus

    # -- reporting ----------------------------------------------------
    def summary(self) -> Dict:
        out = self.stats
        out["aborted_jobs"] = list(out["aborted_jobs"])
        out["by_task"] = {k: dict(v) for k, v in out["by_task"].items()}
        return out
