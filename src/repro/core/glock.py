"""The gang-scheduling lock — a faithful transcription of the paper's
Algorithms 1-4 (struct glock; acquire / try_release / gang-preemption /
pick_next_task_rt).

This is deliberately a plain-Python state machine over integer core ids so it
can be (a) unit-tested against every transition in the paper's pseudo-code,
(b) driven by the discrete-event simulator (core = CPU core), and (c) driven
by the fleet executor (core = mesh slice / lane). The spinlock of the paper
becomes a threading.Lock when driven concurrently; the simulator drives it
single-threaded.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Set

from repro.core.gang import RTTask, Thread
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class GLock:
    """struct glock (Algorithm 1, line 1-2)."""
    n_cores: int
    held_flag: bool = False
    locked_cores: int = 0                 # bitmask
    blocked_cores: int = 0                # bitmask
    leader: Optional[RTTask] = None
    gthreads: List[Optional[Thread]] = dataclasses.field(default=None)
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    # instrumentation lives in a MetricsRegistry (obs.metrics); pass one
    # to label/collect the series, or leave None for detached counters
    metrics: Optional[MetricsRegistry] = None

    def __post_init__(self):
        if self.gthreads is None:
            self.gthreads = [None] * self.n_cores
        reg = self.metrics if self.metrics is not None \
            else MetricsRegistry(enabled=False)
        # parity contract: both simulator engines must reproduce these
        # exactly (tests/test_obs.py)
        self.acq = reg.counter("glock.acquisitions", parity=True)
        self.preempt = reg.counter("glock.preemptions", parity=True)
        self.ipi = reg.counter("glock.ipis", parity=True)

    # compatibility views over the metric counters
    @property
    def acquisitions(self) -> int:
        return int(self.acq.value)

    @property
    def preemptions(self) -> int:
        return int(self.preempt.value)

    @property
    def ipis_sent(self) -> int:
        return int(self.ipi.value)

    # ---- bitmask helpers ---------------------------------------------------
    def _set(self, mask: int, cpu: int) -> int:
        return mask | (1 << cpu)

    def _clear(self, mask: int, cpu: int) -> int:
        return mask & ~(1 << cpu)

    def _is_zero(self, mask: int) -> bool:
        return mask == 0

    def cores_in(self, mask: int) -> List[int]:
        return [c for c in range(self.n_cores) if mask & (1 << c)]


class GangScheduler:
    """pick_next_task_rt with the one-gang-at-a-time invariant.

    ``reschedule_cpus`` is a callback(core_list) standing in for the
    rescheduling IPIs; the simulator re-runs scheduling on those cores, the
    executor wakes the slice workers.
    """

    def __init__(self, n_cores: int,
                 reschedule_cpus: Optional[Callable[[List[int]], None]] = None,
                 enabled: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.g = GLock(n_cores=n_cores, metrics=metrics)
        self.reschedule_cpus = reschedule_cpus or (lambda cores: None)
        self.enabled = enabled   # paper: runtime toggle via sched_features
        # gang hand-off hook: called with ("acquire"|"join"|"leave"|
        # "release"|"preempt", leader RTTask or None) whenever lock
        # ownership or membership changes — "join" when a core enters the
        # running gang at equal priority (Algorithm 1 line 14-15),
        # "leave" when a member thread departs while the lock stays held.
        # The event-driven engine counts hand-offs through it; the
        # executor applies throttle budgets on acquire/join/leave (the
        # live-member set moved) and wakes barrier waiters on "release".
        self.on_gang_change: Optional[
            Callable[[str, Optional[RTTask]], None]] = None

    # ---- Algorithm 2: acquire -----------------------------------------------
    def acquire_gang_lock(self, cpu: int, thread: Thread) -> None:
        g = self.g
        g.held_flag = True
        g.locked_cores = g._set(g.locked_cores, cpu)
        # the acquiring core may have blocked at line 18-19 earlier (e.g.
        # it now preempts the gang that blocked it); it is no longer
        # waiting, so drop its blocked bit or the next release sends it a
        # spurious reschedule IPI
        g.blocked_cores = g._clear(g.blocked_cores, cpu)
        g.leader = thread.task
        g.gthreads[cpu] = thread
        g.acq.value += 1
        if self.on_gang_change is not None:
            self.on_gang_change("acquire", g.leader)

    # ---- Algorithm 3: try release -------------------------------------------
    def try_glock_release(self, prev: Optional[Thread]) -> bool:
        """Returns True when ``prev`` departed while the lock stays held
        (a *partial* leave). The "leave" notification is deliberately
        NOT fired here: the caller (pick_next_task_rt) settles it after
        seeing what replaces ``prev`` — a same-task re-join at the next
        quantum means the member set never actually changed, and firing
        leave+join would transiently lift throttle caps a concurrent
        lock-free ``charge`` could slip through."""
        g = self.g
        if prev is None:
            return False
        left = False
        for cpu in g.cores_in(g.locked_cores):
            if g.gthreads[cpu] is prev:
                g.locked_cores = g._clear(g.locked_cores, cpu)
                g.gthreads[cpu] = None
                left = True
        if g._is_zero(g.locked_cores):
            g.held_flag = False
            g.leader = None
            blocked = g.cores_in(g.blocked_cores)
            if blocked:
                g.ipi.value += len(blocked)
                self.reschedule_cpus(blocked)
            g.blocked_cores = 0
            if self.on_gang_change is not None:
                self.on_gang_change("release", None)
            return False
        return left

    def _settle_leave(self, left: bool) -> None:
        """Emit the deferred partial-leave notification: the live-member
        set shrank (per-member budget floors may rise)."""
        if left and self.g.held_flag and self.on_gang_change is not None:
            self.on_gang_change("leave", self.g.leader)

    # ---- Algorithm 4: gang preemption ----------------------------------------
    def do_gang_preemption(self) -> List[int]:
        g = self.g
        victims = g.cores_in(g.locked_cores)
        if victims:
            g.ipi.value += len(victims)
            g.preempt.value += 1
            self.reschedule_cpus(victims)
        g.locked_cores = 0
        for cpu in victims:
            g.gthreads[cpu] = None
        if victims and self.on_gang_change is not None:
            self.on_gang_change("preempt", g.leader)
        return victims

    # ---- Algorithm 1: pick_next_task_rt ---------------------------------------
    def pick_next_task_rt(self, cpu: int, prev: Optional[Thread],
                          next_thread: Optional[Thread]) -> Optional[Thread]:
        """Returns the thread to run on ``cpu`` (None -> fall through to CFS).

        ``prev``: thread going off this core (may be None).
        ``next_thread``: highest-priority ready RT thread on this core's
        runqueue (may be None).
        """
        if not self.enabled:
            return next_thread
        g = self.g
        with g.lock:
            left = False
            if g.held_flag:
                left = self.try_glock_release(prev)              # Line 11
            if next_thread is None:
                self._settle_leave(left)
                return None
            task = next_thread.task
            if not g.held_flag:                                  # Line 12-13
                self.acquire_gang_lock(cpu, next_thread)
                return next_thread
            if task.prio == g.leader.prio:                       # Line 14-15
                g.locked_cores = g._set(g.locked_cores, cpu)
                # a core that blocked at line 18-19 and later joins the
                # running gang is no longer waiting: keep the blocked set
                # honest, or the eventual release IPIs it spuriously and
                # inflates ipis_sent
                g.blocked_cores = g._clear(g.blocked_cores, cpu)
                g.gthreads[cpu] = next_thread
                # same task re-picked at a quantum boundary: the member
                # set never changed — suppress the leave+join pair so
                # budget hooks see no transient cap lift
                if prev is None or task is not prev.task or not left:
                    self._settle_leave(left)
                    if self.on_gang_change is not None:
                        self.on_gang_change("join", g.leader)
                return next_thread
            if task.prio > g.leader.prio:                        # Line 16-17
                # pending leave is subsumed: preempt + acquire re-derive
                # the whole regime
                self.do_gang_preemption()
                self.acquire_gang_lock(cpu, next_thread)
                return next_thread
            # Line 18-19: lower priority -> blocked
            self._settle_leave(left)
            g.blocked_cores = g._set(g.blocked_cores, cpu)
            return None

    # ---- enforcement / watchdog support (DESIGN.md §11) -----------------------
    #
    # Watchdog ordering: an overrun/watchdog abort never mutates glock
    # state directly. The enforcer (FaultManager in the engines, the
    # executor's watchdog monitor) marks the faulty job dead and then
    # routes every held core through ``pick_next_task_rt(cpu, prev=
    # <held thread>, next=...)`` — the ready queue no longer offers the
    # dead job, so line 11's ``try_glock_release`` drops the core and,
    # on the last member, releases the lock. This keeps the abort on
    # the exact same code path as a natural departure: the gang-change
    # hook fires in its normal order ("leave" per surviving member
    # churn, then "release" or a successor's "acquire"), so budget
    # floors, reclaim-grant voiding, and barrier wakeups cannot be
    # reordered against lock ownership. ``force_release`` below is the
    # one-call wrapper for that pattern.

    def force_release(self, thread: Thread) -> List[int]:
        """Evict ``thread`` from every core it holds by driving each
        through the normal pick path with no successor offered (the
        caller must already have removed its job from the ready
        queues). Returns the cores released."""
        g = self.g
        with g.lock:
            held = [c for c in g.cores_in(g.locked_cores)
                    if g.gthreads[c] is thread]
        out = []
        for c in held:
            if self.pick_next_task_rt(c, thread, None) is None:
                out.append(c)
        return out

    def holds(self, task: RTTask) -> List[int]:
        """Cores on which the glock currently holds a thread of
        ``task`` (enforcement audits: after an abort settles, this must
        be empty unless a live successor job re-acquired)."""
        return [c for c, th in enumerate(self.g.gthreads)
                if th is not None and th.task.uid == task.uid]

    # ---- invariant (for property tests) ----------------------------------------
    def running_gang_prios(self) -> Set[int]:
        return {t.task.prio for t in self.g.gthreads if t is not None}

    def check_invariant(self) -> bool:
        """At most one distinct gang priority holds cores at any time."""
        return len(self.running_gang_prios()) <= 1
