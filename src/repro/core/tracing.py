"""Execution traces (KernelShark-lite): per-core timeline segments with an
ASCII renderer and CSV export, used by the simulator, the executor and the
Fig.5 benchmark."""
from __future__ import annotations

import csv
import dataclasses
import io
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(slots=True)
class Segment:
    core: int
    label: Optional[str]          # None = idle; "throttled:<task>" = stalled
    t0: float
    t1: float


class Trace:
    def __init__(self, n_cores: int):
        self.n_cores = n_cores
        self.segments: List[Segment] = []
        self._open: Dict[int, Segment] = {}

    def record(self, core: int, label: Optional[str], t0: float, t1: float):
        if t1 - t0 < 1e-12:      # zero-length (event-engine cascade) — skip
            return
        seg = self._open.get(core)
        if seg is not None:
            if seg.label == label and -1e-9 < seg.t1 - t0 < 1e-9:
                seg.t1 = t1
                return
            self.segments.append(seg)
        self._open[core] = Segment(core, label, t0, t1)

    def finish(self):
        for seg in self._open.values():
            self.segments.append(seg)
        self._open.clear()
        self.segments.sort(key=lambda s: (s.core, s.t0))

    def busy(self, label: str) -> float:
        self.finish_view()
        return sum(s.t1 - s.t0 for s in self.segments if s.label == label)

    def intervals(self, label: str, tol: float = 1e-9
                  ) -> List[Tuple[float, float]]:
        """Merged [t0, t1) intervals (across cores) during which ``label``
        ran anywhere. The quantum engine emits dt-sized touching segments,
        the event engine emits long exact ones; merging makes the two
        comparable for equivalence checks."""
        self.finish_view()
        segs = sorted(((s.t0, s.t1) for s in self.segments
                       if s.label == label))
        out: List[Tuple[float, float]] = []
        for t0, t1 in segs:
            if out and t0 <= out[-1][1] + tol:
                out[-1] = (out[-1][0], max(out[-1][1], t1))
            else:
                out.append((t0, t1))
        return out

    def finish_view(self):
        if self._open:
            self.finish()

    def to_csv(self) -> str:
        """CSV with properly quoted labels. ``throttled:<task>`` /
        ``dem:<task>`` labels (and any future label containing a comma
        or quote) round-trip through a standard CSV reader; an idle
        (None) segment writes an empty field, distinct from a literal
        task named "idle"."""
        self.finish_view()
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(["core", "label", "t0", "t1"])
        for s in self.segments:
            w.writerow([s.core, "" if s.label is None else s.label,
                        f"{s.t0:.4f}", f"{s.t1:.4f}"])
        return buf.getvalue().rstrip("\n")

    @classmethod
    def from_csv(cls, text: str, n_cores: Optional[int] = None) -> "Trace":
        """Inverse of ``to_csv`` (modulo the 1e-4 ms timestamp
        rounding)."""
        rows = list(csv.reader(io.StringIO(text)))
        assert rows and rows[0] == ["core", "label", "t0", "t1"], \
            "not a Trace CSV"
        body = [(int(c), lab or None, float(t0), float(t1))
                for c, lab, t0, t1 in rows[1:]]
        if n_cores is None:
            n_cores = max((c for c, *_ in body), default=-1) + 1
        tr = cls(n_cores)
        for core, lab, t0, t1 in body:
            tr.segments.append(Segment(core, lab, t0, t1))
        tr.segments.sort(key=lambda s: (s.core, s.t0))
        return tr

    def render_ascii(self, t_end: Optional[float] = None, width: int = 100,
                     t_start: float = 0.0) -> str:
        """One row per core; distinct letters per task label."""
        self.finish_view()
        if not self.segments:
            return "(empty trace)"
        if t_end is None:
            t_end = max(s.t1 for s in self.segments)
        labels = sorted({s.label for s in self.segments if s.label})
        letters = {}
        alphabet = "ABCDEFGHJKLMNPQRSTUVWXYZabcdefghjklmnpqrstuvwxyz"
        for i, lab in enumerate(labels):
            if lab.startswith("throttled:"):
                letters[lab] = "~"
            else:
                letters[lab] = alphabet[i % len(alphabet)]
        # a single-instant trace (every segment at one timestamp, or an
        # explicit t_end == t_start) has no extent to scale into the
        # row — render the instant as one column instead of dividing
        # by zero
        span = t_end - t_start
        if span <= 0:
            span, width = 1.0, 1
        rows = []
        for c in range(self.n_cores):
            row = ["."] * width
            for s in self.segments:
                if s.core != c or s.label is None:
                    continue
                i0 = int((max(s.t0, t_start) - t_start) / span * width)
                i1 = int((min(s.t1, t_end) - t_start) / span * width)
                for i in range(max(i0, 0), min(max(i1, i0 + 1), width)):
                    row[i] = letters[s.label]
            rows.append(f"core{c} |" + "".join(row) + "|")
        legend = "  ".join(f"{v}={k}" for k, v in letters.items()
                           if not k.startswith("throttled:"))
        return "\n".join(rows) + f"\n  [{t_start:.1f}..{t_end:.1f}ms] " + \
            legend + "  ~=throttled"


class NullTrace(Trace):
    """A trace that records nothing (``Simulator(trace=False)``).

    ``bench_sim.py --profile`` shows ``Segment`` allocation as the top
    allocator on the event-engine hot path; Monte-Carlo sim-checks (the
    acceptance grid, sweeps) never read the timeline, only the
    ``SimResult`` counters.  Dropping ``record`` to a no-op skips
    Segment construction entirely while every query keeps working
    against the empty timeline (``busy`` -> 0, ``intervals`` -> [],
    ``to_csv`` -> header only).  Counters, misses, percentiles and RTA
    margins are computed from the engines' own state, so results are
    byte-identical with tracing on or off (tested in
    tests/test_trace_optional.py)."""

    def record(self, core: int, label: Optional[str], t0: float, t1: float):
        pass
