"""Task model for RT-Gang: real-time gangs, virtual gangs, best-effort tasks.

Mirrors the paper's model (§III): a real-time gang is a set of threads
(possibly from multiple tasks — a *virtual gang*) sharing one distinct
real-time priority; priorities define gang identity (paper §IV-E: assigning
the same RT priority to several tasks *is* the virtual-gang mechanism).
Best-effort tasks have no RT priority and run under the fair scheduler on
idle cores, throttled to the running gang's declared memory-bandwidth budget.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_ids = itertools.count(1)


@dataclasses.dataclass
class Thread:
    """One schedulable thread, pinned to a core (no migration, paper §III-A)."""
    task: "RTTask"
    core: int
    index: int = 0

    @property
    def name(self) -> str:
        return f"{self.task.name}/t{self.index}"


@dataclasses.dataclass
class RTTask:
    """Periodic parallel real-time task (gang model: (C, P, k cores)).

    wcet:    per-job execution time of each thread in isolation (paper uses
             equal per-thread compute; a per-thread list is also accepted).
    period:  release period; deadline = period (implicit deadlines).
    cores:   cores its threads are pinned to.
    prio:    distinct fixed RT priority — HIGHER value = higher priority.
             Tasks sharing a prio form a *virtual gang*.
    mem_budget: tolerable best-effort memory traffic (bytes or abstract
             units per regulation interval) while this gang runs; 0 = total
             isolation (paper §III-B).
    mem_intensity: the gang's own memory-traffic intensity in [0, 1] —
             how aggressive a co-runner it is. Used by the virtual-gang
             formation heuristics (vgang/formation.py) to avoid packing
             two memory-hungry gangs into one virtual gang
             (arXiv:1912.10959 §V), and — through ``traffic_rate`` — as
             the traffic each of its threads charges against the
             bandwidth regulator (RTG-throttle, §IV-C: sibling members
             of a virtual gang are regulated like best-effort work).
    mem_rate: explicit per-thread traffic rate (units per ms of
             execution, the BETask.mem_rate scale); None derives it
             from mem_intensity.
    """
    name: str
    wcet: float
    period: float
    cores: Tuple[int, ...]
    prio: int
    mem_budget: float = 0.0
    mem_intensity: float = 0.0
    mem_rate: Optional[float] = None
    release_offset: float = 0.0
    n_jobs: Optional[int] = None          # None = unbounded
    wcet_per_core: Optional[Dict[int, float]] = None
    uid: int = dataclasses.field(default_factory=lambda: next(_ids))

    @property
    def traffic_rate(self) -> float:
        """Memory traffic each thread generates per ms it executes —
        the declared ``mem_rate``, defaulting to ``mem_intensity`` (an
        intensity-s gang produces s units/ms, the same abstract scale as
        BETask.mem_rate). Charged through the BandwidthRegulator by the
        MemoryModel so RT threads can trip per-core budgets."""
        return self.mem_rate if self.mem_rate is not None \
            else self.mem_intensity

    def thread_wcet(self, core: int) -> float:
        if self.wcet_per_core:
            return self.wcet_per_core.get(core, self.wcet)
        return self.wcet

    def release_time(self, k: int) -> Optional[float]:
        """Absolute release time of job ``k`` (None once past n_jobs)."""
        if self.n_jobs is not None and k >= self.n_jobs:
            return None
        return self.release_offset + k * self.period

    @property
    def deadline(self) -> float:
        """Implicit deadlines: deadline = period (paper §III)."""
        return self.period

    @property
    def n_threads(self) -> int:
        return len(self.cores)

    @property
    def utilization(self) -> float:
        return self.wcet / self.period


@dataclasses.dataclass
class BETask:
    """Best-effort task (CFS class). mem_rate: abstract memory traffic it
    generates per ms of execution (used by the throttling model)."""
    name: str
    cores: Tuple[int, ...]
    mem_rate: float = 0.0
    uid: int = dataclasses.field(default_factory=lambda: next(_ids))


def make_virtual_gang(name: str, members: Sequence[RTTask], prio: int,
                      mem_budget: float = 0.0) -> List[RTTask]:
    """Link tasks into a virtual gang by assigning them one shared priority
    (exactly the paper's mechanism, §IV-E). Returns the updated members."""
    out = []
    for t in members:
        out.append(dataclasses.replace(t, prio=prio, mem_budget=mem_budget,
                                       name=t.name))
    return out


def validate_taskset(tasks: Sequence[RTTask]) -> None:
    """Distinct priority per gang; no core pinned twice within one gang."""
    by_prio: Dict[int, List[RTTask]] = {}
    for t in tasks:
        by_prio.setdefault(t.prio, []).append(t)
    for prio, members in by_prio.items():
        cores = [c for t in members for c in t.cores]
        if len(cores) != len(set(cores)):
            raise ValueError(
                f"virtual gang at prio {prio} pins a core twice: {cores}")
