"""Task model for RT-Gang: real-time gangs, virtual gangs, best-effort tasks.

Mirrors the paper's model (§III): a real-time gang is a set of threads
(possibly from multiple tasks — a *virtual gang*) sharing one distinct
real-time priority; priorities define gang identity (paper §IV-E: assigning
the same RT priority to several tasks *is* the virtual-gang mechanism).
Best-effort tasks have no RT priority and run under the fair scheduler on
idle cores, throttled to the running gang's declared memory-bandwidth budget.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_ids = itertools.count(1)


@dataclasses.dataclass
class Thread:
    """One schedulable thread, pinned to a core (no migration, paper §III-A)."""
    task: "RTTask"
    core: int
    index: int = 0

    @property
    def name(self) -> str:
        return f"{self.task.name}/t{self.index}"


@dataclasses.dataclass
class RTTask:
    """Periodic parallel real-time task (gang model: (C, P, k cores)).

    wcet:    per-job execution time of each thread in isolation (paper uses
             equal per-thread compute; a per-thread list is also accepted).
    period:  release period; deadline = period (implicit deadlines).
    cores:   cores its threads are pinned to.
    prio:    distinct fixed RT priority — HIGHER value = higher priority.
             Tasks sharing a prio form a *virtual gang*.
    mem_budget: tolerable best-effort memory traffic (bytes or abstract
             units per regulation interval) while this gang runs; 0 = total
             isolation (paper §III-B).
    mem_intensity: the gang's own memory-traffic intensity in [0, 1] —
             how aggressive a co-runner it is. Used by the virtual-gang
             formation heuristics (vgang/formation.py) to avoid packing
             two memory-hungry gangs into one virtual gang
             (arXiv:1912.10959 §V), and — through ``traffic_rate`` — as
             the traffic each of its threads charges against the
             bandwidth regulator (RTG-throttle, §IV-C: sibling members
             of a virtual gang are regulated like best-effort work).
    mem_rate: explicit per-thread traffic rate (units per ms of
             execution, the BETask.mem_rate scale); None derives it
             from mem_intensity.
    """
    name: str
    wcet: float
    period: float
    cores: Tuple[int, ...]
    prio: int
    mem_budget: float = 0.0
    mem_intensity: float = 0.0
    mem_rate: Optional[float] = None
    release_offset: float = 0.0
    n_jobs: Optional[int] = None          # None = unbounded
    wcet_per_core: Optional[Dict[int, float]] = None
    # mixed-criticality level for degraded-mode enforcement
    # (core/faults.py): under ``degrade``, gangs with strictly lower
    # criticality than an overrunning gang are suspended until it
    # completes. 0 = lowest (default).
    criticality: int = 0
    uid: int = dataclasses.field(default_factory=lambda: next(_ids))

    def __post_init__(self):
        # construction-time declaration validation (ROADMAP item 5,
        # first slice): reject unambiguous nonsense with a clear error
        # instead of producing a garbage schedule. WCET > period is
        # deliberately NOT rejected here — analysis code legitimately
        # builds single-core-equivalent tasks whose inflated WCET
        # exceeds the period (that is exactly how vgang RTA reports an
        # unschedulable formation) and the acceptance grid simulates
        # overloaded sets; use ``validate_declared`` for the strict
        # check where declarations must be trustworthy (enforcement
        # budgets, config ingestion).
        if not self.cores:
            raise ValueError(f"task {self.name!r} pins no cores")
        if len(set(self.cores)) != len(self.cores):
            raise ValueError(
                f"task {self.name!r} pins a core twice: {self.cores}")
        if not self.wcet > 0.0:
            raise ValueError(
                f"task {self.name!r}: wcet must be > 0, got {self.wcet}")
        if not self.period > 0.0:
            raise ValueError(
                f"task {self.name!r}: period must be > 0, "
                f"got {self.period}")
        if self.wcet_per_core:
            for c, w in self.wcet_per_core.items():
                if not w > 0.0:
                    raise ValueError(
                        f"task {self.name!r}: wcet_per_core[{c}] must be "
                        f"> 0, got {w}")
        if not 0.0 <= self.mem_intensity <= 1.0:
            raise ValueError(
                f"task {self.name!r}: mem_intensity must be in [0, 1], "
                f"got {self.mem_intensity}")
        if self.mem_rate is not None and self.mem_rate < 0.0:
            raise ValueError(
                f"task {self.name!r}: mem_rate must be >= 0, "
                f"got {self.mem_rate}")
        if self.mem_budget < 0.0:
            raise ValueError(
                f"task {self.name!r}: mem_budget must be >= 0, "
                f"got {self.mem_budget}")
        if self.release_offset < 0.0:
            raise ValueError(
                f"task {self.name!r}: release_offset must be >= 0, "
                f"got {self.release_offset}")
        if self.n_jobs is not None and self.n_jobs < 0:
            raise ValueError(
                f"task {self.name!r}: n_jobs must be >= 0, "
                f"got {self.n_jobs}")

    @property
    def traffic_rate(self) -> float:
        """Memory traffic each thread generates per ms it executes —
        the declared ``mem_rate``, defaulting to ``mem_intensity`` (an
        intensity-s gang produces s units/ms, the same abstract scale as
        BETask.mem_rate). Charged through the BandwidthRegulator by the
        MemoryModel so RT threads can trip per-core budgets."""
        return self.mem_rate if self.mem_rate is not None \
            else self.mem_intensity

    def thread_wcet(self, core: int) -> float:
        if self.wcet_per_core:
            return self.wcet_per_core.get(core, self.wcet)
        return self.wcet

    def release_time(self, k: int) -> Optional[float]:
        """Absolute release time of job ``k`` (None once past n_jobs)."""
        if self.n_jobs is not None and k >= self.n_jobs:
            return None
        return self.release_offset + k * self.period

    @property
    def deadline(self) -> float:
        """Implicit deadlines: deadline = period (paper §III)."""
        return self.period

    @property
    def n_threads(self) -> int:
        return len(self.cores)

    @property
    def utilization(self) -> float:
        return self.wcet / self.period


@dataclasses.dataclass
class BETask:
    """Best-effort task (CFS class). mem_rate: abstract memory traffic it
    generates per ms of execution (used by the throttling model)."""
    name: str
    cores: Tuple[int, ...]
    mem_rate: float = 0.0
    uid: int = dataclasses.field(default_factory=lambda: next(_ids))

    def __post_init__(self):
        if not self.cores:
            raise ValueError(f"BE task {self.name!r} pins no cores")
        if self.mem_rate < 0.0:
            raise ValueError(
                f"BE task {self.name!r}: mem_rate must be >= 0, "
                f"got {self.mem_rate}")


def make_virtual_gang(name: str, members: Sequence[RTTask], prio: int,
                      mem_budget: float = 0.0) -> List[RTTask]:
    """Link tasks into a virtual gang by assigning them one shared priority
    (exactly the paper's mechanism, §IV-E). Returns the updated members."""
    out = []
    for t in members:
        out.append(dataclasses.replace(t, prio=prio, mem_budget=mem_budget,
                                       name=t.name))
    return out


def validate_declared(tasks: Sequence[RTTask]) -> None:
    """Strict declaration check for consumers that must *trust* the
    declarations (enforcement budgets derived from WCET — core/faults.py
    — and config ingestion): on top of construction-time validation,
    every declared per-thread WCET must fit the implicit deadline
    (= period). Kept separate from ``RTTask.__post_init__`` because the
    RTA layer legitimately constructs inflated-WCET equivalent tasks
    with wcet > period to *report* unschedulability."""
    for t in tasks:
        for c in t.cores:
            w = t.thread_wcet(c)
            if w > t.period + 1e-12:
                raise ValueError(
                    f"task {t.name!r}: declared WCET {w} on core {c} "
                    f"exceeds its period/deadline {t.period} — an "
                    f"enforcement budget derived from this declaration "
                    f"would be meaningless")


def validate_taskset(tasks: Sequence[RTTask]) -> None:
    """Distinct priority per gang; no core pinned twice within one gang."""
    by_prio: Dict[int, List[RTTask]] = {}
    for t in tasks:
        by_prio.setdefault(t.prio, []).append(t)
    for prio, members in by_prio.items():
        cores = [c for t in members for c in t.cores]
        if len(cores) != len(set(cores)):
            raise ValueError(
                f"virtual gang at prio {prio} pins a core twice: {cores}")
