"""Exact, event-driven scheduling engine — the dt -> 0 limit of the
fixed-quantum simulator in sim.py, at O(events) instead of
O(horizon/dt x cores x jobs) cost.

Design (DESIGN.md §8):

* **Heap event queue.** A single heapq holds job releases, thread
  completions, throttle trips (budget exhaustion) and throttle replenish /
  un-stall wakeups. Gang hand-offs (lock release -> blocked cores wake,
  gang preemption IPIs) are zero-delay events: the GangScheduler's
  ``reschedule_cpus`` callback feeds the dirty-core set that the same-
  timestamp scheduling fixed point drains, and ``on_gang_change`` counts
  them.
* **Closed-form advancement.** Between two consecutive events the set of
  co-runners — and therefore every thread's interference-adjusted rate —
  is constant, so remaining work decreases linearly and completion times
  are solved exactly (``t = now + remaining * slowdown``) instead of being
  discovered by dt-stepping.
* **Active-job pointers.** Each task keeps a deque of released-but-
  unfinished jobs; the head is the active job (O(1)), replacing the
  quantum loop's linear rescan of every completed job.
* **Priority-indexed ready queues.** Each core keeps a lazy max-heap of
  (−prio, submission-order, task-uid) entries pushed on job activation;
  stale entries (no pending work on that core) are popped on peek. This
  replaces the per-core O(tasks) scan.

Semantic parity with the quantum engine (asserted by tests/test_events.py
on the paper's Fig.4 and Fig.5 tasksets): identical GangScheduler state
machine, identical interference model, and the continuous-time limit of
the reactive bandwidth regulator (a best-effort core stalls the instant
its window budget is exhausted — the quantum engine overshoots by at most
one accounting quantum, which is exactly its O(dt) discretization bias).
Best-effort candidates sharing a core are modeled as fair fractional
co-runners (each gets 1/n of the core and generates 1/n of its traffic),
the dt -> 0 limit of the quantum loop's per-step round-robin.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.gang import RTTask, Thread

_EPS_T = 1e-9       # time comparison tolerance (ms)
_EPS_W = 1e-9       # work comparison tolerance (ms of compute)
_INF = float("inf")

# event kinds (heap tiebreak after time+seq; values are cosmetic)
_RELEASE, _COMPLETE, _EXHAUST, _UNSTALL = range(4)


class _TaskState:
    """Per-task release bookkeeping + the active-job pointer."""
    __slots__ = ("task", "queue", "released")

    def __init__(self, task: RTTask):
        self.task = task
        self.queue: deque = deque()      # released, unfinished jobs (FIFO)
        self.released = 0

    @property
    def active(self):
        return self.queue[0] if self.queue else None


class EventEngine:
    """Drives a Simulator's GangScheduler/BandwidthRegulator/Trace to an
    exact SimResult. Constructed by ``Simulator.run`` when ``dt is None``."""

    def __init__(self, sim):
        self.sim = sim
        self.events_processed = 0
        self.handoffs = 0

    # -----------------------------------------------------------------
    def run(self, horizon: float):
        from repro.core.sim import Job, SimResult

        sim = self.sim
        n = sim.n_cores
        sched, reg, trace = sim.sched, sim.reg, sim.trace
        interference = sim.interference
        tasks = list(sim.rt_tasks)
        order = {t.uid: i for i, t in enumerate(tasks)}
        threads: Dict[Tuple[int, int], Thread] = {
            (t.uid, c): Thread(task=t, core=c, index=i)
            for t in tasks for i, c in enumerate(t.cores)}
        tstate = {t.uid: _TaskState(t) for t in tasks}

        response: Dict[str, List[float]] = {t.name: [] for t in tasks}
        misses = {t.name: 0 for t in tasks}
        be_progress = {b.name: 0.0 for b in sim.be_tasks}
        slack = 0.0

        current: List[Optional[Thread]] = [None] * n
        slow = [1.0] * n                     # interference slowdown per core
        rt_sig: List[Optional[tuple]] = [None] * n
        be_cands: List[tuple] = [tuple(b for b in sim.be_tasks
                                       if c in b.cores) for c in range(n)]
        be_active: List[tuple] = [()] * n    # unstalled co-running BE tasks
        be_rate = [0.0] * n                  # aggregate traffic rate
        be_sig: List[Optional[tuple]] = [None] * n
        be_epoch = [0] * n
        stall_label: List[Optional[str]] = [None] * n

        ready: List[list] = [[] for _ in range(n)]
        heap: list = []
        seq = itertools.count()

        def push(t: float, kind: int, data) -> None:
            heapq.heappush(heap, (t, next(seq), kind, data))

        dirty = set()

        def _resched(cores):                 # gang hand-off / preemption IPI
            dirty.update(cores)
        sched.reschedule_cpus = _resched

        def _gang_change(event, leader):
            self.handoffs += 1
        sched.on_gang_change = _gang_change

        # ---- releases / activation ----------------------------------
        def activate(job) -> None:
            for c in job.task.cores:
                if job.remaining[c] > _EPS_W:
                    heapq.heappush(ready[c],
                                   (-job.task.prio, order[job.task.uid],
                                    job.task.uid))
                    dirty.add(c)

        def do_release(uid: int) -> None:
            ts = tstate[uid]
            t = ts.task
            rel = t.release_time(ts.released)
            if rel is None:
                return
            job = Job(task=t, release=rel, index=ts.released,
                      remaining={c: t.thread_wcet(c) for c in t.cores})
            ts.released += 1
            ts.queue.append(job)
            if len(ts.queue) == 1:
                activate(job)
            nxt = t.release_time(ts.released)
            if nxt is not None and nxt < horizon:
                push(nxt, _RELEASE, uid)

        for t in tasks:
            first = t.release_time(0)
            if first is not None and first < horizon:
                push(first, _RELEASE, t.uid)

        # ---- ready queue (lazy max-heap peek) -----------------------
        def ready_thread(c: int) -> Optional[Thread]:
            h = ready[c]
            while h:
                _, _, uid = h[0]
                j = tstate[uid].active
                if j is None or j.remaining.get(c, 0.0) <= _EPS_W:
                    heapq.heappop(h)
                    continue
                return threads[(uid, c)]
            return None

        # ---- scheduling fixed point (mirrors sim.py's pass loop) ----
        def fixed_point() -> None:
            for _ in range(4 + len(tasks)):
                if not dirty:
                    break
                todo = sorted(dirty)
                dirty.clear()
                for c in todo:
                    prev = current[c]
                    nxt = ready_thread(c)
                    current[c] = sched.pick_next_task_rt(c, prev, nxt)
            if sched.enabled:
                g = sched.g
                for c in range(n):
                    if current[c] is not None and \
                            g.gthreads[c] is not current[c]:
                        current[c] = g.gthreads[c]

        # ---- best-effort filling + interference rates ---------------
        def refill(now: float) -> None:
            for c in range(n):
                if current[c] is None and be_cands[c] and \
                        not reg.is_stalled(c, now):
                    cands = be_cands[c]
                    be_active[c] = cands
                    be_rate[c] = sum(b.mem_rate for b in cands) / len(cands)
                else:
                    be_active[c] = ()
                    be_rate[c] = 0.0

        def recompute_rates() -> None:
            for c in range(n):
                th = current[c]
                if th is None:
                    continue
                victim = th.task.name
                s = 1.0
                for cc in range(n):
                    if cc == c:
                        continue
                    other = current[cc]
                    if other is not None:
                        if other.task.name != victim:
                            f = interference(victim, other.task.name)
                            if f > s:
                                s = f
                    else:
                        for b in be_active[cc]:
                            if b.name != victim:
                                f = interference(victim, b.name)
                                if f > s:
                                    s = f
                slow[c] = s

        def push_updates(now: float) -> None:
            for c in range(n):
                th = current[c]
                if th is not None:
                    j = tstate[th.task.uid].active
                    if j is None:        # drained; reschedule at next event
                        dirty.add(c)
                        rt_sig[c] = None
                        be_sig[c] = None
                        continue
                    sig = (th.task.uid, j.index, slow[c])
                    if rt_sig[c] != sig:
                        rt_sig[c] = sig
                        push(now + j.remaining[c] * slow[c], _COMPLETE, c)
                    be_sig[c] = None
                    continue
                rt_sig[c] = None
                st = reg.cores[c]
                if st.stalled_until > now + _EPS_T:
                    sig = ("stalled", st.stalled_until)
                    if be_sig[c] != sig:
                        be_sig[c] = sig
                        be_epoch[c] += 1
                        push(st.stalled_until, _UNSTALL, c)
                elif be_active[c] and be_rate[c] > 0.0 and \
                        st.budget != _INF:
                    trip = reg.next_trip_time(c, be_rate[c], now)
                    sig = ("running", be_active[c], be_rate[c], st.budget,
                           trip)
                    if be_sig[c] != sig:
                        be_sig[c] = sig
                        be_epoch[c] += 1
                        if trip < horizon + _EPS_T and trip != _INF:
                            push(trip, _EXHAUST, (c, be_epoch[c]))
                else:
                    sig = ("free", be_active[c])
                    if be_sig[c] != sig:
                        be_sig[c] = sig
                        be_epoch[c] += 1

        # ---- closed-form interval advancement -----------------------
        def advance(t0: float, t1: float) -> None:
            nonlocal slack
            if t1 - t0 < 1e-12:
                return
            span = t1 - t0
            for c in range(n):
                th = current[c]
                if th is not None:
                    j = tstate[th.task.uid].active
                    if j is None:        # drained; idle until rescheduled
                        trace.record(c, None, t0, t1)
                        slack += span
                        continue
                    if j.start is None:
                        j.start = t0
                    j.remaining[c] = max(0.0,
                                         j.remaining[c] - span / slow[c])
                    trace.record(c, th.task.name, t0, t1)
                    continue
                slack += span
                if be_active[c]:
                    k = len(be_active[c])
                    sub = span / k
                    for i, b in enumerate(be_active[c]):
                        be_progress[b.name] += sub
                        trace.record(c, b.name, t0 + i * sub,
                                     t0 + (i + 1) * sub)
                    if be_rate[c] > 0.0:
                        reg.charge_span(c, be_rate[c], t0, t1)
                elif be_cands[c] and reg.is_stalled(c, t0):
                    trace.record(c, stall_label[c] or
                                 "throttled:" + be_cands[c][0].name, t0, t1)
                else:
                    trace.record(c, None, t0, t1)

        def detect_completions(now: float) -> None:
            for c in range(n):
                th = current[c]
                if th is None:
                    continue
                ts = tstate[th.task.uid]
                j = ts.active
                if j is None:
                    # a sibling core's iteration popped the finished job
                    # and the queue drained — this core must reschedule
                    dirty.add(c)
                    continue
                r = j.remaining.get(c)
                if r is None or r > _EPS_W:
                    continue
                j.remaining[c] = 0.0
                dirty.add(c)
                if j.done and j.finish is None:
                    j.finish = now
                    rt = now - j.release
                    response[th.task.name].append(rt)
                    if rt > th.task.deadline + 1e-9:
                        misses[th.task.name] += 1
                    ts.queue.popleft()
                    if ts.queue:
                        activate(ts.queue[0])

        # ---- main loop ----------------------------------------------
        now = 0.0
        fixed_point()
        refill(now)
        recompute_rates()
        push_updates(now)
        while True:
            t_next = min(heap[0][0], horizon) if heap else horizon
            advance(now, t_next)
            now = t_next
            detect_completions(now)
            while heap and heap[0][0] <= now + _EPS_T:
                _, _, kind, data = heapq.heappop(heap)
                self.events_processed += 1
                if now >= horizon - _EPS_T and kind == _RELEASE:
                    continue             # quantum engine never releases at T
                if kind == _RELEASE:
                    do_release(data)
                elif kind == _EXHAUST:
                    c, epoch = data
                    st = reg.cores[c]
                    if epoch == be_epoch[c] and be_rate[c] > 0.0 and \
                            st.budget != _INF and \
                            st.used >= st.budget - 1e-6:
                        reg.trip(c, now)
                        heavy = max(be_active[c] or be_cands[c],
                                    key=lambda b: b.mem_rate)
                        stall_label[c] = "throttled:" + heavy.name
                # _COMPLETE / _UNSTALL: pure wakeups — the state refresh
                # below observes the zero remaining / lifted stall.
            if now >= horizon - _EPS_T:
                break
            fixed_point()
            if sched.enabled and sim.budget_policy is not None:
                sim.budget_policy.apply(sched.g, reg)
            elif sched.enabled and sched.g.held_flag and \
                    sched.g.leader is not None:
                reg.set_gang_budget(sched.g.leader.mem_budget)
            else:
                reg.set_gang_budget(None)
            refill(now)
            recompute_rates()
            push_updates(now)

        throttle_events = sum(st.throttle_events
                              for st in reg.cores.values())
        return SimResult(
            trace=trace, response_times=response, deadline_misses=misses,
            be_progress=be_progress, throttle_events=throttle_events,
            ipis=sched.g.ipis_sent, preemptions=sched.g.preemptions,
            slack_time=slack, horizon=horizon,
            events=self.events_processed, engine="event")
