"""Exact, event-driven scheduling engine — the dt -> 0 limit of the
fixed-quantum simulator in sim.py, at O(events) instead of
O(horizon/dt x cores x jobs) cost.

Design (DESIGN.md §8, §10):

* **Heap event queue.** A single heapq holds job releases, thread
  completions, throttle trips (budget exhaustion) and throttle replenish /
  un-stall wakeups. Gang hand-offs (lock release -> blocked cores wake,
  gang preemption IPIs) are zero-delay events: the GangScheduler's
  ``reschedule_cpus`` callback feeds the dirty-core set that the same-
  timestamp scheduling fixed point drains, and ``on_gang_change`` counts
  them.
* **Lazy closed-form advancement.** Between two regime changes a core's
  occupant, slowdown and traffic rate are constant, so its remaining
  work decreases linearly and nothing needs stepping: each core carries
  a ``mat`` watermark and is *materialized* (work subtracted, traffic
  charged, trace recorded — all in closed form over the whole span)
  only when its own regime is about to change. A steady-state event
  therefore touches O(dirty) cores; untouched cores cost nothing, no
  matter how many cores the machine has.
* **Active-job pointers.** Each task keeps a deque of released-but-
  unfinished jobs; the head is the active job (O(1)), replacing the
  quantum loop's linear rescan of every completed job.
* **Priority-indexed ready queues.** Each core keeps a lazy max-heap of
  (−prio, submission-order, task-uid) entries pushed on job activation;
  stale entries (no pending work on that core) are popped on peek. This
  replaces the per-core O(tasks) scan.
* **Incremental co-runner sets (MemoryModel, DESIGN.md §10).** The old
  per-event ``recompute_rates`` rescan of every (core, core) pair is
  gone: occupancy lives in the shared MemoryModel, updates flow through
  a ``changed``-core set (scheduling deltas, budget-regime deltas,
  trip/unstall wakeups), and interference aggregates are memoized per
  victim name against the occupant-name-set epoch. Only a distinct-
  name-set change pays one cached-lookup sweep to re-pin completion
  predictions.
* **RT-thread bandwidth charging.** Running RT threads charge
  ``RTTask.traffic_rate`` through the regulator exactly like best-effort
  work; a tripped RT thread pauses mid-job — removed from occupancy (no
  traffic, no interference), its completion re-predicted on un-stall at
  the window boundary. This is what RTG-throttle (vgang/sched.py)
  drives: sibling members of a virtual gang are capped while the
  critical member runs unthrottled.

Semantic parity with the quantum engine (asserted by tests/test_events.py
and tests/test_memmodel.py on the paper's Fig.4 and Fig.5 tasksets):
identical GangScheduler state machine, identical MemoryModel, and the
continuous-time limit of the reactive bandwidth regulator (a core stalls
the instant its window budget is exhausted — the quantum engine
overshoots by at most one accounting quantum, which is exactly its O(dt)
discretization bias). Best-effort candidates sharing a core are modeled
as fair fractional co-runners (each gets 1/n of the core and generates
1/n of its traffic) in both engines.
"""
from __future__ import annotations

import heapq
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.gang import RTTask, Thread
from repro.core.memmodel import BE

_EPS_T = 1e-9       # time comparison tolerance (ms)
_EPS_W = 1e-9       # work comparison tolerance (ms of compute)
_INF = float("inf")

# event kinds (heap tiebreak after time+seq; values are cosmetic).
# _ENFORCE:     predicted work-budget crossing of a job (faults.py) —
#               validated against the materialized remaining at pop, so
#               stale predictions are harmless.
# _WATCHDOG:    a job's absolute wall-clock abort deadline (pushed once
#               at release; FaultManager decides whether it still applies).
# _DEMCOMPLETE: a demoted residual drains its share on one core.
(_RELEASE, _COMPLETE, _EXHAUST, _UNSTALL,
 _ENFORCE, _WATCHDOG, _DEMCOMPLETE) = range(7)


class _TaskState:
    """Per-task release bookkeeping + the active-job pointer."""
    __slots__ = ("task", "queue", "released")

    def __init__(self, task: RTTask):
        self.task = task
        self.queue: deque = deque()      # released, unfinished jobs (FIFO)
        self.released = 0

    @property
    def active(self):
        return self.queue[0] if self.queue else None


class EventEngine:
    """Drives a Simulator's GangScheduler/BandwidthRegulator/MemoryModel/
    Trace to an exact SimResult. Constructed by ``Simulator.run`` when
    ``dt is None``."""

    def __init__(self, sim):
        self.sim = sim
        self.events_processed = 0
        self.handoffs = 0
        self.releases = 0
        self.phase_wall: Dict[str, float] = {}
        self._gang_dirty = False

    # -----------------------------------------------------------------
    def run(self, horizon: float):
        from repro.core.sim import Job

        sim = self.sim
        n = sim.n_cores
        sched, reg, trace, mm = sim.sched, sim.reg, sim.trace, sim.mm
        record = trace.record       # hot: bound once (no-op for NullTrace)
        tasks = list(sim.rt_tasks)
        order = {t.uid: i for i, t in enumerate(tasks)}
        threads: Dict[Tuple[int, int], Thread] = {
            (t.uid, c): Thread(task=t, core=c, index=i)
            for t in tasks for i, c in enumerate(t.cores)}
        tstate = {t.uid: _TaskState(t) for t in tasks}

        profile = bool(getattr(sim, "profile", False))
        phase_wall = self.phase_wall
        if profile:
            for k in ("fixed_point", "rates", "push_updates", "advance",
                      "events"):
                phase_wall[k] = 0.0
        perf = time.perf_counter

        response: Dict[str, List[float]] = {t.name: [] for t in tasks}
        misses = {t.name: 0 for t in tasks}
        miss_times: Dict[str, List[float]] = {t.name: [] for t in tasks}
        be_progress = {b.name: 0.0 for b in sim.be_tasks}
        fm = sim.fm
        fm.bind(misses, miss_times, response)
        slack = 0.0

        current: List[Optional[Thread]] = [None] * n
        slow = [1.0] * n                     # interference slowdown per core
        mat = [0.0] * n                      # per-core materialized-to time
        rt_sig: List[Optional[tuple]] = [None] * n   # completion-push sig
        chg_sig: List[Optional[tuple]] = [None] * n  # charging-push sig
        core_epoch = [0] * n                 # _EXHAUST validity guard
        rt_stalled = [False] * n
        stall_label: List[Optional[str]] = [None] * n
        be_cands, be_names = sim.be_cands, sim.be_names
        be_rate = sim.be_share_rate
        reclaim = reg.reclaim
        mm_epoch = mm.agg_epoch - 1          # force first reconcile sweep

        ready: List[list] = [[] for _ in range(n)]
        heap: list = []
        seq = itertools.count()

        def push(t: float, kind: int, data) -> None:
            heapq.heappush(heap, (t, next(seq), kind, data))

        dirty = set()        # cores needing a scheduling pass
        changed = set()      # cores whose occupancy/throttle regime moved

        def _resched(cores):                 # gang hand-off / preemption IPI
            dirty.update(cores)
        sched.reschedule_cpus = _resched

        # reclaim-grant voiding + the gang-event log live in the shared
        # Simulator.gang_hook (the quantum engine installs the same
        # callbacks); cur_t keeps the log stamped with event time
        cur_t = [0.0]
        extra_hook = sim.gang_hook(cur_t)

        def _gang_change(event, leader):
            # joins/leaves mark the regime dirty but are membership
            # churn, not lock hand-offs — keep the metric's meaning
            if event in ("acquire", "release", "preempt"):
                self.handoffs += 1
            if extra_hook is not None:
                extra_hook(event, leader)
            self._gang_dirty = True
        sched.on_gang_change = _gang_change

        # ---- lazy advancement ---------------------------------------
        def materialize(c: int, t: float) -> None:
            """Apply core ``c``'s constant regime over [mat[c], t): work
            progress, traffic charging and trace, all closed-form. Must
            run before any regime ingredient of ``c`` changes (occupant,
            slowdown, stall state, active job)."""
            nonlocal slack
            t0 = mat[c]
            mat[c] = t
            if t - t0 < 1e-12:
                return
            if profile:
                t_p = perf()
            th = current[c]
            if th is not None:
                j = tstate[th.task.uid].active
                if j is None:        # drained; idle until rescheduled
                    record(c, None, t0, t)
                    slack += t - t0
                elif rt_stalled[c]:
                    # paused mid-job: no progress, no traffic
                    record(c, stall_label[c] or
                                 "throttled:" + th.task.name, t0, t)
                else:
                    if j.start is None:
                        j.start = t0
                    j.remaining[c] = max(0.0,
                                         j.remaining[c] - (t - t0) / slow[c])
                    r = mm.rates[c]
                    if r > 0.0:
                        reg.charge_span(c, r, t0, t)
                    record(c, th.task.name, t0, t)
            elif fm.dem_thread(c) is not None:
                # demoted residual (faults.py): drains on the free core
                # ahead of BE fillers, charging its own traffic, under
                # the ambient throttle budget; not counted as slack
                dth = fm.dem_thread(c)
                d = fm.dem_head(c)
                if rt_stalled[c]:
                    record(c, stall_label[c] or
                                 "throttled:" + dth.task.name, t0, t)
                else:
                    d.residual[c] = max(0.0,
                                        d.residual[c] - (t - t0) / slow[c])
                    r = mm.rates[c]
                    if r > 0.0:
                        reg.charge_span(c, r, t0, t)
                    record(c, "dem:" + dth.task.name, t0, t)
            else:
                slack += t - t0
                if mm.kind[c] == BE:
                    cands = be_cands[c]
                    k = len(cands)
                    if k == 1:
                        be_progress[cands[0].name] += t - t0
                        record(c, cands[0].name, t0, t)
                    else:
                        sub = (t - t0) / k
                        for i, b in enumerate(cands):
                            be_progress[b.name] += sub
                            record(c, b.name, t0 + i * sub,
                                         t0 + (i + 1) * sub)
                    r = mm.rates[c]
                    if r > 0.0:
                        reg.charge_span(c, r, t0, t)
                elif be_cands[c]:    # idle-with-candidates == stalled
                    record(c, stall_label[c] or
                                 "throttled:" + be_cands[c][0].name, t0, t)
                else:
                    record(c, None, t0, t)
            if profile:
                phase_wall["advance"] += perf() - t_p

        # ---- releases / activation ----------------------------------
        def activate(job) -> None:
            for c in job.task.cores:
                if job.remaining[c] > _EPS_W:
                    heapq.heappush(ready[c],
                                   (-job.task.prio, order[job.task.uid],
                                    job.task.uid))
                    dirty.add(c)
                    changed.add(c)   # a continuing thread needs a re-push

        def do_release(uid: int) -> None:
            ts = tstate[uid]
            t = ts.task
            rel = t.release_time(ts.released)
            if rel is None:
                return
            job = Job(task=t, release=rel, index=ts.released,
                      remaining={c: t.thread_wcet(c) for c in t.cores})
            fm.on_release(job)
            ts.released += 1
            ts.queue.append(job)
            if len(ts.queue) == 1:
                activate(job)
            wd = fm.watchdog_at(t.uid, job.index)
            if wd is not None and wd <= horizon + _EPS_T:
                push(wd, _WATCHDOG, (t.uid, job.index))
            nxt = t.release_time(ts.released)
            if nxt is not None and nxt < horizon:
                push(nxt, _RELEASE, uid)

        for t in tasks:
            first = t.release_time(0)
            if first is not None and first < horizon:
                push(first, _RELEASE, t.uid)

        # ---- ready queue (lazy max-heap peek) -----------------------
        def ready_thread(c: int) -> Optional[Thread]:
            h = ready[c]
            while h:
                e = h[0]
                uid = e[2]
                if uid in fm.suspended:
                    # degraded mode: park the entry; re-pushed verbatim
                    # when the suspension lifts
                    heapq.heappop(h)
                    fm.park(c, e)
                    continue
                j = tstate[uid].active
                if j is None or j.remaining.get(c, 0.0) <= _EPS_W:
                    heapq.heappop(h)
                    continue
                return threads[(uid, c)]
            return None

        def has_work(uid: int, core: int) -> bool:
            j = tstate[uid].active
            return j is not None and j.remaining.get(core, 0.0) > _EPS_W

        def find_job(uid: int, idx: int):
            for j in tstate[uid].queue:
                if j.index == idx:
                    return j
            return None

        # ---- scheduling fixed point (mirrors sim.py's pass loop) ----
        def fixed_point(now: float) -> set:
            touched = set()
            for _ in range(4 + len(tasks)):
                if not dirty:
                    break
                todo = sorted(dirty)
                dirty.clear()
                for c in todo:
                    prev = current[c]
                    picked = sched.pick_next_task_rt(c, prev,
                                                     ready_thread(c))
                    if picked is not prev:
                        materialize(c, now)
                        current[c] = picked
                        touched.add(c)
            if sched.enabled and self._gang_dirty:
                # sync preempted cores with the glock (only needed when
                # lock ownership actually moved this round)
                g = sched.g
                for c in range(n):
                    if current[c] is not None and \
                            g.gthreads[c] is not current[c]:
                        materialize(c, now)
                        current[c] = g.gthreads[c]
                        touched.add(c)
            return touched

        # ---- occupancy refresh (dirty cores only) -------------------
        def refresh(cores, now: float) -> None:
            for c in cores:
                if mat[c] < now:
                    materialize(c, now)
                occ = current[c] if current[c] is not None \
                    else fm.dem_thread(c)
                stalled = mm.refresh_core(c, occ, be_names[c],
                                          be_rate[c], now)
                if stalled and not rt_stalled[c]:
                    stall_label[c] = "throttled:" + occ.task.name
                rt_stalled[c] = stalled

        def reconcile(push_set, now: float) -> None:
            """Re-read slowdown aggregates. If the distinct occupant-name
            set moved, sweep RT cores against the per-victim memo (cache
            hits, O(1) each) and re-pin only the cores whose aggregate
            actually changed; otherwise only the dirty cores can have a
            new victim."""
            nonlocal mm_epoch
            if mm.agg_epoch != mm_epoch:
                mm_epoch = mm.agg_epoch
                for c in range(n):
                    th = current[c] if current[c] is not None \
                        else fm.dem_thread(c)
                    if th is None or rt_stalled[c]:
                        continue
                    s = mm.slowdown(th.task.name, c)
                    if s != slow[c]:
                        materialize(c, now)
                        slow[c] = s
                        push_set.add(c)
            else:
                for c in tuple(push_set):
                    th = current[c] if current[c] is not None \
                        else fm.dem_thread(c)
                    if th is not None and not rt_stalled[c]:
                        slow[c] = mm.slowdown(th.task.name, c)

        # ---- event (re)prediction for dirty cores -------------------
        def push_updates(cores, now: float) -> None:
            for c in cores:
                th = current[c]
                if th is not None:
                    j = tstate[th.task.uid].active
                    if j is None:        # drained; reschedule at next event
                        dirty.add(c)
                        rt_sig[c] = None
                        chg_sig[c] = None
                        continue
                    if rt_stalled[c]:
                        st = reg.cores[c]
                        s = ("rt-stalled", st.stalled_until)
                        if chg_sig[c] != s:
                            chg_sig[c] = s
                            core_epoch[c] += 1
                            push(st.stalled_until, _UNSTALL, c)
                        rt_sig[c] = None     # re-pin completion on resume
                        continue
                    s = (th.task.uid, j.index, slow[c])
                    if rt_sig[c] != s:
                        rt_sig[c] = s
                        push(now + j.remaining[c] * slow[c], _COMPLETE, c)
                        # work-budget crossing (faults.py): predicted at
                        # the instant the remaining work sinks to the
                        # over-threshold; validated at pop so stale
                        # predictions (slowdown changed, stalled) are
                        # harmless
                        ov = fm.over_threshold(th.task.uid, j.index, c)
                        if ov is not None and j.remaining[c] > ov + _EPS_W:
                            te = now + (j.remaining[c] - ov) * slow[c]
                            if te <= horizon + _EPS_T:
                                push(te, _ENFORCE, (th.task.uid, j.index))
                    trip = mm.next_trip_time(c, now)
                    s = ("rt-run", th.task.uid, j.index, mm.rates[c],
                         reg.cores[c].budget, trip)
                    if chg_sig[c] != s:
                        chg_sig[c] = s
                        core_epoch[c] += 1
                        if trip != _INF and trip < horizon + _EPS_T:
                            push(trip, _EXHAUST, (c, core_epoch[c]))
                    continue
                dth = fm.dem_thread(c)
                if dth is not None:
                    d = fm.dem_head(c)
                    if rt_stalled[c]:
                        st = reg.cores[c]
                        s = ("dem-stalled", st.stalled_until)
                        if chg_sig[c] != s:
                            chg_sig[c] = s
                            core_epoch[c] += 1
                            push(st.stalled_until, _UNSTALL, c)
                        rt_sig[c] = None
                        continue
                    s = ("dem", dth.task.uid, d.index, slow[c])
                    if rt_sig[c] != s:
                        rt_sig[c] = s
                        push(now + d.residual[c] * slow[c],
                             _DEMCOMPLETE, c)
                    trip = mm.next_trip_time(c, now)
                    s = ("dem-run", dth.task.uid, d.index, mm.rates[c],
                         reg.cores[c].budget, trip)
                    if chg_sig[c] != s:
                        chg_sig[c] = s
                        core_epoch[c] += 1
                        if trip != _INF and trip < horizon + _EPS_T:
                            push(trip, _EXHAUST, (c, core_epoch[c]))
                    continue
                rt_sig[c] = None
                st = reg.cores[c]
                if st.stalled_until > now + _EPS_T:
                    s = ("stalled", st.stalled_until)
                    if chg_sig[c] != s:
                        chg_sig[c] = s
                        core_epoch[c] += 1
                        push(st.stalled_until, _UNSTALL, c)
                elif mm.kind[c] == BE and mm.rates[c] > 0.0 and \
                        st.budget != _INF:
                    trip = mm.next_trip_time(c, now)
                    s = ("be-run", mm.names[c], mm.rates[c], st.budget,
                         trip)
                    if chg_sig[c] != s:
                        chg_sig[c] = s
                        core_epoch[c] += 1
                        if trip != _INF and trip < horizon + _EPS_T:
                            push(trip, _EXHAUST, (c, core_epoch[c]))
                else:
                    s = ("free", mm.names[c])
                    if chg_sig[c] != s:
                        chg_sig[c] = s
                        core_epoch[c] += 1

        def detect_completions(cores, now: float) -> None:
            for c in sorted(cores):
                th = current[c]
                if th is None:
                    continue
                if mat[c] < now:
                    materialize(c, now)
                ts = tstate[th.task.uid]
                j = ts.active
                if j is None:
                    # a sibling core's completion popped the finished job
                    # and the queue drained — this core must reschedule
                    dirty.add(c)
                    changed.add(c)
                    continue
                r = j.remaining.get(c)
                if r is None or r > _EPS_W:
                    continue             # stale prediction: superseded
                j.remaining[c] = 0.0
                dirty.add(c)
                changed.add(c)
                if j.done and j.finish is None:
                    j.finish = now
                    rt = now - j.release
                    response[th.task.name].append(rt)
                    if rt > th.task.deadline + 1e-9:
                        misses[th.task.name] += 1
                        miss_times[th.task.name].append(now)
                    ts.queue.popleft()
                    if ts.queue:
                        activate(ts.queue[0])
                    restore_from(th.task.uid, j.index)

        # ---- enforcement mechanics (faults.py, DESIGN.md §11) -------
        def restore_from(uid: int, idx: int) -> None:
            """If (uid, idx) was the degrading job, lift the suspension:
            re-arm parked ready entries and reschedule the restored
            tasks' cores."""
            res = fm.maybe_restore(uid, idx)
            if res is None:
                return
            parked, sus = res
            for c, entries in parked.items():
                for e in entries:
                    heapq.heappush(ready[c], e)
                dirty.add(c)
                changed.add(c)
            for u in sus:
                for c in tstate[u].task.cores:
                    dirty.add(c)
                    changed.add(c)

        def apply_enforcement(action: str, j, now: float) -> None:
            """Apply a FaultManager decision: settle the job's cores,
            then degrade (suspend lower-criticality gangs), demote
            (snapshot the residual), or abort — the latter two take the
            job off the RT path; the scheduling fixed point that follows
            releases its gang-lock cores through the normal pick path."""
            t = j.task
            ts = tstate[t.uid]
            for c in t.cores:
                if mat[c] < now:
                    materialize(c, now)
            if action == "degrade":
                sus = fm.begin_degrade(j, tasks)
                for u in sus:
                    for c in tstate[u].task.cores:
                        dirty.add(c)
                        changed.add(c)
                return
            if action == "demote":
                # snapshot the residual before zeroing
                fm.begin_demote(j, now)
            for c in t.cores:
                j.remaining[c] = 0.0
            if action == "abort":
                j.aborted = True
                fm.record_abort(j, now)
            if ts.queue and ts.queue[0] is j:
                ts.queue.popleft()
                if ts.queue:
                    activate(ts.queue[0])
            else:
                try:
                    ts.queue.remove(j)
                except ValueError:
                    pass
            if action == "abort":
                restore_from(t.uid, j.index)
            for c in t.cores:
                dirty.add(c)
                changed.add(c)
                rt_sig[c] = None

        def timed(key, t_p, a0):
            phase_wall[key] += (perf() - t_p) - (phase_wall["advance"] - a0)

        # ---- main loop ----------------------------------------------
        now = 0.0
        changed.update(range(n))
        changed.update(sim.apply_budget_rule())
        refresh(sorted(changed), now)
        reconcile(changed, now)
        push_updates(sorted(changed), now)
        changed.clear()
        while True:
            now = min(heap[0][0], horizon) if heap else horizon
            cur_t[0] = now
            if profile:
                t_p, a0 = perf(), phase_wall["advance"]
            comp = ()
            while heap and heap[0][0] <= now + _EPS_T:
                _, _, kind, data = heapq.heappop(heap)
                self.events_processed += 1
                if kind == _RELEASE:
                    if now >= horizon - _EPS_T:
                        continue         # quantum engine never releases at T
                    self.releases += 1
                    do_release(data)
                elif kind == _COMPLETE:
                    if not comp:
                        comp = set()
                    comp.add(data)
                elif kind == _EXHAUST:
                    c, epoch = data
                    if epoch != core_epoch[c]:
                        continue         # superseded prediction
                    materialize(c, now)
                    st = reg.cores[c]
                    if mm.rates[c] > 0.0 and st.budget != _INF and \
                            st.used >= st.limit - 1e-6:
                        th = current[c]
                        if reclaim and th is not None and \
                                mm.claim(c, th.task.name, mm.rates[c],
                                         now) > 0.0:
                            # donated quota covers (part of) the rest of
                            # the window: don't trip — the raised limit
                            # re-pins the trip prediction
                            changed.add(c)
                        else:
                            mm.trip(c, now)
                            occ = th if th is not None \
                                else fm.dem_thread(c)
                            if occ is not None:
                                stall_label[c] = ("throttled:"
                                                  + occ.task.name)
                            elif be_cands[c]:
                                heavy = max(be_cands[c],
                                            key=lambda b: b.mem_rate)
                                stall_label[c] = "throttled:" + heavy.name
                            changed.add(c)
                elif kind == _UNSTALL:   # pure wakeup
                    changed.add(data)
                elif kind == _ENFORCE:
                    uid, idx = data
                    j = find_job(uid, idx)
                    if j is not None and not j.aborted:
                        for c in j.task.cores:
                            if mat[c] < now:
                                materialize(c, now)
                        # completion at the same instant wins (the
                        # quantum engine's advance-then-enforce order)
                        via = fm.due(j, now) if not j.done else None
                        if via is not None:
                            action = fm.fire(j, now, via)
                            if action is not None:
                                apply_enforcement(action, j, now)
                elif kind == _WATCHDOG:
                    uid, idx = data
                    j = find_job(uid, idx)
                    if j is not None and not j.aborted:
                        for c in j.task.cores:
                            if mat[c] < now:
                                materialize(c, now)
                        action = None if j.done else \
                            fm.fire(j, now, "watchdog")
                        if action is not None:
                            apply_enforcement(action, j, now)
                else:                    # _DEMCOMPLETE
                    c = data
                    if mat[c] < now:
                        materialize(c, now)
                    d = fm.dem_head(c)
                    if d is not None and current[c] is None and \
                            d.residual.get(c, 1.0) <= _EPS_W:
                        fm.dem_finish_core(c, now)
                        dirty.add(c)
                        changed.add(c)
                        rt_sig[c] = None
            if comp:
                detect_completions(comp, now)
            if profile:
                timed("events", t_p, a0)
            if now >= horizon - _EPS_T:
                for c in range(n):
                    if mat[c] < horizon:
                        materialize(c, horizon)
                break
            if profile:
                t_p, a0 = perf(), phase_wall["advance"]
            touched = fixed_point(now)
            changed.update(touched)
            if fm.pending_audit:
                # the scheduling round after an abort/demote settled:
                # the gang lock must have left the dead job's cores
                fm.audit(sched.g, has_work)
            if profile:
                timed("fixed_point", t_p, a0)
                t_p, a0 = perf(), phase_wall["advance"]
            if touched or self._gang_dirty:
                self._gang_dirty = False
                changed.update(sim.apply_budget_rule())
            if changed:
                refresh(sorted(changed), now)
                reconcile(changed, now)
                if reclaim:
                    # a donor may have gone idle in this round: retry
                    # stalled RT threads against the pool (core order —
                    # the quantum engine's per-step retry order); a
                    # granted draw lifts the stall and resumes the
                    # thread at this very instant
                    lifted = [c for c in range(n)
                              if rt_stalled[c] and current[c] is not None
                              and mm.claim_lift(c, current[c].task, now)]
                    if lifted:
                        changed.update(lifted)
                        refresh(lifted, now)
                        reconcile(set(lifted), now)
                if profile:
                    timed("rates", t_p, a0)
                    t_p, a0 = perf(), phase_wall["advance"]
                push_updates(sorted(changed), now)
                changed.clear()
                if profile:
                    timed("push_updates", t_p, a0)
            elif profile:
                timed("rates", t_p, a0)

        return sim.finalize_result(
            trace, response, misses, miss_times, be_progress, slack,
            horizon,
            releases={t.name: tstate[t.uid].released for t in tasks},
            events=self.events_processed, engine="event")
