"""Gang-scheduled executor for real JAX workloads.

The TPU-fleet adaptation of RT-Gang (DESIGN.md §2): "cores" become *lanes*
(device slices / host workers), threads become per-lane quanta of a job step
(one inference, one training microstep), and the gang lock serializes RT jobs
fleet-wide while best-effort quanta fill idle lanes under byte-budget
admission control.

Differences from the kernel implementation, modeled explicitly:
* no mid-quantum preemption — gang preemption takes effect at quantum
  boundaries, contributing the blocking term B_i = max lower-prio quantum to
  RTA (core/rta.py);
* throttling is admission-based (quantum bytes known from
  ``compiled.cost_analysis()``) rather than perf-counter-reactive;
* straggler mitigation: per-quantum deadline monitor with optional
  speculative backup dispatch of idempotent quanta onto idle lanes.

Works with any callables; benchmarks bind jitted JAX functions per lane.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gang import RTTask, Thread
from repro.core.glock import GangScheduler
from repro.core.throttle import BandwidthRegulator
from repro.core.tracing import Trace

_uid = itertools.count(1)


@dataclasses.dataclass
class RTJob:
    """A periodic real-time job: each release runs ``fn(lane, job_idx)`` on
    every lane in ``lanes`` simultaneously (the gang)."""
    name: str
    fn: Callable[[int, int], None]
    lanes: Tuple[int, ...]
    prio: int
    period_s: Optional[float] = None       # None => single job
    budget_bytes: float = 0.0              # BE budget while this gang runs
    n_jobs: Optional[int] = None
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))


@dataclasses.dataclass
class BEJob:
    name: str
    fn: Callable[[int], None]              # fn(lane)
    lanes: Tuple[int, ...]
    bytes_per_quantum: float = 0.0
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))


@dataclasses.dataclass
class _JobInstance:
    job: RTJob
    index: int
    release: float
    remaining_lanes: set
    start: Optional[float] = None
    finish: Optional[float] = None


class GangExecutor:
    def __init__(self, n_lanes: int, *, enabled: bool = True,
                 regulation_interval_s: float = 0.010,
                 straggler_factor: float = 3.0,
                 backup_dispatch: bool = False):
        self.n_lanes = n_lanes
        self.enabled = enabled
        self.sched = GangScheduler(n_lanes, enabled=enabled)
        # wake blocked lanes promptly on gang hand-off (lock released or
        # preempted) instead of having them poll. Lock order: glock.g.lock
        # is only ever taken *outside* self._lock, so notifying under
        # self._lock from inside the glock callback cannot deadlock.
        self.sched.on_gang_change = self._on_gang_change
        self.reg = BandwidthRegulator(n_lanes,
                                      interval=regulation_interval_s,
                                      mode="admission")
        self.trace = Trace(n_lanes)
        self.rt_jobs: List[RTJob] = []
        self.be_jobs: List[BEJob] = []
        self._instances: Dict[int, List[_JobInstance]] = {}
        self._tasks: Dict[int, RTTask] = {}
        self._threads: Dict[Tuple[int, int], Thread] = {}
        # per-lane lazy max-heaps of (-prio, seq, job uid, instance idx),
        # pushed on release, stale entries popped on peek — the event
        # engine's ready-queue structure, so fleet-size dispatch over
        # hundreds of lanes is O(log n) instead of an O(jobs) scan
        self._ready: List[list] = [[] for _ in range(n_lanes)]
        self._ready_seq = itertools.count()
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._stop = False
        self.straggler_factor = straggler_factor
        self.backup_dispatch = backup_dispatch
        self.stragglers: List[Tuple[str, int, float]] = []
        self.response_times: Dict[str, List[float]] = {}
        self.be_quanta: Dict[str, int] = {}
        self._ema: Dict[str, float] = {}
        self._t0 = 0.0
        # lanes currently *executing* an RT quantum -> gang prio. A newly
        # scheduled gang waits for other gangs' in-flight quanta to drain
        # (the executor analogue of the preemption IPI + context switch;
        # bounded by one quantum = the B_i blocking term in core/rta.py).
        self._inflight: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def submit_rt(self, job: RTJob):
        self.rt_jobs.append(job)
        self._instances[job.uid] = []
        self.response_times.setdefault(job.name, [])
        # mirror as an RTTask (same uid!) so the glock state machine sees
        # gang identity and picked.task.uid maps back to the job
        self._tasks[job.uid] = RTTask(
            name=job.name, wcet=0.0, period=(job.period_s or 1e9) * 1e3,
            cores=job.lanes, prio=job.prio, mem_budget=job.budget_bytes,
            uid=job.uid)
        for i, lane in enumerate(job.lanes):
            self._threads[(job.uid, lane)] = Thread(
                task=self._tasks[job.uid], core=lane, index=i)

    def submit_be(self, job: BEJob):
        self.be_jobs.append(job)
        self.be_quanta.setdefault(job.name, 0)

    # ------------------------------------------------------------------
    def _on_gang_change(self, event: str, leader) -> None:
        if event in ("release", "preempt"):
            with self._wake:
                self._wake.notify_all()

    def _next_release_in(self, now: float) -> Optional[float]:
        """Seconds until the earliest future RT release (None = no more)."""
        best: Optional[float] = None
        for job in self.rt_jobs:
            insts = self._instances[job.uid]
            n = len(insts)
            if job.n_jobs is not None and n >= job.n_jobs:
                continue
            if n == 0:
                return 0.0
            if job.period_s is None:
                continue
            delta = insts[-1].release + job.period_s - now
            if best is None or delta < best:
                best = delta
        return best

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _release_jobs(self):
        now = self._now()
        for job in self.rt_jobs:
            insts = self._instances[job.uid]
            n = len(insts)
            if job.n_jobs is not None and n >= job.n_jobs:
                continue
            period = job.period_s
            next_rel = 0.0 if n == 0 else (insts[-1].release + (period or 0))
            if period is None and n > 0:
                continue
            if now + 1e-9 >= next_rel:
                insts.append(_JobInstance(
                    job=job, index=n, release=next_rel,
                    remaining_lanes=set(job.lanes)))
                seq = next(self._ready_seq)
                for lane in job.lanes:
                    heapq.heappush(self._ready[lane],
                                   (-job.prio, seq, job.uid, n))

    def _ready_thread(self, lane: int) -> Optional[Thread]:
        """Highest-priority released job with work left on this lane —
        lazy max-heap peek (same-priority ties go to the earlier
        release). Callers hold self._lock."""
        h = self._ready[lane]
        while h:
            _, _, uid, idx = h[0]
            inst = self._instances[uid][idx]
            if lane not in inst.remaining_lanes:
                heapq.heappop(h)         # quantum retired: stale entry
                continue
            return self._threads[(uid, lane)]
        return None

    def _active_instance(self, job: RTJob, lane: int) -> Optional[_JobInstance]:
        return next((i for i in self._instances[job.uid]
                     if lane in i.remaining_lanes), None)

    # ------------------------------------------------------------------
    def _worker(self, lane: int):
        prev: Optional[Thread] = None
        while True:
            with self._lock:
                if self._stop:
                    return
                self._release_jobs()
                nxt = self._ready_thread(lane)
            picked = self.sched.pick_next_task_rt(lane, prev, nxt)
            prev = None
            if picked is not None:
                job = next(j for j in self.rt_jobs
                           if j.uid == picked.task.uid)
                self.reg.set_gang_budget(job.budget_bytes)
                inst = None
                with self._lock:
                    inst = self._active_instance(job, lane)
                if inst is None:
                    prev = picked
                    continue
                # gang-isolation barrier: wait out other gangs' in-flight
                # quanta. Condition-variable wakeups (notified when any
                # quantum retires and on gang hand-offs) replace the old
                # sleep-poll so idle lanes don't burn CPU while they wait.
                with self._wake:
                    while True:
                        if self._stop:
                            return
                        others = [p for ln, p in self._inflight.items()
                                  if ln != lane and p != job.prio]
                        if not others:
                            self._inflight[lane] = job.prio
                            break
                        self._wake.wait(timeout=0.05)
                t0 = self._now()
                if inst.start is None:
                    inst.start = t0
                try:
                    job.fn(lane, inst.index)
                finally:
                    with self._wake:
                        self._inflight.pop(lane, None)
                        self._wake.notify_all()
                t1 = self._now()
                self.trace.record(lane, job.name, t0 * 1e3, t1 * 1e3)
                dur = t1 - t0
                key = job.name
                ema = self._ema.get(key)
                if ema is not None and dur > self.straggler_factor * ema:
                    self.stragglers.append((key, lane, dur))
                self._ema[key] = dur if ema is None else \
                    0.9 * ema + 0.1 * dur
                with self._lock:
                    inst.remaining_lanes.discard(lane)
                    if not inst.remaining_lanes and inst.finish is None:
                        inst.finish = t1
                        self.response_times[job.name].append(
                            inst.finish - inst.release)
                prev = picked
                continue

            # best-effort filling under admission throttling
            ran_be = False
            for be in self.be_jobs:
                if lane not in be.lanes:
                    continue
                now = self._now()
                if self.reg.charge(lane, be.bytes_per_quantum, now):
                    t0 = self._now()
                    be.fn(lane)
                    t1 = self._now()
                    self.trace.record(lane, be.name, t0 * 1e3, t1 * 1e3)
                    self.be_quanta[be.name] += 1
                    ran_be = True
                    break
            if not ran_be:
                # idle lane: sleep on the condition variable until the next
                # RT release is due, a quantum retires, or a gang hand-off
                # frees work — not a fixed-period poll.
                with self._wake:
                    if self._stop:
                        return
                    delta = self._next_release_in(self._now())
                    timeout = 0.05 if delta is None else \
                        min(max(delta, 0.0002), 0.05)
                    self._wake.wait(timeout=timeout)

    # ------------------------------------------------------------------
    def run(self, duration_s: float):
        self._t0 = time.monotonic()
        workers = [threading.Thread(target=self._worker, args=(lane,),
                                    daemon=True)
                   for lane in range(self.n_lanes)]
        for w in workers:
            w.start()
        time.sleep(duration_s)
        with self._wake:
            self._stop = True
            self._wake.notify_all()
        for w in workers:
            w.join(timeout=5.0)
        self.trace.finish_view()
        return {
            "response_times": self.response_times,
            "be_quanta": dict(self.be_quanta),
            "stragglers": list(self.stragglers),
            "preemptions": self.sched.g.preemptions,
            "acquisitions": self.sched.g.acquisitions,
        }
