"""Gang-scheduled executor for real JAX workloads.

The TPU-fleet adaptation of RT-Gang (DESIGN.md §2): "cores" become *lanes*
(device slices / host workers), threads become per-lane quanta of a job step
(one inference, one training microstep), and the gang lock serializes RT jobs
fleet-wide while best-effort quanta fill idle lanes under byte-budget
admission control.

Differences from the kernel implementation, modeled explicitly:
* no mid-quantum preemption — gang preemption takes effect at quantum
  boundaries, contributing the blocking term B_i = max lower-prio quantum to
  RTA (core/rta.py);
* throttling is admission-based (quantum bytes known from
  ``compiled.cost_analysis()``) rather than perf-counter-reactive;
* straggler mitigation: per-quantum deadline monitor with optional
  speculative backup dispatch of idempotent quanta onto idle lanes.

Virtual gangs (DESIGN.md §2.4): ``submit_vgang`` flattens a formed
``vgang.formation.VirtualGang`` onto disjoint lane blocks (the same
member remapping the simulator policy uses) and a ``budget_policy`` —
normally ``vgang.sched.VirtualGangPolicy`` — sets per-lane throttle
budgets from the glock's live-member state. Budgets are applied *only*
from the gang-change hook, under the glock: a worker that picked a gang
but lost the ownership race (or is still draining the gang-isolation
barrier) never writes budgets, so a stale lane cannot clobber the
running gang's regime.

Works with any callables; benchmarks bind jitted JAX functions per lane.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.gang import RTTask, Thread, _ids
from repro.core.glock import GangScheduler
from repro.core.throttle import BandwidthRegulator
from repro.core.tracing import Trace

# job uids share the RTTask counter so that virtual-gang members —
# whose RTJobs reuse the member task's uid (submit_vgang) — can never
# collide with uids handed to plain submit_rt jobs
_uid = _ids


@dataclasses.dataclass
class RTJob:
    """A periodic real-time job: each release runs ``fn(lane, job_idx)`` on
    every lane in ``lanes`` simultaneously (the gang)."""
    name: str
    fn: Callable[[int, int], None]
    lanes: Tuple[int, ...]
    prio: int
    period_s: Optional[float] = None       # None => single job
    budget_bytes: float = 0.0              # BE budget while this gang runs
    n_jobs: Optional[int] = None
    # bytes one quantum of *this* job moves. When the lane's enforced
    # budget is finite (an RTG-throttle sibling cap), the quantum is
    # admission-charged against it and the lane stalls to the next
    # regulation window on denial — the executor analogue of the
    # engines' RT-thread charging (DESIGN.md §10.1). 0 = never gated.
    bytes_per_quantum: float = 0.0
    # declared wall-clock WCET of one quantum (seconds). Feeds the
    # glock mirror task and — with the executor's ``watchdog_factor`` —
    # the per-quantum watchdog deadline (DESIGN.md §11.4).
    wcet_s: Optional[float] = None
    # explicit per-quantum watchdog deadline (seconds): a quantum still
    # in flight this long after dispatch has its whole gang aborted so
    # a hung member thread cannot deadlock the gang-isolation barrier.
    # None = derive from wcet_s x watchdog_factor, or the executor-wide
    # ``watchdog_s`` default.
    watchdog_s: Optional[float] = None
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))


@dataclasses.dataclass
class BEJob:
    name: str
    fn: Callable[[int], None]              # fn(lane)
    lanes: Tuple[int, ...]
    bytes_per_quantum: float = 0.0
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))


@dataclasses.dataclass
class _JobInstance:
    job: RTJob
    index: int
    release: float
    remaining_lanes: set
    start: Optional[float] = None
    finish: Optional[float] = None
    aborted: bool = False          # watchdog killed this gang release


class GangExecutor:
    def __init__(self, n_lanes: int, *, enabled: bool = True,
                 regulation_interval_s: float = 0.010,
                 straggler_factor: float = 3.0,
                 backup_dispatch: bool = False,
                 budget_policy=None, reclaim: bool = False,
                 watchdog_s: Optional[float] = None,
                 watchdog_factor: Optional[float] = None,
                 metrics=None):
        """``budget_policy``: optional object with ``apply(glock,
        regulator)`` — the same interface ``Simulator`` takes
        (vgang/sched.py) — invoked from the gang-change hook to set
        per-lane budgets from the live-member state. ``None`` falls back
        to the paper's rule: the leader's declared budget on every lane
        the gang does not occupy.

        ``reclaim``: mid-window bandwidth donation (DESIGN.md §7.5) at
        admission granularity — a gated sibling quantum that would be
        denied first draws the unspent window quota of member lanes
        whose work for this release already retired.

        ``watchdog_s`` / ``watchdog_factor`` arm the per-lane wall-clock
        watchdog (DESIGN.md §11.4): a quantum still in flight past its
        deadline — ``job.watchdog_s``, else ``watchdog_factor x
        job.wcet_s``, else ``watchdog_s`` — has its whole gang aborted:
        the instance is marked, the gang's glock hold is released lane
        by lane through ``pick_next_task_rt`` (so budget floors and
        wakeups run in the normal gang-change hook order) and the hung
        lane retires from the gang-isolation barrier, unblocking waiting
        gangs. The hung callable itself cannot be killed — it keeps
        running on its worker thread and its eventual return is
        discarded — but it no longer holds any scheduling state."""
        self.n_lanes = n_lanes
        self.enabled = enabled
        self.budget_policy = budget_policy
        # observability (DESIGN.md §12): one registry shared with the
        # glock and regulator; None = detached instruments (bare mode)
        from repro.obs.metrics import MetricsRegistry
        self.metrics = metrics
        self._mreg = metrics if metrics is not None \
            else MetricsRegistry(enabled=False)
        self.sched = GangScheduler(n_lanes, enabled=enabled,
                                   metrics=self._mreg)
        # wake blocked lanes promptly on gang hand-off (lock released or
        # preempted) instead of having them poll. Lock order: glock.g.lock
        # is only ever taken *outside* self._lock, so notifying under
        # self._lock from inside the glock callback cannot deadlock.
        self.sched.on_gang_change = self._on_gang_change
        self.reg = BandwidthRegulator(n_lanes,
                                      interval=regulation_interval_s,
                                      mode="admission", reclaim=reclaim,
                                      metrics=self._mreg)
        self.trace = Trace(n_lanes)
        self.rt_jobs: List[RTJob] = []
        self.be_jobs: List[BEJob] = []
        self._jobs: Dict[int, RTJob] = {}          # uid -> job (O(1) map)
        self._instances: Dict[int, List[_JobInstance]] = {}
        self._tasks: Dict[int, RTTask] = {}
        self._threads: Dict[Tuple[int, int], Thread] = {}
        # per-lane lazy max-heaps of (-prio, seq, job uid, instance idx),
        # pushed on release, stale entries popped on peek — the event
        # engine's ready-queue structure, so fleet-size dispatch over
        # hundreds of lanes is O(log n) instead of an O(jobs) scan
        self._ready: List[list] = [[] for _ in range(n_lanes)]
        self._ready_seq = itertools.count()
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._stop = False
        self.straggler_factor = straggler_factor
        self.backup_dispatch = backup_dispatch
        self.stragglers: List[Tuple[str, int, float]] = []
        self.response_times: Dict[str, List[float]] = {}
        # per-name obs.metrics counters (executor.* series); the
        # be_quanta / rt_stalls / aborted properties expose the
        # historical plain-dict views
        self._be_q: Dict[str, object] = {}
        self._stall_c: Dict[str, object] = {}
        self._abort_c: Dict[str, object] = {}
        self._ema: Dict[str, float] = {}
        self._budget_sig = None     # last glock state budgets derive from
        # gang prios whose in-flight quanta were still draining when the
        # current leader's budgets were applied: until they retire, the
        # enforced regime is the element-wise min over (outgoing,
        # incoming) — see _apply_budgets / _end_drain
        self._draining: frozenset = frozenset()
        self._t0 = 0.0
        # lanes currently *executing* an RT quantum -> gang prio. A newly
        # scheduled gang waits for other gangs' in-flight quanta to drain
        # (the executor analogue of the preemption IPI + context switch;
        # bounded by one quantum = the B_i blocking term in core/rta.py).
        self._inflight: Dict[int, int] = {}
        # watchdog bookkeeping: lane -> (job uid, instance idx, dispatch
        # time, deadline or None), maintained exactly alongside _inflight
        self.watchdog_s = watchdog_s
        self.watchdog_factor = watchdog_factor
        self._inflight_info: Dict[int, tuple] = {}
        self.watchdog_aborts: List[Tuple[str, int, int, float]] = []

    # compatibility dict views over the executor.* metric counters
    @property
    def be_quanta(self) -> Dict[str, int]:
        return {k: int(c.value) for k, c in self._be_q.items()}

    @property
    def rt_stalls(self) -> Dict[str, int]:
        return {k: int(c.value) for k, c in self._stall_c.items()}

    @property
    def aborted(self) -> Dict[str, int]:
        return {k: int(c.value) for k, c in self._abort_c.items()}

    def _counter_for(self, table: Dict[str, object], series: str,
                     name: str):
        c = table.get(name)
        if c is None:
            c = table[name] = self._mreg.counter(series, gang=name)
        return c

    # ------------------------------------------------------------------
    def submit_rt(self, job: RTJob):
        if job.uid in self._instances:
            raise ValueError(f"duplicate RT job uid {job.uid} "
                             f"({job.name!r})")
        if job.lanes and max(job.lanes) >= self.n_lanes:
            raise ValueError(f"job {job.name!r} pins lane "
                             f"{max(job.lanes)}, executor has "
                             f"{self.n_lanes}")
        self.rt_jobs.append(job)
        self._jobs[job.uid] = job
        self._instances[job.uid] = []
        self.response_times.setdefault(job.name, [])
        # mirror as an RTTask (same uid!) so the glock state machine sees
        # gang identity and picked.task.uid maps back to the job. The
        # mirror's wcet is the declared quantum wall time (sim-ms scale);
        # undeclared jobs get a positive placeholder — the glock never
        # reads it, and RTTask rejects wcet <= 0 at construction.
        self._tasks[job.uid] = RTTask(
            name=job.name, wcet=max(job.wcet_s or 0.0, 1e-9) * 1e3,
            period=(job.period_s or 1e9) * 1e3,
            cores=job.lanes, prio=job.prio, mem_budget=job.budget_bytes,
            uid=job.uid)
        for i, lane in enumerate(job.lanes):
            self._threads[(job.uid, lane)] = Thread(
                task=self._tasks[job.uid], core=lane, index=i)

    def submit_be(self, job: BEJob):
        self.be_jobs.append(job)
        self._counter_for(self._be_q, "executor.be_quanta", job.name)

    def submit_vgang(self, vg, fns: Dict[str, Callable[[int, int], None]],
                     *, n_jobs: Optional[int] = None,
                     time_scale: float = 1e-3,
                     bytes_per_quantum: Optional[Dict[str, float]] = None
                     ) -> List[RTJob]:
        """Submit a formed virtual gang (vgang/formation.VirtualGang):
        members are flattened onto disjoint lane blocks with the same
        remapping the simulator policy uses (vgang/sched.remap_members),
        share the vgang's priority, and — sharing one period with zero
        offset — release synchronously, so the glock dispatches them as
        one unit (Algorithm 1 line 14-15). Member uids are preserved, so
        a ``VirtualGangPolicy`` installed as ``budget_policy`` resolves
        its per-member budget tables against the mirrored threads.

        ``fns`` maps member task name -> quantum callable(lane, idx);
        ``time_scale`` converts task-time periods (sim ms) to wall
        seconds; ``bytes_per_quantum`` optionally declares per-member
        quantum traffic for RTG-throttle admission gating."""
        from repro.vgang.sched import remap_members
        members = remap_members(vg)
        # validate the whole gang before submitting any member: a lane
        # or uid rejection halfway through must not leave a half gang
        # behind (one member dispatching at the vgang's priority without
        # its siblings or their budget floors)
        for m in members:
            if m.uid in self._instances:
                raise ValueError(f"duplicate RT job uid {m.uid} "
                                 f"({m.name!r})")
            if m.cores and max(m.cores) >= self.n_lanes:
                raise ValueError(
                    f"virtual gang {vg.name!r} needs lane "
                    f"{max(m.cores)}, executor has {self.n_lanes}")
            if m.name not in fns:
                raise ValueError(f"virtual gang {vg.name!r}: no quantum "
                                 f"callable for member {m.name!r}")
        jobs = []
        for m in members:
            job = RTJob(
                name=m.name, fn=fns[m.name], lanes=m.cores, prio=m.prio,
                period_s=m.period * time_scale,
                budget_bytes=m.mem_budget,
                n_jobs=n_jobs if n_jobs is not None else m.n_jobs,
                bytes_per_quantum=(bytes_per_quantum or {}).get(m.name,
                                                                0.0),
                uid=m.uid)
            self.submit_rt(job)
            jobs.append(job)
        return jobs

    # ------------------------------------------------------------------
    def _apply_budgets(self) -> None:
        """Set per-lane throttle budgets from the glock state. Runs only
        inside the gang-change hook (under ``glock.g.lock``), so budget
        writes are serialized with lock-ownership transitions: the
        enforced regime always belongs to the *current* leader, never to
        a stale lane that lost the pick ordering. Memoized on the
        (leader, live member thread uids) signature — consecutive hook
        events for a regime that did not move (e.g. the leave+join pair
        when a different same-prio task replaces a member on one lane:
        the leave already sees the successor installed) skip the lane
        rescan. The member uids must be part of the signature: that
        same replacement keeps leader and core mask identical while the
        budget floor moves with the member set.

        Drain-window ordering (ROADMAP item 1): a gang acquiring after
        a preemption applies its budgets while the outgoing gang's last
        quanta still drain (no mid-quantum preemption — the preemptor
        waits at the gang-isolation barrier). Best-effort work admitted
        under the incoming regime alone would pierce the *outgoing*
        gang's isolation, so while foreign in-flight quanta remain, the
        enforced regime is the element-wise min over (budgets before
        the change, incoming budgets); ``_end_drain`` re-derives the
        pure incoming regime when the last foreign quantum retires."""
        g = self.sched.g
        sig = (g.held_flag,
               None if g.leader is None else g.leader.uid,
               tuple(None if th is None else th.task.uid
                     for th in g.gthreads))
        if sig == self._budget_sig:
            return
        self._budget_sig = sig

        def derive(reg):
            if self.budget_policy is not None:
                self.budget_policy.apply(g, reg)
            elif g.held_flag and g.leader is not None:
                occupied = {th.core for th in g.gthreads
                            if th is not None}
                reg.set_core_budgets({c: None for c in occupied},
                                     default=g.leader.mem_budget)

        # the foreign-in-flight snapshot and the drain publication must
        # be one atomic step against _quantum_retired (a quantum
        # retiring in between would miss the _draining flag and never
        # run _end_drain, pinning the min regime forever), and the min
        # regime must reach the live regulator in a *single* write:
        # deriving the incoming regime in place first would expose its
        # looser budgets to concurrent lock-free BE charges while the
        # outgoing gang still drains — so it is derived on a shadow
        # bank and only min(outgoing, incoming) is ever published.
        with self._lock:
            draining = frozenset(
                p for ln, p in self._inflight.items()
                if g.leader is not None and p != g.leader.prio)
            if draining:
                shadow = BandwidthRegulator(
                    self.n_lanes, interval=self.reg.interval,
                    mode=self.reg.mode)
                derive(shadow)
                self.reg.set_core_budgets(
                    {c: min(st.budget, shadow.cores[c].budget)
                     for c, st in self.reg.cores.items()})
                self._draining = draining
                # force a clean re-derivation once the drain completes
                self._budget_sig = None
        if not draining:
            derive(self.reg)

    def _end_drain(self) -> None:
        """The outgoing gang's last foreign in-flight quantum retired:
        drop the element-wise min regime and re-derive budgets from the
        live glock state alone."""
        g = self.sched.g
        with g.lock:
            self._budget_sig = None
            self._apply_budgets()
        with self._wake:
            self._wake.notify_all()

    def _quantum_retired(self, lane: int) -> bool:
        """Remove ``lane`` from the in-flight set (caller does NOT hold
        the lock); returns True when this retirement completed a
        drain — the caller must then run ``_end_drain``."""
        with self._wake:
            self._inflight.pop(lane, None)
            self._inflight_info.pop(lane, None)
            drain_done = bool(self._draining) and not any(
                p in self._draining for p in self._inflight.values())
            if drain_done:
                self._draining = frozenset()
            self._wake.notify_all()
        return drain_done

    def _on_release(self) -> None:
        """Full release: extend the departed gang's *tightest* enforced
        budget to every lane — its own former lanes included, which were
        exempt while occupied. Best-effort work on any lane thus stays
        behind the last declared lid (the paper's §IV-F rule) until the
        next gang's acquire overwrites it; nothing between two gangs is
        ever admitted more than the most conservative recent regime."""
        self._budget_sig = None
        floor = min(st.budget for st in self.reg.cores.values())
        if floor != float("inf"):
            self.reg.set_core_budgets({}, default=floor)

    def _on_gang_change(self, event: str, leader) -> None:
        # acquire/join/leave move the live-member set -> re-derive
        # budgets while still under g.lock; release floors every lane at
        # the departing gang's regime (conservative hand-off).
        if event in ("acquire", "join", "leave"):
            if event == "acquire" and self.reg.reclaim:
                # grants issued under the departing regime must not
                # leak into the acquiring gang's windows — even when
                # the budget values happen to coincide
                self.reg.reset_reclaim()
            self._apply_budgets()
            if event == "leave":
                # a leave only raises budgets (min over fewer members) —
                # wake admission-stalled and idle lanes so a lifted
                # stall is observed now, not at the next poll timeout
                with self._wake:
                    self._wake.notify_all()
        elif event == "release":
            self._on_release()
            with self._wake:
                self._wake.notify_all()
        elif event == "preempt":
            with self._wake:
                self._wake.notify_all()

    def _next_release_in(self, now: float) -> Optional[float]:
        """Seconds until the earliest future RT release (None = no more)."""
        best: Optional[float] = None
        for job in self.rt_jobs:
            insts = self._instances[job.uid]
            n = len(insts)
            if job.n_jobs is not None and n >= job.n_jobs:
                continue
            if n == 0:
                return 0.0
            if job.period_s is None:
                continue
            delta = insts[-1].release + job.period_s - now
            if best is None or delta < best:
                best = delta
        return best

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _release_jobs(self):
        now = self._now()
        for job in self.rt_jobs:
            insts = self._instances[job.uid]
            n = len(insts)
            if job.n_jobs is not None and n >= job.n_jobs:
                continue
            period = job.period_s
            next_rel = 0.0 if n == 0 else (insts[-1].release + (period or 0))
            if period is None and n > 0:
                continue
            if now + 1e-9 >= next_rel:
                insts.append(_JobInstance(
                    job=job, index=n, release=next_rel,
                    remaining_lanes=set(job.lanes)))
                seq = next(self._ready_seq)
                for lane in job.lanes:
                    heapq.heappush(self._ready[lane],
                                   (-job.prio, seq, job.uid, n))

    def _ready_thread(self, lane: int) -> Optional[Thread]:
        """Highest-priority released job with work left on this lane —
        lazy max-heap peek (same-priority ties go to the earlier
        release). Callers hold self._lock."""
        h = self._ready[lane]
        while h:
            _, _, uid, idx = h[0]
            inst = self._instances[uid][idx]
            if lane not in inst.remaining_lanes:
                heapq.heappop(h)         # quantum retired: stale entry
                continue
            return self._threads[(uid, lane)]
        return None

    def _active_instance(self, job: RTJob, lane: int) -> Optional[_JobInstance]:
        return next((i for i in self._instances[job.uid]
                     if lane in i.remaining_lanes), None)

    def _admit_rt_quantum(self, lane: int,
                          job: RTJob) -> Tuple[str, bool]:
        """Admission-charge one RT quantum against the lane's enforced
        budget (RTG-throttle: sibling lanes carry a finite cap while the
        critical member's lanes are uncapped — vgang/sched.py). On
        denial the lane stalls to the next regulation window, exactly
        the engines' RT-stall semantics at quantum granularity. The
        caller must declare budgets that admit at least one quantum per
        window (``bytes_per_quantum <= cap``), the same no-starvation
        condition rta.rtg_throttle_wcet prices as an infinite bound.

        Returns ``(verdict, stalled)``: verdict ``"run"`` when admitted,
        ``"stop"`` when the executor shut down mid-stall, ``"requeue"``
        when the gang lost the lock while waiting — a preemptor's budget
        regime may never admit this quantum (its floor can sit below our
        bytes), and starting it under a foreign regime would also be
        wrong, so the worker must re-enter the scheduler instead of
        spinning on denials while the preemptor waits at the
        gang-isolation barrier. ``stalled`` reports whether a denial
        actually delayed the quantum (so the caller traces a throttled
        span only for real stalls, not admission overhead). Gating needs
        a gang regime: with the scheduler disabled (passthrough mode,
        held_flag never set) quanta run ungated."""
        if job.bytes_per_quantum <= 0.0 or not self.sched.enabled:
            return "run", False
        g = self.sched.g
        stalled = False
        while True:
            # ownership check and charge are one atomic step under
            # g.lock (budget writes happen under it, in the gang-change
            # hook): a preemptor's acquire may have raised this lane's
            # budget — lifting our stall — and a charge made after
            # losing the lock would admit our quantum against the
            # *foreign* regime instead of requeueing
            with g.lock:
                if not (g.held_flag and g.leader is not None
                        and g.leader.prio == job.prio):
                    return "requeue", stalled
                now = self._now()
                st = self.reg.cores[lane]
                stalled_now = self.reg.is_stalled(lane, now)
                short = st.used + job.bytes_per_quantum - st.limit
                if self.reg.reclaim and short > 0.0 and \
                        st.budget != float("inf") and \
                        self._reclaim_rt_draw(lane, job, short,
                                              now) >= short:
                    # mid-window donation (DESIGN.md §7.5): the window
                    # was topped up from retired member lanes — this
                    # also lifts an existing stall (the executor
                    # analogue of the engines' claim_lift: a donor that
                    # retired after our trip rescues the quantum)
                    if stalled_now:
                        self.reg.unstall(lane)
                    admitted = self.reg.charge(
                        lane, job.bytes_per_quantum, now)
                elif stalled_now:
                    # existing stall (ours or a BE quantum's trip) and
                    # no covering donation: don't re-charge (each
                    # denied retry would inflate total_denied by a
                    # spurious-wakeup-dependent factor), wait it out
                    admitted = False
                else:
                    admitted = self.reg.charge(
                        lane, job.bytes_per_quantum, now)
            if admitted:
                return "run", stalled
            if not stalled:
                # first delay for this quantum: count it once, whether
                # the window was tripped by our own charge or was
                # already spent (e.g. by a best-effort filler)
                with self._lock:
                    self._counter_for(self._stall_c, "executor.rt_stalls",
                                      job.name).value += 1
            stalled = True
            wait = self.reg.next_release(lane, now) - now
            with self._wake:
                if self._stop:
                    return "stop", stalled
                self._wake.wait(timeout=min(max(wait, 0.0002), 0.05))

    def _reclaim_rt_draw(self, lane: int, job: RTJob, need: float,
                         now: float) -> float:
        """Admission-mode reclaiming (DESIGN.md §2.4/§7.5): draw
        ``need`` bytes of unspent window quota — all or nothing — from
        lanes of the running gang's *retired* members: members with no
        pending work this release and nothing in flight, whose
        interference dominates the drawing member's for every other
        member. This is the quota-for-quota half of the engines'
        exchange gate; the continuous-time offset cap has no admission
        analogue (the admission-mode analysis prices whole windows, not
        offsets — the executor bound's extra window slop absorbs the
        difference, DESIGN.md §2.4). Caller holds ``g.lock``; needs a
        ``budget_policy`` exposing ``interference``."""
        pol = self.budget_policy
        intf = getattr(pol, "interference", None)
        g = self.sched.g
        if intf is None or not g.held_flag or g.leader is None:
            return 0.0
        members = [j for j in self.rt_jobs if j.prio == g.leader.prio]
        names = [j.name for j in members]
        donors = []
        with self._lock:
            for m in members:
                if m.uid == job.uid or not m.lanes:
                    continue
                if any(ln in self._inflight for ln in m.lanes):
                    continue
                if any(self._active_instance(m, ln) is not None
                       for ln in m.lanes):
                    continue            # still has pending work
                if all(intf(v, job.name) <= intf(v, m.name) + 1e-12
                       for v in names if v not in (job.name, m.name)):
                    donors.extend(m.lanes)
        if not donors:
            return 0.0
        return self.reg.draw_from(lane, sorted(donors), need, now,
                                  require_full=True)

    # ------------------------------------------------------------------
    # watchdog (DESIGN.md §11.4)

    def _watchdog_deadline(self, job: RTJob) -> Optional[float]:
        """Wall-clock in-flight deadline for one quantum of ``job``."""
        if job.watchdog_s is not None:
            return job.watchdog_s
        if self.watchdog_factor is not None and job.wcet_s is not None:
            return self.watchdog_factor * job.wcet_s
        return self.watchdog_s

    def _watchdog_armed(self) -> bool:
        return self.watchdog_s is not None or any(
            self._watchdog_deadline(j) is not None for j in self.rt_jobs)

    def _watchdog_monitor(self, tick: float):
        while True:
            with self._wake:
                if self._stop:
                    return
                now = self._now()
                victims = [(ln, info[0], info[1])
                           for ln, info in self._inflight_info.items()
                           if info[3] is not None and now - info[2] > info[3]]
            for ln, uid, idx in victims:
                self._watchdog_abort(ln, uid, idx)
            time.sleep(tick)

    def _watchdog_abort(self, lane: int, uid: int, idx: int) -> bool:
        """Abort the gang release whose quantum is hung on ``lane``:
        mark the instance aborted (siblings' pending entries go stale
        and their in-flight returns are discarded), release every lane
        the gang still holds through ``pick_next_task_rt`` — i.e.
        through the glock state machine, so ``try_glock_release`` fires
        the gang-change hook and budget floors / wakeups happen in the
        normal hook order (glock.py "watchdog ordering") — then retire
        the hung lane from the gang-isolation barrier. Lock order:
        instance state under self._lock first, then g.lock via the pick
        (never nested the other way)."""
        with self._wake:
            info = self._inflight_info.get(lane)
            if info is None or info[0] != uid or info[1] != idx:
                return False         # retired between scan and abort
        job = self._jobs[uid]
        with self._lock:
            inst = self._instances[uid][idx]
            # a second hung lane of an already-aborted gang still needs
            # retiring from the barrier below; only the marking and the
            # glock release are once-per-instance
            first = not inst.aborted and inst.finish is None
            if first:
                inst.aborted = True
                inst.remaining_lanes.clear()
                self.watchdog_aborts.append(
                    (job.name, lane, idx, self._now()))
                self._counter_for(self._abort_c, "executor.aborted",
                                  job.name).value += 1
        if first:
            g = self.sched.g
            for ln in job.lanes:
                th = self._threads.get((uid, ln))
                if th is not None and g.gthreads[ln] is th:
                    self.sched.pick_next_task_rt(ln, th, None)
        if self._quantum_retired(lane):
            self._end_drain()
        return first

    # ------------------------------------------------------------------
    def _worker(self, lane: int):
        prev: Optional[Thread] = None
        while True:
            with self._lock:
                if self._stop:
                    return
                self._release_jobs()
                nxt = self._ready_thread(lane)
            picked = self.sched.pick_next_task_rt(lane, prev, nxt)
            prev = None
            if picked is not None:
                job = self._jobs[picked.task.uid]
                # NOTE: no budget write here. Budgets are applied from
                # the gang-change hook under g.lock (_apply_budgets); a
                # pre-barrier write from this thread could land *after*
                # another gang preempted us and clobber the running
                # gang's regime (the stale-lane race pinned by
                # tests/test_executor_vgang.py).
                inst = None
                with self._lock:
                    inst = self._active_instance(job, lane)
                if inst is None:
                    prev = picked
                    continue
                # gang-isolation barrier: wait out other gangs' in-flight
                # quanta. Condition-variable wakeups (notified when any
                # quantum retires and on gang hand-offs) replace the old
                # sleep-poll so idle lanes don't burn CPU while they wait.
                with self._wake:
                    while True:
                        if self._stop:
                            return
                        others = [p for ln, p in self._inflight.items()
                                  if ln != lane and p != job.prio]
                        if not others:
                            self._inflight[lane] = job.prio
                            self._inflight_info[lane] = (
                                job.uid, inst.index, self._now(),
                                self._watchdog_deadline(job))
                            break
                        self._wake.wait(timeout=0.05)
                t0 = self._now()
                if inst.start is None:
                    inst.start = t0
                requeue = False
                stalled = False
                try:
                    verdict, stalled = self._admit_rt_quantum(lane, job)
                    if verdict == "stop":
                        return               # stopped while stalled
                    if verdict == "requeue":
                        requeue = True       # preempted while stalled
                    else:
                        t_run = self._now()
                        job.fn(lane, inst.index)
                finally:
                    if self._quantum_retired(lane):
                        self._end_drain()
                if requeue:
                    # the quantum never started: leave the instance
                    # pending and re-enter the scheduler (the preempting
                    # gang proceeds; we block at Algorithm 1 line 18-19)
                    prev = picked
                    continue
                t1 = self._now()
                dur = t1 - t_run
                key = job.name
                with self._lock:
                    if inst.aborted:
                        # the watchdog killed this gang release while we
                        # ran: the late return is discarded — no sample,
                        # no EMA poisoning, no finish
                        self.trace.record(lane, f"aborted:{key}",
                                          t0 * 1e3, t1 * 1e3)
                        prev = picked
                        continue
                    if stalled:              # admission stall (§2.4)
                        self.trace.record(lane, f"throttled:{key}",
                                          t0 * 1e3, t_run * 1e3)
                    self.trace.record(lane, key, t_run * 1e3, t1 * 1e3)
                    ema = self._ema.get(key)
                    if ema is not None and \
                            dur > self.straggler_factor * ema:
                        self.stragglers.append((key, lane, dur))
                    self._ema[key] = dur if ema is None else \
                        0.9 * ema + 0.1 * dur
                    inst.remaining_lanes.discard(lane)
                    if not inst.remaining_lanes and inst.finish is None:
                        inst.finish = t1
                        self.response_times[job.name].append(
                            inst.finish - inst.release)
                prev = picked
                continue

            # best-effort filling under admission throttling
            ran_be = False
            for be in self.be_jobs:
                if lane not in be.lanes:
                    continue
                now = self._now()
                if self.reg.charge(lane, be.bytes_per_quantum, now):
                    t0 = self._now()
                    be.fn(lane)
                    t1 = self._now()
                    with self._lock:
                        self.trace.record(lane, be.name,
                                          t0 * 1e3, t1 * 1e3)
                        self._be_q[be.name].value += 1
                    ran_be = True
                    break
            if not ran_be:
                # idle lane: sleep on the condition variable until the next
                # RT release is due, a quantum retires, or a gang hand-off
                # frees work — not a fixed-period poll.
                with self._wake:
                    if self._stop:
                        return
                    delta = self._next_release_in(self._now())
                    timeout = 0.05 if delta is None else \
                        min(max(delta, 0.0002), 0.05)
                    self._wake.wait(timeout=timeout)

    # ------------------------------------------------------------------
    def run(self, duration_s: float):
        self._t0 = time.monotonic()
        workers = [threading.Thread(target=self._worker, args=(lane,),
                                    daemon=True)
                   for lane in range(self.n_lanes)]
        for w in workers:
            w.start()
        if self._watchdog_armed():
            deadlines = [d for d in (self._watchdog_deadline(j)
                                     for j in self.rt_jobs)
                         if d is not None]
            tick = min(deadlines) / 4 if deadlines else 0.01
            threading.Thread(target=self._watchdog_monitor,
                             args=(min(max(tick, 0.001), 0.05),),
                             daemon=True).start()
        time.sleep(duration_s)
        with self._wake:
            self._stop = True
            self._wake.notify_all()
        for w in workers:
            w.join(timeout=5.0)
        self.trace.finish_view()
        return {
            "response_times": self.response_times,
            "be_quanta": dict(self.be_quanta),
            "stragglers": list(self.stragglers),
            "rt_stalls": dict(self.rt_stalls),
            "preemptions": self.sched.g.preemptions,
            "acquisitions": self.sched.g.acquisitions,
            "ipis": self.sched.g.ipis_sent,
            "reclaimed_bytes": self.reg.total_reclaimed,
            "watchdog_aborts": list(self.watchdog_aborts),
            "aborted": dict(self.aborted),
            "metrics": self.metrics.snapshot()
            if self.metrics is not None else None,
        }
