"""RT-Gang core: the paper's contribution.

* gang.py     — task model (RT gangs, virtual gangs, best-effort tasks)
* glock.py    — Algorithms 1-4 state machine (one-gang-at-a-time invariant)
* sim.py      — fixed-quantum scheduler simulator (reproduces Fig.4/5)
* events.py   — exact event-driven engine (Simulator dt=None; O(events))
* throttle.py — BWLOCK-adapted bandwidth regulation (reactive + admission)
* rta.py      — classical response-time analysis enabled by the transform
* executor.py — gang-scheduled executor for real JAX workloads (TPU lanes)
* tracing.py  — KernelShark-lite execution traces
"""
from repro.core.gang import BETask, RTTask, Thread, make_virtual_gang
from repro.core.glock import GangScheduler, GLock
from repro.core.sim import (Simulator, SimResult, matrix_interference,
                            no_interference)
from repro.core.events import EventEngine
from repro.core.throttle import BandwidthRegulator
from repro.core.rta import response_time, schedulable, total_utilization
from repro.core.executor import BEJob, GangExecutor, RTJob
from repro.core.faults import (BeOverrun, Enforcement, FaultPlan,
                               HungThread, LostWakeup, WcetOverrun)
from repro.core.tracing import Trace

__all__ = ["BETask", "RTTask", "Thread", "make_virtual_gang",
           "GangScheduler", "GLock", "Simulator", "SimResult", "EventEngine",
           "matrix_interference", "no_interference", "BandwidthRegulator",
           "response_time", "schedulable", "total_utilization",
           "BEJob", "GangExecutor", "RTJob", "Trace",
           "FaultPlan", "Enforcement", "WcetOverrun", "HungThread",
           "LostWakeup", "BeOverrun"]
