"""MemoryModel — the shared interference/bandwidth layer of both
simulator engines (DESIGN.md §10).

Both engines used to carry their own copy of the co-runner bookkeeping:
the quantum loop rebuilt a ``running_names`` map and took a max over
co-runners per core per step, and the event engine's ``recompute_rates``
rescanned every (core, other-core) pair per event — O(cores^2) at every
steady-state throttle event. This module replaces both with one
incrementally-maintained model:

* **Occupancy** — each core holds one occupant record: an RT thread, a
  fractional set of best-effort candidates, or nothing. Updates are
  diffed: an unchanged assignment is a no-op, a changed one adjusts the
  global occupant-name multiset only for that core (O(dirty) per event).
* **Interference** — the engines' slowdown rule is
  ``max(1, max_{name present, name != victim} interference(victim, name))``:
  same-named threads never interfere and a gang's own threads share one
  name, so the slowdown depends only on the victim's name and the *set*
  of distinct occupant names — not on which core anyone sits on. The
  model therefore versions the distinct-name set with an ``epoch``
  (bumped only on a 0<->1 presence transition) and memoizes slowdowns
  per victim name against it: a steady-state event where the name set
  is unchanged reuses every cached aggregate.
* **Bandwidth charging** — RT threads charge their ``traffic_rate``
  (RTTask.mem_rate, derived from mem_intensity) through the same
  ``BandwidthRegulator`` best-effort work uses, so RT threads can trip
  per-core budgets (RTG-throttle: sibling members of a virtual gang are
  regulated while the critical member runs unthrottled). A tripped RT
  thread *pauses mid-job* — the engines stop its progress and remove it
  from occupancy (a stalled thread generates no traffic and no
  interference) until the regulation window ends.
* **Dynamic reclaiming** (``BandwidthRegulator(reclaim=True)``,
  DESIGN.md §7.5) — an RT thread that exhausts its window quota *claims*
  the unspent quota of idle cores that previously hosted RT work (the
  regulator's pull-based donation pool) before tripping, and a stalled
  thread is re-tried when a donor appears. Each drawn unit is funded by
  a specific donor core under the *exchange gate* that keeps the static
  RTG-throttle RTA bound sound (vgang/rta.py, DESIGN.md §9.3.2): the
  funded extension must lie inside the donor occupant's own static
  unstalled window (offsets the static analysis already priced the
  donor as present at), and for every present-or-stalled RT victim the
  drawer's interference factor must not exceed the absent donor's —
  under the engines' max-of-pairwise slowdown rule the extension then
  never raises any victim's slowdown above what the static profile
  already assumed at those offsets.

Location-dependent interference: a pairwise model may declare
``distance_aware = True`` and accept ``(victim, aggressor, distance)``
(core index distance). The name-keyed slowdown memo is then invalid —
the same co-runner set at different cores gives different aggregates —
so the memo keys on ``(victim, core)`` and is versioned by a *location*
epoch that bumps on every occupancy change, not only on 0<->1 presence
transitions (ROADMAP: formation under per-core locality).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.core.gang import RTTask
from repro.core.throttle import BandwidthRegulator

# occupant kinds
IDLE, RT, BE = 0, 1, 2

_INF = float("inf")


def distance_interference(fn: Callable[[str, str, int], float]
                          ) -> Callable[[str, str, int], float]:
    """Mark ``fn(victim, aggressor, distance)`` as a location-dependent
    pairwise model (distance = |victim core - aggressor core|)."""
    fn.distance_aware = True       # type: ignore[attr-defined]
    return fn


class MemoryModel:
    """Incremental co-runner sets + slowdown aggregates + traffic
    charging, driven by both engines (core/sim.py and core/events.py).

    ``kind``/``names``/``rates`` are per-core views the engines read in
    their hot loops; mutate occupancy only through ``set_rt``/``set_be``
    /``clear`` so the name multiset and epoch stay consistent.
    """

    def __init__(self, n_cores: int,
                 interference: Callable[[str, str], float],
                 regulator: BandwidthRegulator):
        self.n_cores = n_cores
        self.interference = interference
        self.distance_aware = bool(getattr(interference, "distance_aware",
                                           False))
        self.reg = regulator
        self.kind: List[int] = [IDLE] * n_cores
        self.names: List[Tuple[str, ...]] = [()] * n_cores
        self.rates: List[float] = [0.0] * n_cores
        self.epoch = 0                       # distinct-name-set version
        self.loc_epoch = 0                   # any-occupancy-change version
        self._count: Dict[str, int] = {}     # occupant-name multiset
        # location-free: victim -> (epoch, s); distance-aware:
        # (victim, core) -> (loc_epoch, s)
        self._slow: Dict = {}
        # reclaiming: the last RT task a core hosted — kept across
        # clear(), a now-idle core donates on its former occupant's
        # behalf, but only to drawers of the *same gang* (equal RT
        # priority): leftover grants of a previously scheduled gang
        # must never fund another gang's extension, whose static bound
        # never priced those members as co-runners. Plus the stalled
        # threads' names (a stalled thread is cleared from occupancy
        # but is still a victim the exchange gate must protect).
        self._last_rt: List[Optional[RTTask]] = [None] * n_cores
        self.stalled_victims: Dict[int, str] = {}

    @property
    def agg_epoch(self) -> int:
        """The version the slowdown memo is valid against — the distinct-
        name-set epoch for location-free interference, the location epoch
        (every occupancy change) for distance-aware models. Engines that
        cache aggregates must key on this, not on ``epoch``."""
        return self.loc_epoch if self.distance_aware else self.epoch

    # ---- occupancy (incremental) ------------------------------------
    def _assign(self, core: int, kind: int, names: Tuple[str, ...],
                rate: float) -> None:
        if self.kind[core] == kind and self.names[core] == names:
            self.rates[core] = rate
            return
        self.loc_epoch += 1
        cnt = self._count
        for nm in self.names[core]:
            left = cnt[nm] - 1
            if left:
                cnt[nm] = left
            else:
                del cnt[nm]
                self.epoch += 1
        for nm in names:
            had = cnt.get(nm, 0)
            cnt[nm] = had + 1
            if not had:
                self.epoch += 1
        self.kind[core] = kind
        self.names[core] = names
        self.rates[core] = rate

    def set_rt(self, core: int, task: RTTask) -> None:
        """An RT thread of ``task`` occupies ``core`` (running, i.e. not
        throttle-stalled — stalled threads are ``clear``-ed)."""
        self._last_rt[core] = task
        self._assign(core, RT, (task.name,), task.traffic_rate)

    def set_be(self, core: int, names: Tuple[str, ...],
               rate: float) -> None:
        """Fractional best-effort co-runners occupy ``core``: every
        candidate is present for interference purposes and the core
        charges their aggregate ``rate`` (sum of mem_rate / n)."""
        self._assign(core, BE, names, rate)

    def clear(self, core: int) -> None:
        """Core idle (or its occupant is throttle-stalled: a stalled
        thread generates no traffic and no interference)."""
        self._assign(core, IDLE, (), 0.0)

    def refresh_core(self, core: int, thread, be_names: Tuple[str, ...],
                     be_rate: float, now: float) -> bool:
        """Re-derive ``core``'s occupancy from the engine's scheduling
        state — the one shared stall policy both engines apply: an RT
        occupant with traffic whose budget is tripped pauses (cleared:
        no traffic, no interference) and True is returned; otherwise
        the RT thread occupies the core. A free core hosts its
        best-effort candidates fractionally unless stalled."""
        if thread is not None:
            if thread.task.traffic_rate > 0.0 and \
                    self.reg.is_stalled(core, now):
                self.clear(core)
                self.stalled_victims[core] = thread.task.name
                return True
            self.stalled_victims.pop(core, None)
            self.set_rt(core, thread.task)
            return False
        self.stalled_victims.pop(core, None)
        if be_names and not self.reg.is_stalled(core, now):
            self.set_be(core, be_names, be_rate)
        else:
            self.clear(core)
        return False

    # ---- interference aggregate (epoch-memoized) --------------------
    def slowdown(self, victim: str, core: Optional[int] = None) -> float:
        """max(1, max over present occupant names != victim) — cached
        against the distinct-name-set epoch, so steady-state events
        reuse every aggregate and a name-set change costs one
        O(#distinct names) rebuild per victim, not O(cores^2).

        Distance-aware interference (``distance_interference``): the
        aggregate depends on *where* the victim and its co-runners sit,
        so the memo keys on ``(victim, core)`` and validates against the
        location epoch — a co-runner moving cores without any 0<->1 name
        transition must invalidate it (the name-keyed memo would return
        the stale aggregate)."""
        if self.distance_aware:
            if core is None:
                raise ValueError("distance-aware interference needs the "
                                 "victim's core for slowdown()")
            key = (victim, core)
            hit = self._slow.get(key)
            if hit is not None and hit[0] == self.loc_epoch:
                return hit[1]
            s = 1.0
            intf = self.interference
            for oc in range(self.n_cores):
                if oc == core:
                    continue
                dist = abs(oc - core)
                for nm in self.names[oc]:
                    if nm != victim:
                        f = intf(victim, nm, dist)
                        if f > s:
                            s = f
            self._slow[key] = (self.loc_epoch, s)
            return s
        hit = self._slow.get(victim)
        if hit is not None and hit[0] == self.epoch:
            return hit[1]
        s = 1.0
        intf = self.interference
        for nm in self._count:
            if nm != victim:
                f = intf(victim, nm)
                if f > s:
                    s = f
        self._slow[victim] = (self.epoch, s)
        return s

    # ---- bandwidth charging -----------------------------------------
    # Thin seams over the regulator so both engines charge RT and BE
    # occupants identically: the dt-stepped loop uses charge_quantum;
    # the closed-form engine predicts trips via next_trip_time/trip and
    # span-charges reg.charge_span(core, rates[core], ...) directly in
    # its materialization hot path.

    def charge_quantum(self, core: int, dt: float, now: float) -> float:
        """Charge one quantum of the core's occupant traffic; returns
        the fraction of the quantum that executed (reactive: the
        traffic is fully accounted, the occupant runs until the exact
        trip point within the quantum and then stalls until the window
        ends — the same progress the closed-form engine realizes).

        Reclaiming: when the quantum would exhaust an RT occupant's
        window limit, the claim happens first, at the *exact* sub-
        quantum exhaustion instant — the same instant the closed-form
        engine's trip event fires — so both engines draw identical
        amounts in identical order."""
        r = self.rates[core]
        if r <= 0.0:
            return 1.0
        amount = r * dt
        reg = self.reg
        if reg.reclaim and self.kind[core] == RT:
            st = reg.cores[core]
            reg._roll_window(st, now)
            if now >= st.stalled_until:
                # claim as soon as the quantum *reaches* the limit (the
                # event engine's exhaustion event fires the moment
                # used == limit, before any overshoot) — but only when
                # the exhaustion instant lies strictly inside the
                # current window: a future-dated t_x at/past the
                # boundary would roll the drawer's window early, erase
                # its usage, and let the straddling quantum's traffic
                # slip past the trip (next window's charges claim on
                # their own, with usage freshly rolled)
                head = st.limit - st.used
                t_x = now + max(0.0, head) / r
                if amount >= head - 1e-12 and \
                        t_x < st.window_start + st.interval - 1e-12:
                    self.claim(core, self.names[core][0], r, t_x)
        return reg.charge_partial(core, amount, now)

    def next_trip_time(self, core: int, now: float) -> float:
        r = self.rates[core]
        if r <= 0.0 or self.reg.cores[core].budget == _INF:
            return _INF
        return self.reg.next_trip_time(core, r, now)

    def trip(self, core: int, now: float) -> None:
        self.reg.trip(core, now)

    # ---- dynamic reclaiming (DESIGN.md §7.5) ------------------------
    # Eligibility policy on top of the regulator's pull accounting.
    # Donors are idle cores that previously hosted RT work; each drawn
    # unit is funded by a specific donor under the *exchange gate* that
    # keeps the static RTG-throttle bound sound (DESIGN.md §9.3.2):
    #
    #  * offset cap — the funded extension lies inside the donor
    #    occupant's static unstalled window [0, budget/rate_donor): the
    #    static analysis already priced the donor present at exactly
    #    those offsets, and the donor is provably absent now (idle);
    #  * factor dominance — for every present-or-stalled RT victim the
    #    drawer's pairwise factor is <= the donor's, so under the
    #    max-of-pairwise slowdown rule the substitution never raises any
    #    victim's slowdown above the static profile.
    #
    # Both engines call these at the same instants (the exact trip
    # point / the stall-retry when occupancy changes), scanning donors
    # in core order, so the accounting is byte-identical across engines.

    def _dominated(self, victim: str, victim_core: int, drawer: str,
                   drawer_core: int, donor: str, donor_core: int) -> bool:
        intf = self.interference
        if self.distance_aware:
            f_o = intf(victim, drawer, abs(victim_core - drawer_core))
            f_d = intf(victim, donor, abs(victim_core - donor_core))
        else:
            f_o = intf(victim, drawer)
            f_d = intf(victim, donor)
        return f_o <= f_d + 1e-12

    def _donor_covers(self, drawer: str, drawer_core: int, donor: str,
                      donor_core: int) -> bool:
        """Factor dominance over every victim: RT occupants plus
        stalled threads (cleared from occupancy, but they may resume
        mid-window through their own draw and must stay protected)."""
        for mc in range(self.n_cores):
            if mc == drawer_core:
                continue
            if self.kind[mc] == RT:
                victim = self.names[mc][0]
            else:
                victim = self.stalled_victims.get(mc)
            if victim is None or victim in (drawer, donor):
                continue
            if not self._dominated(victim, mc, drawer, drawer_core,
                                   donor, donor_core):
                return False
        return True

    def claim(self, core: int, drawer: str, rate: float,
              t_x: float) -> float:
        """At the exhaustion instant ``t_x`` of ``core``'s RT occupant
        ``drawer``, claim donated quota to keep charging at ``rate``
        past its own window limit — donor by donor, in core order,
        each funding only the contiguous extension sub-span inside its
        own static window (first-to-trip claims first; later trippers
        get what is left). Returns the drawn amount."""
        reg = self.reg
        if not reg.reclaim or rate <= 0.0:
            return 0.0
        st = reg.cores[core]
        reg._roll_window(st, t_x)
        interval = st.interval
        covered = t_x - st.window_start      # extension starts here
        if covered >= interval - 1e-15:
            return 0.0
        drawer_task = self._last_rt[core]
        if drawer_task is None:
            return 0.0
        got = 0.0
        for d in range(self.n_cores):
            if d == core or self.kind[d] != IDLE:
                continue
            last = self._last_rt[d]
            # same-gang scope: only a co-member's grant (equal RT
            # priority = gang identity) may fund this drawer
            if last is None or last.prio != drawer_task.prio:
                continue
            donor, donor_rate = last.name, last.traffic_rate
            dst = reg.cores[d]
            reg._roll_window(dst, t_x)
            if dst.budget == _INF:
                continue
            # the donor occupant's static unstalled window offset
            q_d = interval if donor_rate <= 0.0 \
                else min(interval, dst.budget / donor_rate)
            if q_d <= covered + 1e-15:
                continue
            if not self._donor_covers(drawer, core, donor, d):
                continue
            # accounting routed through the regulator's one transfer
            # primitive (engines are single-threaded; the executor path
            # goes through draw_from, which locks)
            take = reg._transfer(d, core, rate * (q_d - covered), t_x)
            if take <= 0.0:
                continue
            got += take
            covered += take / rate
            if covered >= interval - 1e-15:
                break
        return got

    def claim_lift(self, core: int, task: RTTask, now: float) -> bool:
        """Retry a throttle-stalled RT thread against the donation pool
        (a donor appeared after the trip): draw what the rest of the
        window needs; any positive grant lifts the stall. Engines call
        this for stalled cores — in core order — whenever occupancy
        changes while reclaiming is on."""
        reg = self.reg
        r = task.traffic_rate
        if not reg.reclaim or r <= 0.0:
            return False
        if not reg.is_stalled(core, now):
            return False
        if self.claim(core, task.name, r, now) <= 0.0:
            return False
        st = reg.cores[core]
        if st.used >= st.limit - 1e-12:
            # the grant does not even cover the trip overshoot (the
            # quantum engine's counter runs ahead of the exact trip
            # point by up to one quantum): lifting now would just
            # re-trip on the next consultation and double-count the
            # stall — stay stalled until the window ends
            return False
        reg.unstall(core)
        return True
