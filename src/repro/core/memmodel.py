"""MemoryModel — the shared interference/bandwidth layer of both
simulator engines (DESIGN.md §10).

Both engines used to carry their own copy of the co-runner bookkeeping:
the quantum loop rebuilt a ``running_names`` map and took a max over
co-runners per core per step, and the event engine's ``recompute_rates``
rescanned every (core, other-core) pair per event — O(cores^2) at every
steady-state throttle event. This module replaces both with one
incrementally-maintained model:

* **Occupancy** — each core holds one occupant record: an RT thread, a
  fractional set of best-effort candidates, or nothing. Updates are
  diffed: an unchanged assignment is a no-op, a changed one adjusts the
  global occupant-name multiset only for that core (O(dirty) per event).
* **Interference** — the engines' slowdown rule is
  ``max(1, max_{name present, name != victim} interference(victim, name))``:
  same-named threads never interfere and a gang's own threads share one
  name, so the slowdown depends only on the victim's name and the *set*
  of distinct occupant names — not on which core anyone sits on. The
  model therefore versions the distinct-name set with an ``epoch``
  (bumped only on a 0<->1 presence transition) and memoizes slowdowns
  per victim name against it: a steady-state event where the name set
  is unchanged reuses every cached aggregate.
* **Bandwidth charging** — RT threads charge their ``traffic_rate``
  (RTTask.mem_rate, derived from mem_intensity) through the same
  ``BandwidthRegulator`` best-effort work uses, so RT threads can trip
  per-core budgets (RTG-throttle: sibling members of a virtual gang are
  regulated while the critical member runs unthrottled). A tripped RT
  thread *pauses mid-job* — the engines stop its progress and remove it
  from occupancy (a stalled thread generates no traffic and no
  interference) until the regulation window ends.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.core.gang import RTTask
from repro.core.throttle import BandwidthRegulator

# occupant kinds
IDLE, RT, BE = 0, 1, 2

_INF = float("inf")


class MemoryModel:
    """Incremental co-runner sets + slowdown aggregates + traffic
    charging, driven by both engines (core/sim.py and core/events.py).

    ``kind``/``names``/``rates`` are per-core views the engines read in
    their hot loops; mutate occupancy only through ``set_rt``/``set_be``
    /``clear`` so the name multiset and epoch stay consistent.
    """

    def __init__(self, n_cores: int,
                 interference: Callable[[str, str], float],
                 regulator: BandwidthRegulator):
        self.n_cores = n_cores
        self.interference = interference
        self.reg = regulator
        self.kind: List[int] = [IDLE] * n_cores
        self.names: List[Tuple[str, ...]] = [()] * n_cores
        self.rates: List[float] = [0.0] * n_cores
        self.epoch = 0                       # distinct-name-set version
        self._count: Dict[str, int] = {}     # occupant-name multiset
        self._slow: Dict[str, Tuple[int, float]] = {}   # victim -> (epoch, s)

    # ---- occupancy (incremental) ------------------------------------
    def _assign(self, core: int, kind: int, names: Tuple[str, ...],
                rate: float) -> None:
        if self.kind[core] == kind and self.names[core] == names:
            self.rates[core] = rate
            return
        cnt = self._count
        for nm in self.names[core]:
            left = cnt[nm] - 1
            if left:
                cnt[nm] = left
            else:
                del cnt[nm]
                self.epoch += 1
        for nm in names:
            had = cnt.get(nm, 0)
            cnt[nm] = had + 1
            if not had:
                self.epoch += 1
        self.kind[core] = kind
        self.names[core] = names
        self.rates[core] = rate

    def set_rt(self, core: int, task: RTTask) -> None:
        """An RT thread of ``task`` occupies ``core`` (running, i.e. not
        throttle-stalled — stalled threads are ``clear``-ed)."""
        self._assign(core, RT, (task.name,), task.traffic_rate)

    def set_be(self, core: int, names: Tuple[str, ...],
               rate: float) -> None:
        """Fractional best-effort co-runners occupy ``core``: every
        candidate is present for interference purposes and the core
        charges their aggregate ``rate`` (sum of mem_rate / n)."""
        self._assign(core, BE, names, rate)

    def clear(self, core: int) -> None:
        """Core idle (or its occupant is throttle-stalled: a stalled
        thread generates no traffic and no interference)."""
        self._assign(core, IDLE, (), 0.0)

    def refresh_core(self, core: int, thread, be_names: Tuple[str, ...],
                     be_rate: float, now: float) -> bool:
        """Re-derive ``core``'s occupancy from the engine's scheduling
        state — the one shared stall policy both engines apply: an RT
        occupant with traffic whose budget is tripped pauses (cleared:
        no traffic, no interference) and True is returned; otherwise
        the RT thread occupies the core. A free core hosts its
        best-effort candidates fractionally unless stalled."""
        if thread is not None:
            if thread.task.traffic_rate > 0.0 and \
                    self.reg.is_stalled(core, now):
                self.clear(core)
                return True
            self.set_rt(core, thread.task)
            return False
        if be_names and not self.reg.is_stalled(core, now):
            self.set_be(core, be_names, be_rate)
        else:
            self.clear(core)
        return False

    # ---- interference aggregate (epoch-memoized) --------------------
    def slowdown(self, victim: str) -> float:
        """max(1, max over present occupant names != victim) — cached
        against the distinct-name-set epoch, so steady-state events
        reuse every aggregate and a name-set change costs one
        O(#distinct names) rebuild per victim, not O(cores^2)."""
        hit = self._slow.get(victim)
        if hit is not None and hit[0] == self.epoch:
            return hit[1]
        s = 1.0
        intf = self.interference
        for nm in self._count:
            if nm != victim:
                f = intf(victim, nm)
                if f > s:
                    s = f
        self._slow[victim] = (self.epoch, s)
        return s

    # ---- bandwidth charging -----------------------------------------
    # Thin seams over the regulator so both engines charge RT and BE
    # occupants identically: the dt-stepped loop uses charge_quantum;
    # the closed-form engine predicts trips via next_trip_time/trip and
    # span-charges reg.charge_span(core, rates[core], ...) directly in
    # its materialization hot path.

    def charge_quantum(self, core: int, dt: float, now: float) -> float:
        """Charge one quantum of the core's occupant traffic; returns
        the fraction of the quantum that executed (reactive: the
        traffic is fully accounted, the occupant runs until the exact
        trip point within the quantum and then stalls until the window
        ends — the same progress the closed-form engine realizes)."""
        r = self.rates[core]
        if r <= 0.0:
            return 1.0
        return self.reg.charge_partial(core, r * dt, now)

    def next_trip_time(self, core: int, now: float) -> float:
        r = self.rates[core]
        if r <= 0.0 or self.reg.cores[core].budget == _INF:
            return _INF
        return self.reg.next_trip_time(core, r, now)

    def trip(self, core: int, now: float) -> None:
        self.reg.trip(core, now)
