"""Memory-bandwidth throttling of best-effort work (paper §III-D / §IV-F,
adapting BWLOCK [53]).

Paper mechanism: per-core perf counters count memory transactions per 1 ms
regulation interval; on budget overflow an interrupt stalls the core until
the next interval. The budget is the *currently running RT gang's* declared
tolerable traffic.

Two modes (DESIGN.md §7.3):

* ``reactive``  — paper-faithful: usage accumulates as best-effort work runs;
  the core is stalled the moment the budget is exceeded (overshoot of at most
  one accounting quantum, like one sampling period of the counter).
* ``admission`` — TPU-native: a quantum of work with statically-known bytes
  (from ``compiled.cost_analysis()``) is admitted only if it fits the
  remaining budget. No overshoot; suits hardware without mid-program
  preemption.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Set


@dataclasses.dataclass
class ThrottleState:
    budget: float                # allowed traffic per interval (bytes/units)
    interval: float = 1.0        # regulation interval (ms in the sim)
    used: float = 0.0
    window_start: float = 0.0
    stalled_until: float = 0.0
    # instrumentation
    throttle_events: int = 0
    total_used: float = 0.0
    total_denied: float = 0.0


class BandwidthRegulator:
    """Per-core regulator bank; budget is set by the running gang."""

    def __init__(self, n_cores: int, interval: float = 1.0,
                 mode: str = "reactive"):
        assert mode in ("reactive", "admission")
        self.mode = mode
        self.interval = interval
        self.cores: Dict[int, ThrottleState] = {
            c: ThrottleState(budget=float("inf"), interval=interval)
            for c in range(n_cores)}
        self._lock = threading.Lock()

    def set_gang_budget(self, budget: Optional[float]) -> Set[int]:
        """Called on gang-lock acquisition: the new gang's declared budget is
        enforced on every core that runs best-effort work (paper §IV-F).
        A budget increase (e.g. the throttling gang departed) lifts stalls
        from the previous regime; usage within the window is kept."""
        return self.set_core_budgets({}, default=budget)

    def set_core_budgets(self, budgets: Dict[int, Optional[float]],
                         default: Optional[float] = None) -> Set[int]:
        """Per-core budget assignment (virtual gangs: each member gang
        declares its own tolerable traffic, so the enforced budget can
        differ per core — see vgang/sched.py). Cores absent from
        ``budgets`` get ``default``. Same stall-lift rule as
        ``set_gang_budget``: a budget increase releases the stall.

        Returns the cores whose regime actually changed (budget moved or
        a stall was lifted) — the event engine folds exactly these into
        its dirty-core set instead of rescanning every core."""
        changed: Set[int] = set()
        with self._lock:
            for c, st in self.cores.items():
                raw = budgets.get(c, default)
                b = float("inf") if raw is None else float(raw)
                if b == st.budget:
                    continue
                if b > st.budget and st.stalled_until > 0.0:
                    st.stalled_until = 0.0
                st.budget = b
                changed.add(c)
        return changed

    def _roll_window(self, st: ThrottleState, now: float) -> None:
        delta = now - st.window_start
        if delta >= st.interval:
            # jump directly to the window containing ``now`` (O(1) even
            # after a long idle gap; every skipped window resets usage)
            st.window_start += int(delta / st.interval) * st.interval
            st.used = 0.0

    def charge(self, core: int, amount: float, now: float) -> bool:
        """Account ``amount`` of traffic at time ``now``.

        reactive: always charges; returns False (and stalls the core until
        the next interval) if the budget is now exceeded.
        admission: charges only if it fits; returns False if denied.

        All-or-nothing view of ``charge_partial``: a reactive trip always
        admits a fraction < 1 (the overflowing amount never fully fit)."""
        return self.charge_partial(core, amount, now) >= 1.0

    def charge_partial(self, core: int, amount: float, now: float) -> float:
        """Charge one quantum, admitting a *fraction* of it: the counter
        accounts the full amount (reactive hardware overshoots by less
        than one sampling quantum), the core stalls when the budget is
        exceeded, and the return value is the fraction of the quantum
        that executed before the trip. This keeps the dt-stepped
        engine's progress aligned with the closed-form engine, which
        runs work up to the exact exhaustion instant — without it, a
        lost tripping quantum per window can tip a completion past a
        whole stall period. Admission mode stays all-or-nothing."""
        st = self.cores[core]
        self._roll_window(st, now)
        if now < st.stalled_until:
            st.total_denied += amount
            return 0.0
        if self.mode == "admission":
            if st.used + amount > st.budget:
                st.throttle_events += 1
                st.total_denied += amount
                st.stalled_until = st.window_start + st.interval
                return 0.0
            st.used += amount
            st.total_used += amount
            return 1.0
        before = st.used
        st.used += amount
        st.total_used += amount
        if st.used > st.budget:
            st.throttle_events += 1
            st.stalled_until = st.window_start + st.interval
            if amount <= 0.0:
                return 0.0
            return max(0.0, min(1.0, (st.budget - before) / amount))
        return 1.0

    def is_stalled(self, core: int, now: float) -> bool:
        st = self.cores[core]
        self._roll_window(st, now)
        return now < st.stalled_until

    def next_release(self, core: int, now: float) -> float:
        st = self.cores[core]
        return max(st.stalled_until, now)

    # ---- continuous-time interface (event-driven engine) -----------------
    # The quantum simulator charges dt-sized packets through ``charge``;
    # the exact engine instead runs best-effort work over closed intervals
    # and needs (a) span accounting, (b) the closed-form time at which the
    # current budget trips, (c) an explicit trip. These are the dt -> 0
    # limit of the reactive mode (no one-quantum overshoot).

    def window_end(self, core: int, now: float) -> float:
        st = self.cores[core]
        self._roll_window(st, now)
        return st.window_start + st.interval

    def charge_span(self, core: int, rate: float, t0: float,
                    t1: float) -> None:
        """Account continuous traffic at ``rate`` units/ms over [t0, t1].
        Spans may cross regulation-window boundaries; usage carried into
        the window containing ``t1`` is exactly the traffic generated since
        that window opened."""
        st = self.cores[core]
        self._roll_window(st, t0)
        amount = rate * (t1 - t0)
        if t1 < st.window_start + st.interval:
            st.used += amount
        else:
            self._roll_window(st, t1)
            st.used = rate * (t1 - st.window_start)
        st.total_used += amount

    def next_trip_time(self, core: int, rate: float, now: float) -> float:
        """Absolute time at which continuous traffic at ``rate`` exceeds the
        budget, assuming the rate holds; inf if it never does. Exactly
        reaching the budget at a window boundary does not trip (usage never
        *exceeds* the budget)."""
        st = self.cores[core]
        self._roll_window(st, now)
        if st.budget == float("inf") or rate <= 0.0:
            return float("inf")
        we = st.window_start + st.interval
        t = now + max(0.0, st.budget - st.used) / rate
        if t < we - 1e-12:
            return t
        if st.budget / rate < st.interval - 1e-12:
            return we + st.budget / rate
        return float("inf")

    def trip(self, core: int, now: float) -> None:
        """Stall ``core`` until the end of the current regulation window
        (the budget was exhausted at ``now``)."""
        st = self.cores[core]
        self._roll_window(st, now)
        st.throttle_events += 1
        st.stalled_until = st.window_start + st.interval
