"""Memory-bandwidth throttling of best-effort work (paper §III-D / §IV-F,
adapting BWLOCK [53]).

Paper mechanism: per-core perf counters count memory transactions per 1 ms
regulation interval; on budget overflow an interrupt stalls the core until
the next interval. The budget is the *currently running RT gang's* declared
tolerable traffic.

Two modes (DESIGN.md §7.3):

* ``reactive``  — paper-faithful: usage accumulates as best-effort work runs;
  the core is stalled the moment the budget is exceeded (overshoot of at most
  one accounting quantum, like one sampling period of the counter).
* ``admission`` — TPU-native: a quantum of work with statically-known bytes
  (from ``compiled.cost_analysis()``) is admitted only if it fits the
  remaining budget. No overshoot; suits hardware without mid-program
  preemption.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional


@dataclasses.dataclass
class ThrottleState:
    budget: float                # allowed traffic per interval (bytes/units)
    interval: float = 1.0        # regulation interval (ms in the sim)
    used: float = 0.0
    window_start: float = 0.0
    stalled_until: float = 0.0
    # instrumentation
    throttle_events: int = 0
    total_used: float = 0.0
    total_denied: float = 0.0


class BandwidthRegulator:
    """Per-core regulator bank; budget is set by the running gang."""

    def __init__(self, n_cores: int, interval: float = 1.0,
                 mode: str = "reactive"):
        assert mode in ("reactive", "admission")
        self.mode = mode
        self.interval = interval
        self.cores: Dict[int, ThrottleState] = {
            c: ThrottleState(budget=float("inf"), interval=interval)
            for c in range(n_cores)}
        self._lock = threading.Lock()

    def set_gang_budget(self, budget: Optional[float]) -> None:
        """Called on gang-lock acquisition: the new gang's declared budget is
        enforced on every core that runs best-effort work (paper §IV-F).
        A budget increase (e.g. the throttling gang departed) lifts stalls
        from the previous regime; usage within the window is kept."""
        b = float("inf") if budget is None else float(budget)
        with self._lock:
            for st in self.cores.values():
                if b > st.budget:
                    st.stalled_until = 0.0
                st.budget = b

    def _roll_window(self, st: ThrottleState, now: float) -> None:
        while now >= st.window_start + st.interval:
            st.window_start += st.interval
            st.used = 0.0

    def charge(self, core: int, amount: float, now: float) -> bool:
        """Account ``amount`` of traffic at time ``now``.

        reactive: always charges; returns False (and stalls the core until
        the next interval) if the budget is now exceeded.
        admission: charges only if it fits; returns False if denied.
        """
        st = self.cores[core]
        self._roll_window(st, now)
        if now < st.stalled_until:
            st.total_denied += amount
            return False
        if self.mode == "admission":
            if st.used + amount > st.budget:
                st.throttle_events += 1
                st.total_denied += amount
                st.stalled_until = st.window_start + st.interval
                return False
            st.used += amount
            st.total_used += amount
            return True
        # reactive
        st.used += amount
        st.total_used += amount
        if st.used > st.budget:
            st.throttle_events += 1
            st.stalled_until = st.window_start + st.interval
            return False
        return True

    def is_stalled(self, core: int, now: float) -> bool:
        st = self.cores[core]
        self._roll_window(st, now)
        return now < st.stalled_until

    def next_release(self, core: int, now: float) -> float:
        st = self.cores[core]
        return max(st.stalled_until, now)
