"""Memory-bandwidth throttling of best-effort work (paper §III-D / §IV-F,
adapting BWLOCK [53]).

Paper mechanism: per-core perf counters count memory transactions per 1 ms
regulation interval; on budget overflow an interrupt stalls the core until
the next interval. The budget is the *currently running RT gang's* declared
tolerable traffic.

Two modes (DESIGN.md §7.3):

* ``reactive``  — paper-faithful: usage accumulates as best-effort work runs;
  the core is stalled the moment the budget is exceeded (overshoot of at most
  one accounting quantum, like one sampling period of the counter).
* ``admission`` — TPU-native: a quantum of work with statically-known bytes
  (from ``compiled.cost_analysis()``) is admitted only if it fits the
  remaining budget. No overshoot; suits hardware without mid-program
  preemption.

Dynamic reclaiming (``reclaim=True``, DESIGN.md §7.5, after the analysis
of arXiv:1809.05921): a core that sits idle inside a regulation window
leaves its unspent quota *donatable*, and a charging core that exhausts
its own quota may *draw* that quota instead of tripping. The pool is
pull-based — nothing is banked; ``donatable`` is computed on demand from
the donor's fresh window state, a draw marks the donor's ``donated``
counter (so quota is never handed out twice) and credits the drawer's
``drawn`` counter, and both reset at the window roll. The per-window
limit a core charges against is therefore

    limit = budget - donated + drawn

Eligibility (who may donate to whom) is policy, not accounting: the
MemoryModel restricts donors to idle cores and gates draws on an
interference-dominance rule (memmodel.py); the executor restricts
donors to lanes with no pending RT work. A budget *decrease* revokes
the core's unspent reclaimed grant (``drawn`` cleared) and — fixing the
mid-window lowering bug — stalls the core immediately when its usage
already exceeds the new limit, instead of letting it overrun until the
next window roll.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.obs.metrics import Counter, Gauge, MetricsRegistry

_INF = float("inf")


@dataclasses.dataclass
class ThrottleState:
    budget: float                # allowed traffic per interval (bytes/units)
    interval: float = 1.0        # regulation interval (ms in the sim)
    core: int = -1               # which core this state regulates
    used: float = 0.0
    window_start: float = 0.0
    stalled_until: float = 0.0
    # dynamic reclaiming (per-window, reset on roll — DESIGN.md §7.5)
    donated: float = 0.0         # quota pulled out of this core's window
    drawn: float = 0.0           # quota granted to this core's window
    # instrumentation: obs.metrics instruments — the regulator binds
    # registry-owned series (throttle.trips{core=} is on the engine
    # parity contract) or detached instances when unmetered
    trips: Counter = dataclasses.field(default_factory=Counter)
    used_total: Counter = dataclasses.field(default_factory=Counter)
    denied_total: Counter = dataclasses.field(default_factory=Counter)
    # worst observed charge past the per-window limit (the enforcement
    # invariant ``used <= limit`` up to one accounting quantum; the
    # event engine's closed-form charging keeps this at float epsilon,
    # the quantum engine at one reactive overshoot <= rate x dt, and
    # admission mode at exactly 0 — asserted by tests/test_faults.py)
    overrun: Gauge = dataclasses.field(default_factory=Gauge)

    # compatibility views over the metric instruments
    @property
    def throttle_events(self) -> int:
        return int(self.trips.value)

    @property
    def total_used(self) -> float:
        return self.used_total.value

    @property
    def total_denied(self) -> float:
        return self.denied_total.value

    @property
    def max_overrun(self) -> float:
        return self.overrun.value

    @property
    def limit(self) -> float:
        """Effective per-window allowance: the enforced budget minus what
        this core donated plus what it drew from donors."""
        if self.budget == _INF:
            return _INF
        return self.budget - self.donated + self.drawn


class BandwidthRegulator:
    """Per-core regulator bank; budget is set by the running gang."""

    def __init__(self, n_cores: int, interval: float = 1.0,
                 mode: str = "reactive", reclaim: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 record_history: bool = False):
        assert mode in ("reactive", "admission")
        self.mode = mode
        self.interval = interval
        self.reclaim = reclaim
        # fault-injection hook (core/faults.py "lost wakeup"): every
        # stall routes its stall-until through this callable(core, t) ->
        # t', so a fault plan can delay or drop the window-end wakeup.
        # None = stalls land exactly at the window boundary.
        self.stall_fault = None
        reg = metrics if metrics is not None \
            else MetricsRegistry(enabled=False)
        self.metrics = metrics
        self._reclaimed = reg.counter("reclaim.drawn")
        self.cores: Dict[int, ThrottleState] = {
            c: ThrottleState(
                budget=float("inf"), interval=interval, core=c,
                trips=reg.counter("throttle.trips", parity=True, core=c),
                used_total=reg.counter("throttle.used_total", core=c),
                denied_total=reg.counter("throttle.denied_total", core=c),
                overrun=reg.gauge("throttle.max_overrun", core=c))
            for c in range(n_cores)}
        # counter-track samples for the Perfetto export (obs.perfetto):
        # ("window", t_end, core, used, limit) per closed finite-budget
        # window, ("draw", t, cumulative) per reclaim transfer. Opt-in:
        # unbounded growth is wrong for long executor runs.
        self.history: Optional[List[Tuple]] = [] if record_history else None
        self._lock = threading.Lock()

    @property
    def total_reclaimed(self) -> float:
        """Units drawn from donors, lifetime."""
        return self._reclaimed.value

    def set_gang_budget(self, budget: Optional[float]) -> Set[int]:
        """Called on gang-lock acquisition: the new gang's declared budget is
        enforced on every core that runs best-effort work (paper §IV-F).
        A budget increase (e.g. the throttling gang departed) lifts stalls
        from the previous regime; usage within the window is kept."""
        return self.set_core_budgets({}, default=budget)

    def set_core_budgets(self, budgets: Dict[int, Optional[float]],
                         default: Optional[float] = None) -> Set[int]:
        """Per-core budget assignment (virtual gangs: each member gang
        declares its own tolerable traffic, so the enforced budget can
        differ per core — see vgang/sched.py). Cores absent from
        ``budgets`` get ``default``. Same stall-lift rule as
        ``set_gang_budget``: a budget increase releases the stall.

        Returns the cores whose regime actually changed (budget moved or
        a stall was lifted) — the event engine folds exactly these into
        its dirty-core set instead of rescanning every core.

        Mid-window lowering: a cut below the core's already-consumed
        usage takes effect *immediately* — ``is_stalled`` treats
        ``used > limit`` as a trip the moment it is next consulted (both
        engines consult it right after truing up the core's usage), so
        the core cannot overrun the new regime until the next
        ``_roll_window``. A decrease also revokes any unspent reclaimed
        grant (``drawn``): the stricter incoming regime wins over quota
        donated under the old one."""
        changed: Set[int] = set()
        with self._lock:
            for c, st in self.cores.items():
                raw = budgets.get(c, default)
                b = float("inf") if raw is None else float(raw)
                if b == st.budget:
                    continue
                if b > st.budget and st.stalled_until > 0.0:
                    st.stalled_until = 0.0
                if b < st.budget:
                    st.drawn = 0.0
                st.budget = b
                changed.add(c)
        return changed

    def _set_stall(self, core: int, st: ThrottleState) -> None:
        """Stall ``core`` until the end of its current window, routed
        through the ``stall_fault`` hook (a lost-wakeup fault extends
        the stall past the boundary). Every stall site goes through
        here so the fault applies uniformly in both engines and the
        executor."""
        until = st.window_start + st.interval
        if self.stall_fault is not None:
            until = self.stall_fault(core, until)
        st.stalled_until = until

    def _note_overrun(self, st: ThrottleState, before: float) -> None:
        """Record how far a *charge* pushed usage past the limit.
        Pre-existing excess (``before`` already over: a mid-window
        budget cut below consumed quota, which ``is_stalled`` converts
        to an immediate stall) is the regime's doing, not a charging
        overrun, and is excluded."""
        if st.budget == _INF or before > st.limit + 1e-12:
            return
        st.overrun.update_max(st.used - st.limit)

    def max_overrun(self) -> float:
        """Worst charge past a per-window limit across all cores."""
        return max(st.max_overrun for st in self.cores.values())

    def _roll_window(self, st: ThrottleState, now: float) -> None:
        delta = now - st.window_start
        if delta >= st.interval:
            if self.history is not None and st.budget != _INF:
                t_end = st.window_start + st.interval
                self.history.append(
                    ("window", t_end, st.core, st.used, st.limit))
                if delta >= 2 * st.interval:
                    # skipped windows carried no usage: one zero sample
                    # steps the counter track down instead of holding
                    self.history.append(
                        ("window", t_end + st.interval, st.core,
                         0.0, st.budget))
            # jump directly to the window containing ``now`` (O(1) even
            # after a long idle gap; every skipped window resets usage)
            st.window_start += int(delta / st.interval) * st.interval
            st.used = 0.0
            st.donated = 0.0
            st.drawn = 0.0

    def charge(self, core: int, amount: float, now: float) -> bool:
        """Account ``amount`` of traffic at time ``now``.

        reactive: always charges; returns False (and stalls the core until
        the next interval) if the budget is now exceeded.
        admission: charges only if it fits; returns False if denied.

        All-or-nothing view of ``charge_partial``: a reactive trip always
        admits a fraction < 1 (the overflowing amount never fully fit)."""
        return self.charge_partial(core, amount, now) >= 1.0

    def charge_partial(self, core: int, amount: float, now: float) -> float:
        """Charge one quantum, admitting a *fraction* of it: the counter
        accounts the full amount (reactive hardware overshoots by less
        than one sampling quantum), the core stalls when the budget is
        exceeded, and the return value is the fraction of the quantum
        that executed before the trip. This keeps the dt-stepped
        engine's progress aligned with the closed-form engine, which
        runs work up to the exact exhaustion instant — without it, a
        lost tripping quantum per window can tip a completion past a
        whole stall period. Admission mode stays all-or-nothing."""
        st = self.cores[core]
        self._roll_window(st, now)
        if now < st.stalled_until:
            st.denied_total.value += amount
            return 0.0
        limit = st.limit
        if self.mode == "admission":
            if st.used + amount > limit:
                st.trips.value += 1
                st.denied_total.value += amount
                self._set_stall(core, st)
                return 0.0
            st.used += amount
            st.used_total.value += amount
            return 1.0
        before = st.used
        st.used += amount
        st.used_total.value += amount
        if st.used > limit:
            st.trips.value += 1
            self._note_overrun(st, before)
            self._set_stall(core, st)
            if amount <= 0.0:
                return 0.0
            return max(0.0, min(1.0, (limit - before) / amount))
        return 1.0

    def is_stalled(self, core: int, now: float) -> bool:
        """Whether ``core`` may not run at ``now``. Usage above the
        current per-window limit counts as stalled even without an
        explicit trip — that is how a mid-window budget cut below the
        already-consumed quota (or a revoked reclaim grant) takes hold
        immediately; the implicit state is converted to an explicit
        stall-until-window-end here (counted once as a throttle event),
        so window-boundary wakeup predictions see it."""
        st = self.cores[core]
        self._roll_window(st, now)
        if now < st.stalled_until:
            return True
        if st.used > st.limit + 1e-12:
            st.trips.value += 1
            self._set_stall(core, st)
            return True
        return False

    def next_release(self, core: int, now: float) -> float:
        st = self.cores[core]
        return max(st.stalled_until, now)

    # ---- continuous-time interface (event-driven engine) -----------------
    # The quantum simulator charges dt-sized packets through ``charge``;
    # the exact engine instead runs best-effort work over closed intervals
    # and needs (a) span accounting, (b) the closed-form time at which the
    # current budget trips, (c) an explicit trip. These are the dt -> 0
    # limit of the reactive mode (no one-quantum overshoot).

    def window_end(self, core: int, now: float) -> float:
        st = self.cores[core]
        self._roll_window(st, now)
        return st.window_start + st.interval

    def charge_span(self, core: int, rate: float, t0: float,
                    t1: float) -> None:
        """Account continuous traffic at ``rate`` units/ms over [t0, t1].
        Spans may cross regulation-window boundaries; usage carried into
        the window containing ``t1`` is exactly the traffic generated since
        that window opened."""
        st = self.cores[core]
        self._roll_window(st, t0)
        amount = rate * (t1 - t0)
        if t1 < st.window_start + st.interval:
            before = st.used
            st.used += amount
        else:
            self._roll_window(st, t1)
            before = 0.0
            st.used = rate * (t1 - st.window_start)
        st.used_total.value += amount
        self._note_overrun(st, before)

    def next_trip_time(self, core: int, rate: float, now: float) -> float:
        """Absolute time at which continuous traffic at ``rate`` exceeds the
        per-window limit, assuming the rate holds; inf if it never does.
        Exactly reaching the limit at a window boundary does not trip
        (usage never *exceeds* it). Under reclaiming the current window's
        limit includes the pool draw already granted to this core
        (``drawn``) minus what it donated; a prediction crossing into the
        next window prices the plain budget (both counters reset at the
        roll, and future donations only *raise* the limit, so the
        prediction is re-derived at the trip event, never missed)."""
        st = self.cores[core]
        self._roll_window(st, now)
        if st.budget == float("inf") or rate <= 0.0:
            return float("inf")
        we = st.window_start + st.interval
        t = now + max(0.0, st.limit - st.used) / rate
        if t < we - 1e-12:
            return t
        if st.budget / rate < st.interval - 1e-12:
            return we + st.budget / rate
        return float("inf")

    def trip(self, core: int, now: float) -> None:
        """Stall ``core`` until the end of the current regulation window
        (the budget was exhausted at ``now``)."""
        st = self.cores[core]
        self._roll_window(st, now)
        st.trips.value += 1
        self._set_stall(core, st)

    # ---- dynamic reclaiming (DESIGN.md §7.5) -------------------------
    # Pure accounting: eligibility (which cores may donate, which
    # occupants may draw) is decided by the caller — the MemoryModel for
    # the simulator engines, the executor for lanes.

    def donatable(self, core: int, now: float) -> float:
        """Unspent quota of ``core``'s current window that a donor scan
        may hand out: limit - used, for finite budgets only (an
        unthrottled core has no meaningful quota to give)."""
        st = self.cores[core]
        self._roll_window(st, now)
        if st.budget == _INF:
            return 0.0
        return max(0.0, st.limit - st.used)

    def draw_from(self, core: int, donors: Iterable[int], need: float,
                  now: float, require_full: bool = False) -> float:
        """Pull up to ``need`` units out of ``donors``' windows (scanned
        in the given order — callers pass core order, which both engines
        and the analysis replicate) and grant them to ``core``'s window.
        Returns the amount actually drawn; 0 when reclaiming is off.

        ``require_full``: draw nothing unless the donors can cover the
        whole ``need`` — an admission-mode caller gains nothing from a
        partial grant (the quantum is still denied whole), while the
        donors would lose the quota for the rest of the window."""
        if not self.reclaim or need <= 0.0:
            return 0.0
        got = 0.0
        with self._lock:
            donors = [d for d in donors if d != core]
            if require_full:
                avail = sum(self.donatable(d, now) for d in donors)
                if avail < need - 1e-15:
                    return 0.0
            for d in donors:
                got += self._transfer(d, core, need - got, now)
                if got >= need - 1e-15:
                    break
        return got

    def _transfer(self, donor: int, drawer: int, amount: float,
                  now: float) -> float:
        """Move up to ``amount`` of ``donor``'s unspent window quota to
        ``drawer``'s window — the one place the donation invariant
        (donor ``donated`` marked so quota is never handed out twice,
        drawer ``drawn`` credited, ``total_reclaimed`` accounted) is
        maintained; ``draw_from`` and MemoryModel.claim both route
        through it. Returns the amount moved."""
        take = min(self.donatable(donor, now), amount)
        if take <= 0.0:
            return 0.0
        self.cores[donor].donated += take
        st = self.cores[drawer]
        self._roll_window(st, now)
        st.drawn += take
        self._reclaimed.value += take
        if self.history is not None:
            self.history.append(("draw", now, self._reclaimed.value))
        return take

    def unstall(self, core: int) -> None:
        """Lift ``core``'s stall (a reclaim draw restored its quota)."""
        self.cores[core].stalled_until = 0.0

    def reset_reclaim(self) -> None:
        """Void every core's window donation state. Drivers call this on
        each gang-lock *acquire*: grants and donation marks belong to
        the regime that issued them, and an incoming gang whose budget
        values happen to equal the old ones would otherwise inherit
        them (``set_core_budgets`` diffs values and cannot see the
        leadership change)."""
        with self._lock:
            for st in self.cores.values():
                st.donated = 0.0
                st.drawn = 0.0
