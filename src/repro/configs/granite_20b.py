"""Granite-20B-Code — llama-arch MQA (kv=1) code model. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=False,
    rope_theta=10_000.0,
    source="arXiv:2405.04324 (hf: ibm-granite/granite-20b-code-base)",
)
