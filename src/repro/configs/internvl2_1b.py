"""InternVL2-1B — InternViT (stub) + Qwen2-0.5B-like LM backbone.

[arXiv:2404.16821; hf]. The vision tower is a STUB per the brief:
``input_specs()`` provides precomputed patch embeddings (n_vision_tokens of
them) which the model prepends to the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    n_vision_tokens=256,
    source="arXiv:2404.16821 (hf: OpenGVLab/InternVL2-1B; LM = Qwen2-0.5B)",
)
