"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""
from __future__ import annotations

from repro.configs.base import (ModelConfig, MoEConfig, ParallelConfig,
                                RGLRUConfig, SSMConfig, ShapeConfig, SHAPES,
                                reduced)

from repro.configs import (granite_20b, internvl2_1b, kimi_k2_1t,
                           mamba2_1_3b, minitron_4b, olmoe_1b_7b, qwen2_72b,
                           qwen2_7b, recurrentgemma_9b, whisper_base)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_72b, minitron_4b, qwen2_7b, granite_20b, mamba2_1_3b,
              internvl2_1b, kimi_k2_1t, olmoe_1b_7b, recurrentgemma_9b,
              whisper_base)
}

ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(arch_id: str) -> ModelConfig:
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")


def get_shape(shape_id: str) -> ShapeConfig:
    try:
        return SHAPES[shape_id]
    except KeyError:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES)}")


def valid_cells():
    """All runnable (arch, shape) cells with skip reasons for the rest.

    Returns (runnable, skipped) where skipped maps (arch, shape) -> reason.
    """
    runnable, skipped = [], {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.sub_quadratic:
                skipped[(arch, shape)] = (
                    "long_500k needs sub-quadratic attention; "
                    f"{arch} is full-attention (KV cache at 512k seq is "
                    "O(seq) per layer per sequence — architecture-infeasible, "
                    "not a sharding gap)")
                continue
            runnable.append((arch, shape))
    return runnable, skipped


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
           "ParallelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
           "get_config", "get_shape", "reduced", "valid_cells"]
