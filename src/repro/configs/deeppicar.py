"""DeepPicar DAVE-2 CNN — the paper's own real-time DNN control workload.

NVIDIA DAVE-2 architecture (Bojarski et al., arXiv:1604.07316) as used by
DeepPicar [Bechtel et al., RTCSA'18] and by RT-Gang's case study (paper §II,
Fig.1, Fig.6): 200x66 RGB input, 5 conv layers, 3 fc layers + steering output.
This is not one of the 10 assigned LM architectures; it exists to drive the
paper-faithful benchmarks (fig1/fig6) on the gang-scheduled executor.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class Dave2Config:
    name: str = "deeppicar-dave2"
    input_hw: Tuple[int, int] = (66, 200)
    in_channels: int = 3
    # (out_channels, kernel, stride)
    conv: Tuple[Tuple[int, int, int], ...] = (
        (24, 5, 2), (36, 5, 2), (48, 5, 2), (64, 3, 1), (64, 3, 1))
    fc: Tuple[int, ...] = (100, 50, 10)
    n_outputs: int = 1


CONFIG = Dave2Config()
