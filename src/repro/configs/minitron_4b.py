"""Minitron-4B — pruned Nemotron dense GQA transformer. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    qkv_bias=False,
    act="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2407.14679 (hf: nvidia/Minitron-4B-Base)",
)
