"""OLMoE-1B-7B — 64-expert top-8 MoE. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,                       # per-expert hidden
    vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, capacity_factor=1.25),
    rope_theta=10_000.0,
    source="arXiv:2409.02060 (hf: allenai/OLMoE-1B-7B-0924)",
)
