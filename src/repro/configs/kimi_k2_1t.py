"""Kimi K2 1T-A32B — trillion-param MoE, 384 experts top-8. [arXiv:2501.kimi2]

Per the assigned table: 61L, d_model 7168, 64H GQA kv=8, per-expert d_ff 2048,
vocab 163840, 384 experts top-8. Deviations from the real K2 (MLA attention,
dense first layer, shared expert) are intentional — we follow the assigned
table; head_dim is set to 128 explicitly (7168/64 = 112 is MXU-hostile), so
q-proj is 7168->8192 and kv-proj 7168->1024.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,                       # per-expert hidden
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    source="arXiv:2501.kimi2 (paper-table config; see module docstring)",
)
