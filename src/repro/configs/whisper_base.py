"""Whisper-base — encoder-decoder ASR backbone, conv frontend STUB.

[arXiv:2212.04356]. Per the brief the mel/conv frontend is a stub:
``input_specs()`` provides precomputed frame embeddings (1500, d_model) as the
encoder input; the transformer backbone (6L enc + 6L dec, d512, 8H MHA,
GELU MLP) is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                      # decoder layers
    n_encoder_layers=6,
    is_encoder_decoder=True,
    n_encoder_frames=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=0.0,                  # whisper uses learned/sinusoidal positions
    source="arXiv:2212.04356 (hf: openai/whisper-base)",
)
