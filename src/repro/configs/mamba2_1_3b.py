"""Mamba2-1.3B — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060 (mamba2-1.3b: 48L d2048 N=128 P=64 expand=2)",
)
