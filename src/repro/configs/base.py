"""Model / run configuration dataclasses.

One ``ModelConfig`` covers every assigned architecture family:
dense transformer LMs (with GQA/MQA, optional QKV bias, optional local window),
MoE transformers, Mamba2 (SSD), RG-LRU hybrids (recurrentgemma), and
encoder-decoder (whisper). Modality frontends (audio conv, vision tower) are
STUBS per the brief: ``input_specs()`` supplies precomputed frame/patch
embeddings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # experts' hidden size lives in ModelConfig.d_ff (per-expert width)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters."""
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length (training/prefill)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent-block parameters."""
    lru_width: int = 0            # 0 => d_model
    conv_width: int = 4
    c_exponent: float = 8.0       # a = sigmoid(L)^(c * r_t)
    # block pattern: cycle of layer kinds, truncated to n_layers
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    act: str = "swiglu"           # swiglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    window: int = 0               # 0 => full causal attention; >0 => local window
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_encoder_frames: int = 1500  # stubbed audio frontend output length
    # vlm stub
    n_vision_tokens: int = 0      # prepended patch-embedding tokens
    # numerics
    dtype: str = "bfloat16"
    # notes for DESIGN/EXPERIMENTS (citations, deviations)
    source: str = ""

    # ---- derived helpers -------------------------------------------------
    def norm_style(self) -> str:
        return self.norm

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode with O(1)/O(window) state (long_500k)."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence (for hybrids)."""
        if self.family == "hybrid":
            assert self.rglru is not None
            pat = self.rglru.pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.family == "moe":
            return ("moe",) * self.n_layers
        return ("attn",) * self.n_layers

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for MODEL_FLOPS."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind in ("attn",):
                per_layer_attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                if self.qkv_bias:
                    per_layer_attn += self.q_dim + 2 * self.kv_dim
                mlp = 3 * D * F if self.act == "swiglu" else 2 * D * F
                per_layer += per_layer_attn + mlp + 2 * D
            elif kind == "moe":
                assert self.moe is not None
                attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                experts = self.moe.n_experts * 3 * D * F
                router = D * self.moe.n_experts
                per_layer += attn + experts + router + 2 * D
            elif kind == "ssm":
                assert self.ssm is not None
                di = self.ssm.d_inner(D)
                nh = self.ssm.n_heads(D)
                in_proj = D * (2 * di + 2 * self.ssm.state_dim + nh)
                conv = self.ssm.conv_width * (di + 2 * self.ssm.state_dim)
                out_proj = di * D
                per_layer += in_proj + conv + out_proj + nh * 2 + di + 2 * D
            elif kind == "rec":
                assert self.rglru is not None
                w = self.rglru.lru_width or D
                per_layer += D * 2 * w + self.rglru.conv_width * w + 2 * w * w + w * D
                mlp = 3 * D * F if self.act == "swiglu" else 2 * D * F
                per_layer += mlp + 2 * D
        total = emb + per_layer + D  # final norm
        if self.is_encoder_decoder:
            # encoder self-attn+mlp + decoder cross-attn
            enc = self.n_encoder_layers * (
                4 * D * D * 1  # qkvo with n_heads*head_dim == D for whisper
                + (2 * D * F if self.act == "gelu" else 3 * D * F) + 2 * D)
            cross = self.n_layers * (D * self.q_dim + 2 * D * self.kv_dim
                                     + self.q_dim * D + D)
            total += enc + cross
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        assert self.moe is not None
        D, F = self.d_model, self.d_ff
        dense_like = self.n_params() - self.n_layers * (
            self.moe.n_experts - self.moe.top_k) * 3 * D * F
        return int(dense_like)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a run is laid out on the mesh."""
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None       # set for multi-pod meshes
    fsdp: bool = True                    # shard params/opt over data axis
    fsdp_pod: bool = True                # extend FSDP over the pod axis too
    tensor_parallel: bool = True
    expert_parallel: bool = True
    sequence_parallel: bool = False      # SP on activations (hillclimb lever)
    shard_kv_seq_on_decode: bool = True  # kv_heads < model axis => shard KV seq
    remat: str = "block"                 # none | block | full | dots
    grad_accum: int = 1
    optimizer: str = "adamw"             # adamw | adafactor
    opt_state_dtype: str = "float32"     # float32 | bfloat16
    grad_compress: str = "none"          # none | int8_ef (cross-pod allreduce)
    fused_xent: bool = False             # chunked-vocab fused softmax-xent (hillclimb)
    scan_layers: bool = True
    param_dtype: str = "float32"         # float32 | bfloat16 (dry-runs: bf16)
    compute_dtype: str = "bfloat16"      # forward-pass dtype
    q_block: int = 512                   # flash-attention q block (XLA path)
    kv_block: int = 1024                 # flash-attention kv block (XLA path)
    # --- hillclimb levers (defaults = paper-faithful baseline) ---
    explicit_rs: bool = False            # shard_map out-projections with
    #                                      psum_scatter (Megatron-SP) instead
    #                                      of GSPMD all-reduce
    moe_decode_cap_mult: float = 4.0     # decode expert-capacity multiplier
    pad_attention_heads: bool = False    # pad Hq up to a TP multiple so cp
    #                                      archs can run the tp recipe
    moe_weight_stationary: bool = False  # decode MoE: shard expert d_ff over
    #                                      `data` and move activations, not
    #                                      weights (kills the per-step FSDP
    #                                      weight gather at inference)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict = dict(
        n_layers=2 if not cfg.rglru else 3,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        n_encoder_frames=16 if cfg.is_encoder_decoder else cfg.n_encoder_frames,
        n_vision_tokens=4 if cfg.n_vision_tokens else 0,
        name=cfg.name + "-smoke",
        dtype="float32",
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(n_experts=4, top_k=2,
                                 capacity_factor=cfg.moe.capacity_factor)
        small["d_ff"] = 64
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                                 conv_width=cfg.ssm.conv_width, chunk=8)
    if cfg.rglru is not None:
        small["rglru"] = RGLRUConfig(lru_width=0, conv_width=cfg.rglru.conv_width,
                                     pattern=cfg.rglru.pattern)
        small["window"] = 8
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
