"""RecurrentGemma-9B — RG-LRU + local attention hybrid, 2:1. [arXiv:2402.19427]

Griffin block pattern (rec, rec, attn) cycled over 38 layers; local attention
window 2048, MQA (kv=1). GeGLU MLP, d_ff 12288 (per assigned table).
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    window=2048,
    act="gelu",
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      pattern=("rec", "rec", "attn")),
    rope_theta=10_000.0,
    source="arXiv:2402.19427 (Griffin; hf: google/recurrentgemma-9b)",
)
