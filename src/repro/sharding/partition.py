"""Logical-axis sharding rules -> PartitionSpec (MaxText-style).

Every parameter and key activation in the model stack is annotated with a
tuple of *logical* axis names.  ``Rules`` maps logical names to mesh axes,
with conflict resolution (a mesh axis may appear at most once per spec; later
claims are dropped) and divisibility checks (a dim not divisible by its mesh
axes falls back to replicated).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class Rules:
    def __init__(self, table: Dict[str, MeshAxes], mesh: Mesh):
        self.table = dict(table)
        self.mesh = mesh

    def axis_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor with the given logical dim names.

        If ``shape`` is given, any dim not divisible by its mesh-axis product
        is replicated instead (keeps GSPMD from padding weirdly).
        """
        used: set = set()
        out = []
        for i, name in enumerate(logical):
            axes = self.table.get(name) if name else None
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes if a in self.mesh.shape and a not in used)
            if not axes:
                out.append(None)
                continue
            if shape is not None:
                size = int(np.prod([self.mesh.shape[a] for a in axes]))
                if shape[i] % size != 0:
                    # try progressively shorter prefixes of the axis tuple
                    while axes and shape[i] % int(
                            np.prod([self.mesh.shape[a] for a in axes])) != 0:
                        axes = axes[:-1]
                    if not axes:
                        out.append(None)
                        continue
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


def make_rules(mesh: Mesh, parallel) -> Tuple[Rules, Rules]:
    """(param_rules, act_rules) for a ParallelConfig on the given mesh."""
    data_axes: Tuple[str, ...] = (parallel.data_axis,)
    if parallel.pod_axis and parallel.pod_axis in mesh.shape:
        batch_axes: MeshAxes = (parallel.pod_axis, parallel.data_axis)
    else:
        batch_axes = (parallel.data_axis,)
    model = parallel.model_axis if parallel.tensor_parallel else None

    fsdp_axes: MeshAxes = None
    if parallel.fsdp:
        fsdp_axes = data_axes
        if parallel.fsdp_pod and parallel.pod_axis and parallel.pod_axis in mesh.shape:
            fsdp_axes = (parallel.pod_axis, parallel.data_axis)

    param_table: Dict[str, MeshAxes] = {
        "embed": fsdp_axes,          # d_model dim of weights (ZeRO-3 style)
        "vocab": model,
        "vocab_in": fsdp_axes,       # untied input table: rows over fsdp,
        "embed_in": model,           # cols over model (gather stays local)
        "heads": model,              # flattened q_dim
        "kv": model,                 # flattened kv_dim
        "mlp": model,
        "experts": model if parallel.expert_parallel else None,
        "expert_mlp": data_axes,     # weight-stationary MoE: d_ff over data
        "ssm_inner": model,
        "ssm_heads": model,
        "ssm_state": None,
        "lru": model,
        "lru_blocks": model,
        "conv": None,
        "layers": None,              # scan dim
        "frames": None,
    }
    act_table: Dict[str, MeshAxes] = {
        "batch": batch_axes,
        "seq": model if parallel.sequence_parallel else None,
        "kv_seq": model if parallel.shard_kv_seq_on_decode else None,
        "heads": model,
        "kv": model,
        "mlp": model,
        "vocab": model,
        "experts": model if parallel.expert_parallel else None,
        "embed": None,
        "ssm_inner": model,
        "ssm_heads": model,
        "lru": model,
        "frames": None,
    }
    return Rules(param_table, mesh), Rules(act_table, mesh)


def constrain(x, rules: Rules, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names (no-op outside a mesh ctx)."""
    try:
        spec = rules.spec(logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    except (ValueError, TypeError):
        return x


def tree_specs(logical_tree, rules: Rules, shape_tree):
    """Map a pytree of logical tuples + shapes -> pytree of PartitionSpec."""
    return jax.tree.map(
        lambda lg, sh: rules.spec(lg, sh.shape if hasattr(sh, "shape") else sh),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
