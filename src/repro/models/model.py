"""ModelApi: unified build/init/loss/prefill/decode for every architecture.

``build_model(cfg, parallel, mesh)`` returns a :class:`ModelApi` whose
methods are pure functions suitable for ``jax.jit`` with shardings derived
from the logical-axis rules. All families scan over layers so HLO size is
O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import mamba2, moe, rglru, whisper
from repro.models import transformer as T
from repro.sharding.partition import Rules, constrain, make_rules

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _remat(body: Callable, policy: str) -> Callable:
    if policy == "none":
        return body
    if policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(body)  # "block"/"full": save only carries


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------
def xent_loss(logits, labels, rules: Rules):
    """Masked softmax cross-entropy; labels < 0 are ignored."""
    mask = (labels >= 0)
    labels_c = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    n = jnp.maximum(mask.sum(), 1)
    return nll.sum() / n, {"tokens": mask.sum()}


def fused_xent_loss(x, table, labels, rules: Rules, tied: bool,
                    chunk: int = 1024):
    """Chunked-vocab fused softmax-xent: never materializes (B,S,V) logits.

    Scans over sequence chunks; each chunk computes its logits, reduces to
    (lse, gold) and discards them. Grad recomputes per chunk (checkpointed).
    """
    B, S, D = x.shape
    mask = labels >= 0
    labels_c = jnp.maximum(labels, 0)
    nchunk = max(1, S // chunk)
    xs = x.reshape(B, nchunk, S // nchunk, D).transpose(1, 0, 2, 3)
    ls = labels_c.reshape(B, nchunk, S // nchunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, inp):
        xc, lc = inp
        xf = xc.astype(jnp.float32)
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", xf, table.astype(jnp.float32))
        else:
            logits = jnp.einsum("bsd,dv->bsv", xf, table.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(where=None), None

    # accumulate sum of per-token nll over chunks, then mask-normalize.
    # (mask handled by zeroing nll of masked tokens inside)
    def step_masked(carry, inp):
        xc, lc, mc = inp
        xf = xc.astype(jnp.float32)
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", xf, table.astype(jnp.float32))
        else:
            logits = jnp.einsum("bsd,dv->bsv", xf, table.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + ((lse - gold) * mc).sum(), None

    ms = mask.reshape(B, nchunk, S // nchunk).transpose(1, 0, 2)
    total, _ = jax.lax.scan(jax.checkpoint(step_masked), jnp.float32(0.0),
                            (xs, ls, ms))
    n = jnp.maximum(mask.sum(), 1)
    return total / n, {"tokens": mask.sum()}


# --------------------------------------------------------------------------
# ModelApi
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    parallel: ParallelConfig
    mesh: Any
    defs: Any
    rules_p: Rules
    rules_a: Rules
    recipe: str

    # ---- params ----------------------------------------------------------
    def init(self, rng) -> Any:
        return L.init_params(rng, self.defs, DTYPES[self.parallel.param_dtype])

    def param_shapes(self) -> Any:
        return L.param_shapes(self.defs, DTYPES[self.parallel.param_dtype])

    def param_pspecs(self) -> Any:
        return jax.tree.map(
            lambda d: self.rules_p.spec(d.logical, d.shape),
            self.defs, is_leaf=L.is_def)

    def param_shardings(self) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_pspecs())

    def n_params(self) -> int:
        return int(sum(np.prod(d.shape) for d in
                       jax.tree.leaves(self.defs, is_leaf=L.is_def)))

    # ---- ctx --------------------------------------------------------------
    def _ctx(self, mode: str, positions) -> T.Ctx:
        return T.Ctx(cfg=self.cfg, parallel=self.parallel, rules=self.rules_a,
                     mesh=self.mesh, mode=mode, positions=positions,
                     recipe=self.recipe, q_block=self.parallel.q_block,
                     kv_block=self.parallel.kv_block)

    def _compute_dtype(self):
        return DTYPES[self.parallel.compute_dtype] if \
            self.cfg.dtype == "bfloat16" else DTYPES[self.cfg.dtype]

    def _cast(self, params):
        cd = self._compute_dtype()
        return jax.tree.map(
            lambda a: a.astype(cd) if a.dtype == jnp.float32 and
            jnp.issubdtype(a.dtype, jnp.floating) else a, params)

    # ---- forward ----------------------------------------------------------
    def _embed_in(self, params, batch, ctx):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = T.embed_tokens(cfg, params, tokens, self.rules_a,
                           self._compute_dtype())
        if cfg.family == "vlm":
            nv = cfg.n_vision_tokens
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x[:, nv:, :]], axis=1)
            x = constrain(x, self.rules_a, ("batch", "seq", None))
        return x

    def _run_blocks(self, params, x, ctx, caches=None):
        """Dispatch per family; returns (x, new_caches, aux)."""
        cfg = self.cfg
        policy = self.parallel.remat if ctx.mode == "train" else "none"
        fam = cfg.family

        if fam in ("dense", "vlm"):
            return self._run_uniform(params["blocks"], x, ctx, caches,
                                     T.dense_block_apply, policy)
        if fam == "moe":
            return self._run_moe(params["blocks"], x, ctx, caches, policy)
        if fam == "ssm":
            return self._run_uniform(params["blocks"], x, ctx, caches,
                                     mamba2.ssm_block_apply, policy)
        if fam == "hybrid":
            return self._run_hybrid(params, x, ctx, caches, policy)
        raise ValueError(fam)

    def _run_uniform(self, blocks, x, ctx, caches, apply_fn, policy):
        collect = ctx.mode == "prefill"
        if ctx.mode == "decode":
            def body(carry, xs):
                blk, cache = xs
                y, c = apply_fn(ctx, blk, carry, cache)
                return y, c
            x, new_caches = jax.lax.scan(body, x, (blocks, caches))
            return x, new_caches, {}

        def body(carry, blk):
            y, c = apply_fn(ctx, blk, carry)
            return y, (c if collect else None)
        body = _remat(body, policy)
        x, ys = jax.lax.scan(body, x, blocks)
        return x, (ys if collect else None), {}

    def _run_moe(self, blocks, x, ctx, caches, policy):
        collect = ctx.mode == "prefill"
        if ctx.mode == "decode":
            def body(carry, xs):
                blk, cache = xs
                y, c, _aux = moe.moe_block_apply(ctx, blk, carry, cache)
                return y, c
            x, new_caches = jax.lax.scan(body, x, (blocks, caches))
            return x, new_caches, {}

        def body(carry, blk):
            y, lb, rz = carry
            y, c, aux = moe.moe_block_apply(ctx, blk, y)
            return ((y, lb + aux["load_balance"], rz + aux["router_z"]),
                    (c if collect else None))
        body = _remat(body, policy)
        (x, lb, rz), ys = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)), blocks)
        n = self.cfg.n_layers
        aux = {"load_balance": lb / n, "router_z": rz / n}
        return x, (ys if collect else None), aux

    def _run_hybrid(self, params, x, ctx, caches, policy):
        collect = ctx.mode == "prefill"
        kinds = {"rec": rglru.rec_block_apply, "attn": rglru.attn_block_apply_rg}
        pattern = self.cfg.rglru.pattern

        def group_body(carry, xs):
            if ctx.mode == "decode":
                blk, cache = xs
            else:
                blk = xs
                cache = {k: None for k in blk}
            y = carry
            outs = {}
            for i, kind in enumerate(pattern):
                key = f"{kind}{i}"
                y, c = kinds[kind](ctx, blk[key], y, cache.get(key))
                if collect or ctx.mode == "decode":
                    outs[key] = c
            return y, (outs if outs else None)

        def tail_body(carry, xs):
            if ctx.mode == "decode":
                blk, cache = xs
            else:
                blk, cache = xs, None
            y, c = rglru.rec_block_apply(ctx, blk, carry, cache)
            return y, (c if (collect or ctx.mode == "decode") else None)

        gb = _remat(group_body, policy) if ctx.mode == "train" else group_body
        tb = _remat(tail_body, policy) if ctx.mode == "train" else tail_body

        new_caches = {}
        if ctx.mode == "decode":
            x, gc = jax.lax.scan(gb, x, (params["groups"], caches["groups"]))
            new_caches["groups"] = gc
            if "tail" in params:
                x, tc = jax.lax.scan(tb, x, (params["tail"], caches["tail"]))
                new_caches["tail"] = tc
        else:
            x, gc = jax.lax.scan(gb, x, params["groups"])
            new_caches["groups"] = gc
            if "tail" in params:
                x, tc = jax.lax.scan(tb, x, params["tail"])
                new_caches["tail"] = tc
        if not collect and ctx.mode != "decode":
            new_caches = None
        return x, new_caches, {}

    # ---- public entry points ----------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        params = self._cast(params)
        if cfg.family == "audio":
            return self._whisper_loss(params, batch)
        B, S = batch["tokens"].shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        ctx = self._ctx("train", positions)
        x = self._embed_in(params, batch, ctx)
        x, _, aux = self._run_blocks(params, x, ctx)
        x = T.final_norm(cfg, params, x)
        if self.parallel.fused_xent:
            table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
            loss, metrics = fused_xent_loss(x, table, batch["labels"],
                                            self.rules_a, cfg.tie_embeddings)
        else:
            logits = T.lm_logits(cfg, params, x, self.rules_a)
            loss, metrics = xent_loss(logits, batch["labels"], self.rules_a)
        if aux:
            loss = loss + (cfg.moe.router_aux_coef * aux["load_balance"]
                           + 1e-4 * aux["router_z"])
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    def prefill_fn(self, params, batch):
        cfg = self.cfg
        params = self._cast(params)
        if cfg.family == "audio":
            return self._whisper_prefill(params, batch)
        B, S = batch["tokens"].shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        ctx = self._ctx("prefill", positions)
        x = self._embed_in(params, batch, ctx)
        x, caches, _ = self._run_blocks(params, x, ctx)
        x = T.final_norm(cfg, params, x)
        logits = T.lm_logits(cfg, params, x[:, -1:, :], self.rules_a)
        return logits, caches

    def decode_fn(self, params, caches, tokens, pos):
        """tokens: (B,1) int32; pos: (B,) position of the new token."""
        cfg = self.cfg
        params = self._cast(params)
        if cfg.family == "audio":
            return self._whisper_decode(params, caches, tokens, pos)
        ctx = self._ctx("decode", pos)
        x = T.embed_tokens(cfg, params, tokens, self.rules_a,
                           self._compute_dtype())
        x, new_caches, _ = self._run_blocks(params, x, ctx, caches)
        x = T.final_norm(cfg, params, x)
        logits = T.lm_logits(cfg, params, x, self.rules_a)
        return logits, new_caches

    # ---- whisper ----------------------------------------------------------
    def _whisper_loss(self, params, batch):
        cfg = self.cfg
        ctx = self._ctx("train", None)
        enc = whisper.encode(ctx, params, batch["frames"].astype(
            self._compute_dtype()))
        B, S = batch["tokens"].shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        ctx.positions = positions
        x = whisper.decoder_embed(ctx, params, batch["tokens"],
                                  positions, self._compute_dtype())
        x = constrain(x, self.rules_a, ("batch", "seq", None))
        x, _ = whisper.run_decoder_train(ctx, params, x, enc)
        x = L.layer_norm(x, params["final_ln"], params["final_ln_b"],
                         cfg.norm_eps)
        logits = T.lm_logits(cfg, params, x, self.rules_a)
        loss, metrics = xent_loss(logits, batch["labels"], self.rules_a)
        metrics["loss"] = loss
        return loss, metrics

    def _whisper_prefill(self, params, batch):
        cfg = self.cfg
        ctx = self._ctx("prefill", None)
        enc = whisper.encode(ctx, params, batch["frames"].astype(
            self._compute_dtype()))
        B, S = batch["tokens"].shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        ctx.positions = positions
        x = whisper.decoder_embed(ctx, params, batch["tokens"], positions,
                                  self._compute_dtype())
        x, caches = whisper.run_decoder_train(ctx, params, x, enc)
        x = L.layer_norm(x, params["final_ln"], params["final_ln_b"],
                         cfg.norm_eps)
        logits = T.lm_logits(cfg, params, x[:, -1:, :], self.rules_a)
        return logits, caches

    def _whisper_decode(self, params, caches, tokens, pos):
        cfg = self.cfg
        ctx = self._ctx("decode", pos)
        x = whisper.decoder_embed(ctx, params, tokens, pos[:, None],
                                  self._compute_dtype())
        x, new_caches = whisper.run_decoder_decode(ctx, params, x, caches)
        x = L.layer_norm(x, params["final_ln"], params["final_ln_b"],
                         cfg.norm_eps)
        logits = T.lm_logits(cfg, params, x, self.rules_a)
        return logits, new_caches


# --------------------------------------------------------------------------
# Builder
# --------------------------------------------------------------------------
def build_defs(cfg: ModelConfig, parallel: Optional[ParallelConfig] = None):
    if cfg.family == "audio":
        return whisper.whisper_defs(cfg)
    if cfg.family == "moe":
        ws = bool(parallel and parallel.moe_weight_stationary)
        return T.lm_defs(cfg, lambda c: moe.moe_block_defs(c, ws))
    if cfg.family == "ssm":
        return T.lm_defs(cfg, mamba2.ssm_block_defs)
    if cfg.family == "hybrid":
        pattern = cfg.rglru.pattern
        plen = len(pattern)
        n_groups, tail = divmod(cfg.n_layers, plen)
        group_defs = {}
        for i, kind in enumerate(pattern):
            group_defs[f"{kind}{i}"] = (
                rglru.rec_block_defs(cfg) if kind == "rec"
                else rglru.attn_block_defs_rg(cfg))
        D, V = cfg.d_model, cfg.vocab_size
        defs = {
            "embed": L.ParamDef((V, D), ("vocab", "embed") if
                                cfg.tie_embeddings else ("vocab_in", "embed_in")),
            "final_ln": L.ParamDef((D,), ("embed",), "ones"),
            "groups": L.stack_defs(group_defs, n_groups),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = L.ParamDef((D, V), ("embed", "vocab"))
        if tail:
            assert all(k == "rec" for k in
                       [pattern[i % plen] for i in range(n_groups * plen,
                                                         cfg.n_layers)]), \
                "tail layers must be recurrent"
            defs["tail"] = L.stack_defs(rglru.rec_block_defs(cfg), tail)
        return defs
    # dense / vlm
    return T.lm_defs(cfg, T.dense_block_defs)


def build_model(cfg: ModelConfig, parallel: ParallelConfig, mesh) -> ModelApi:
    rules_p, rules_a = make_rules(mesh, parallel)
    tp = mesh.shape.get(parallel.model_axis, 1) if mesh is not None else 1
    if parallel.pad_attention_heads and tp > 1 and cfg.n_heads % tp:
        # hillclimb lever: pad Hq to a TP multiple so head-parallel attention
        # applies (extra heads are real-but-redundant capacity; FLOPs grow by
        # padded/Hq on attention only, collectives shrink from ZeRO-gather to
        # Megatron-TP). Requires the padded count to stay a GQA multiple.
        padded = ((cfg.n_heads + tp - 1) // tp) * tp
        if cfg.n_kv_heads and padded % cfg.n_kv_heads == 0:
            cfg = dataclasses.replace(cfg, n_heads=padded)
    recipe = T.recipe_for(cfg, tp)
    defs = build_defs(cfg, parallel)
    return ModelApi(cfg=cfg, parallel=parallel, mesh=mesh, defs=defs,
                    rules_p=rules_p, rules_a=rules_a, recipe=recipe)
