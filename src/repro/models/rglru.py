"""RG-LRU recurrent block + hybrid assembly (RecurrentGemma / Griffin).

Griffin residual block = temporal mixer (RG-LRU recurrence OR local MQA
attention) + MLP. RG-LRU per channel c:

    r_t = sigmoid(W_a x_t)            (recurrence gate, block-diagonal W)
    i_t = sigmoid(W_x x_t)            (input gate, block-diagonal W)
    log a_t = -c_exp * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block-diagonal gate weights use n_heads blocks aligned to the ``model`` mesh
axis so the whole recurrence is shard-local under TP. Training/prefill uses
``jax.lax.associative_scan`` (the ``rglru_scan`` Pallas kernel implements the
chunked linear-time version for TPU); decode is the O(1) update.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import attn_defs, attn_apply, mlp_defs, mlp_apply
from repro.sharding.partition import constrain


def _n_blocks(cfg: ModelConfig) -> int:
    return max(cfg.n_heads, 1)


def rglru_mixer_defs(cfg: ModelConfig) -> Dict[str, L.ParamDef]:
    assert cfg.rglru is not None
    g = cfg.rglru
    D = cfg.d_model
    Wd = g.lru_width or D
    nb = _n_blocks(cfg)
    bw = Wd // nb
    return {
        "ln": L.ParamDef((D,), ("embed",), "ones"),
        "w_gate_branch": L.ParamDef((D, Wd), ("embed", "lru")),
        "w_x_branch": L.ParamDef((D, Wd), ("embed", "lru")),
        "conv": L.ParamDef((g.conv_width, Wd), (None, "lru"), "normal", 0.5),
        # block-diagonal gates: (nb, bw, bw), nb aligned to model axis
        "w_a": L.ParamDef((nb, bw, bw), ("lru_blocks", None, None)),
        "b_a": L.ParamDef((nb, bw), ("lru_blocks", None), "zeros"),
        "w_i": L.ParamDef((nb, bw, bw), ("lru_blocks", None, None)),
        "b_i": L.ParamDef((nb, bw), ("lru_blocks", None), "zeros"),
        "lam": L.ParamDef((Wd,), ("lru",), "ones"),
        "w_out": L.ParamDef((Wd, D), ("lru", "embed")),
    }


def rec_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"mix": rglru_mixer_defs(cfg), "mlp": mlp_defs(cfg)}


def _block_diag_apply(x, w, b, nb):
    """x: (B,S,Wd) -> (B,S,Wd) with block-diagonal weight (nb,bw,bw)."""
    B, S, Wd = x.shape
    bw = Wd // nb
    xb = x.reshape(B, S, nb, bw)
    y = jnp.einsum("bsnw,nwv->bsnv", xb, w) + b[None, None]
    return y.reshape(B, S, Wd)


def _rglru_scan(log_a, gx, h0=None):
    """Associative linear recurrence h_t = a_t h_{t-1} + gx_t.

    log_a, gx: (B,S,W) fp32. h0: (B,W) or None. Returns (h_all (B,S,W),
    h_last (B,W))."""
    a = jnp.exp(log_a)
    if h0 is not None:
        gx = gx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return hh, hh[:, -1]


def rglru_mixer_apply(ctx, p, x, cache: Optional[dict] = None):
    cfg = ctx.cfg
    g = cfg.rglru
    nb = _n_blocks(cfg)
    B, S, D = x.shape

    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.recipe == "tp" and ctx.mode != "decode":
        h = constrain(h, ctx.rules, ("batch", None, None))

    gate = L.gelu(h @ p["w_gate_branch"])
    xb = h @ p["w_x_branch"]
    conv_state = cache.get("conv") if cache else None
    xb, conv_new = L_causal_conv(xb, p["conv"], conv_state)

    xb = constrain(xb, ctx.rules, ("batch", None, "lru"))
    r = jax.nn.sigmoid(_block_diag_apply(xb, p["w_a"], p["b_a"], nb)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_apply(xb, p["w_i"], p["b_i"], nb)
                       .astype(jnp.float32))
    lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
    log_a = -g.c_exponent * lam[None, None, :] * r
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xb.astype(jnp.float32))

    if ctx.mode == "decode":
        assert cache is not None and S == 1
        h_prev = cache["h"].astype(jnp.float32)             # (B, Wd)
        h_new = jnp.exp(log_a[:, 0]) * h_prev + gated_x[:, 0]
        y = h_new[:, None]
        new_cache = {"conv": conv_new, "h": h_new}
    else:
        h0 = cache["h"].astype(jnp.float32) if cache else None
        y, h_last = _rglru_scan(log_a, gated_x, h0)
        new_cache = ({"conv": conv_new, "h": h_last}
                     if ctx.mode == "prefill" else None)

    y = (y.astype(x.dtype) * gate) @ p["w_out"]
    y = constrain(y, ctx.rules, ("batch", "seq", None))
    return x + y, new_cache


def rec_block_apply(ctx, p, x, cache=None):
    x, new_cache = rglru_mixer_apply(ctx, p["mix"], x, cache)
    x = mlp_apply(ctx, p["mlp"], x)
    return x, new_cache


def attn_block_defs_rg(cfg: ModelConfig) -> Dict[str, Any]:
    return {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg)}


def attn_block_apply_rg(ctx, p, x, cache=None):
    x, new_cache = attn_apply(ctx, p["attn"], x, cache)
    x = mlp_apply(ctx, p["mlp"], x)
    return x, new_cache


# local import indirection to avoid a cycle with mamba2 (shared conv)
from repro.models.mamba2 import _causal_conv as L_causal_conv  # noqa: E402
