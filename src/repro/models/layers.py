"""Core layers: param-def system, norms, RoPE, attention, MLP.

Conventions
-----------
* Params are nested dicts of arrays. Each model builder first constructs a
  matching nested dict of :class:`ParamDef` (shape + logical axis names +
  initializer), from which ``init`` (real arrays), ``eval_shape`` structs and
  ``PartitionSpec`` trees are all derived. Logical axis names are resolved by
  ``repro.sharding.partition.Rules``.
* Attention comes in two XLA-path flavours:
  - ``flash_attention_jnp``: double-blocked online-softmax attention
    (lax.scan over q-blocks and kv-chunks) — O(block) memory at any sequence
    length; this mirrors the Pallas kernel in ``repro.kernels.flash_attention``
    which replaces it on real TPUs.
  - ``decode_attention``: single-query attention against a KV cache.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Param definition system
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    scale: float = 1.0          # stddev multiplier for normal inits

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(rng, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, d.shape, jnp.float32) * std).astype(dtype)


def init_params(rng, defs, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    rngs = jax.random.split(rng, len(leaves))
    vals = [_init_one(r, d, dtype) for r, d in zip(rngs, leaves)]
    return jax.tree.unflatten(treedef, vals)


def param_shapes(defs, dtype=jnp.float32):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
                        is_leaf=is_def)


def param_logical(defs):
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a scan dimension of size n to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.logical, d.init,
                           d.scale),
        defs, is_leaf=is_def)


def count_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


# --------------------------------------------------------------------------
# Norms / activations / embeddings
# --------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return out.astype(dtype) * weight.astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out.astype(dtype) * weight.astype(dtype)) + bias.astype(dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32):
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (2 * dim / d))
    ang = pos * inv
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (XLA path)
# --------------------------------------------------------------------------
NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, Hkv, G, D), k: (B, Sk, Hkv, D) -> (B, Hkv, G, Sq, Sk)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def masked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                     q_offset=0, kv_len=None, softcap: float = 0.0):
    """Plain (materialized-scores) attention. Use only for small Sq*Sk.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). q_offset: absolute position of
    q[0] (int or (B,) array). kv_len: optional (B,) valid kv length.
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D) * (D ** -0.5)
    s = _gqa_scores(qg, k)  # (B, Hkv, G, Sq, Sk) fp32
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    Sk = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + q_offset  # q_offset: scalar
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask = mask[None] & (kpos[None] < kv_len[:, None, None])
        mask = mask[:, None, None]  # (B,1,1,Sq,Sk)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def flash_attention_jnp(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset=0, q_block: int = 512, kv_block: int = 1024,
                        softcap: float = 0.0):
    """Blocked online-softmax attention; memory O(q_block * kv_block).

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Sk >= Sq. ``q_offset`` is
    the absolute position of q[0] among the keys (may be a traced scalar —
    context parallelism passes ``axis_index * local_len``). Fully-masked kv
    blocks are skipped with lax.cond so compiled FLOPs track the causal
    triangle, not the square. Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    assert Sq % q_block == 0 and Sk % kv_block == 0, (Sq, Sk, q_block, kv_block)
    nq, nk = Sq // q_block, Sk // kv_block
    scale = D ** -0.5
    q_offset = jnp.asarray(q_offset, jnp.int32)

    qb = q.reshape(B, nq, q_block, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block           # qblk: (B, q_block, Hkv, G, D)
        qblk = qblk * scale
        q_start = q_offset + qi * q_block

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_kv
            k_start = ki * kv_block

            def compute(args):
                m, l, acc = args
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                               preferred_element_type=jnp.float32)
                if softcap > 0:
                    s = jnp.tanh(s / softcap) * softcap
                qpos = q_start + jnp.arange(q_block)[:, None]
                kpos = k_start + jnp.arange(kv_block)[None, :]
                mask = jnp.ones((q_block, kv_block), bool)
                if causal:
                    mask &= kpos <= qpos
                if window > 0:
                    mask &= kpos > qpos - window
                s = jnp.where(mask, s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk)
                acc_new = acc * corr[..., None].astype(acc.dtype) + pv
                return m_new, l_new, acc_new

            # skip blocks that are entirely masked out
            needed = jnp.asarray(True)
            if causal:
                needed &= k_start <= q_start + q_block - 1
            if window > 0:
                needed &= k_start + kv_block - 1 > q_start - window
            m, l, acc = jax.lax.cond(needed, compute, lambda a: a, (m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, D), v.dtype)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
        # (B, Hkv, G, q_block, D) -> (B, q_block, Hkv, G, D)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # outs: (nq, B, q_block, Hkv, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D)
    return out


# context-parallel entry point: same math, explicit q_offset
flash_attention_cp = flash_attention_jnp


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              q_block=512, kv_block=1024):
    """Dispatch: small sequences -> materialized; long -> blocked flash."""
    S = q.shape[1]
    if S <= max(q_block, 512) or S % q_block or S % kv_block:
        return masked_attention(q, k, v, causal=causal, window=window,
                                softcap=softcap)
    return flash_attention_jnp(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_block=q_block,
                               kv_block=kv_block)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     softcap: float = 0.0):
    """Single-position attention against a cache.

    q: (B, 1, Hq, D); caches: (B, Smax, Hkv, D); pos: (B,) current index
    (the new token's position; cache entries > pos are invalid).
    """
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(k_cache.shape[1])[None, :]
    mask = kpos <= pos[:, None]
    if window > 0:
        mask &= kpos > pos[:, None] - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return out.reshape(B, 1, Hq, D)
