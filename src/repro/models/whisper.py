"""Whisper-base encoder-decoder backbone. [arXiv:2212.04356]

The mel/conv frontend is a STUB per the brief: the model consumes precomputed
frame embeddings (B, n_frames, d_model). The transformer backbone is real:
pre-LN, learned decoder positions, sinusoidal encoder positions, GELU MLPs,
MHA with biases. Decode uses a self-attention KV cache plus per-layer
cross-attention K/V precomputed from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import (Ctx, attn_defs, attn_apply, mlp_defs,
                                      mlp_apply, _norm, _qkv)
from repro.sharding.partition import constrain

MAX_DECODER_POS = 32768  # assigned decode_32k shape exceeds whisper's 448


def enc_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg)}


def dec_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"self": attn_defs(cfg), "cross": attn_defs(cfg),
            "mlp": mlp_defs(cfg)}


def whisper_defs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab_size
    return {
        "embed": L.ParamDef((V, D), ("vocab", "embed")),
        "pos_dec": L.ParamDef((MAX_DECODER_POS, D), (None, "embed"),
                              "normal", 0.02),
        "enc_blocks": L.stack_defs(enc_block_defs(cfg), cfg.n_encoder_layers),
        "enc_ln": L.ParamDef((D,), ("embed",), "ones"),
        "enc_ln_b": L.ParamDef((D,), ("embed",), "zeros"),
        "dec_blocks": L.stack_defs(dec_block_defs(cfg), cfg.n_layers),
        "final_ln": L.ParamDef((D,), ("embed",), "ones"),
        "final_ln_b": L.ParamDef((D,), ("embed",), "zeros"),
    }


def _enc_attn(ctx: Ctx, p, x):
    """Non-causal encoder self-attention (frames are short: materialized)."""
    cfg = ctx.cfg
    h = L.layer_norm(x, p["ln"], p["ln_b"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h)
    out = L.masked_attention(q, k, v, causal=False)
    B, S = x.shape[0], x.shape[1]
    return x + out.reshape(B, S, cfg.q_dim) @ p["wo"]


def _cross_attn(ctx: Ctx, p, x, enc_kv):
    """Decoder cross-attention. enc_kv: (k, v) each (B, F, H, Dh)."""
    cfg = ctx.cfg
    h = L.layer_norm(x, p["ln"], p["ln_b"], cfg.norm_eps)
    B, S = h.shape[0], h.shape[1]
    q = (h @ p["wq"]) + p["bq"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k, v = enc_kv
    out = L.masked_attention(q, k, v, causal=False)
    return x + out.reshape(B, S, cfg.q_dim) @ p["wo"]


def _cross_kv(cfg: ModelConfig, p, enc_out):
    B, F = enc_out.shape[0], enc_out.shape[1]
    k = ((enc_out @ p["wk"]) + p["bk"]).reshape(B, F, cfg.n_kv_heads,
                                                cfg.head_dim)
    v = ((enc_out @ p["wv"]) + p["bv"]).reshape(B, F, cfg.n_kv_heads,
                                                cfg.head_dim)
    return k, v


def encode(ctx: Ctx, params, frames):
    """frames: (B, F, D) stub embeddings -> encoder output (B, F, D)."""
    cfg = ctx.cfg
    F = frames.shape[1]
    x = frames + L.sinusoidal_positions(F, cfg.d_model, frames.dtype)[None]
    x = constrain(x, ctx.rules, ("batch", None, None))

    def body(carry, blk):
        y = _enc_attn(ctx, blk["attn"], carry)
        y = mlp_apply(ctx, blk["mlp"], y)
        return y, None

    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_ln"], params["enc_ln_b"], cfg.norm_eps)


def decoder_embed(ctx: Ctx, params, tokens, positions, compute_dtype):
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = jnp.take(params["pos_dec"], positions, axis=0)
    return (x + pos).astype(compute_dtype)


def run_decoder_train(ctx: Ctx, params, x, enc_out):
    """Training/prefill pass over decoder blocks. Returns (x, caches)."""
    collect = ctx.mode == "prefill"

    def body(carry, blk):
        y, self_cache = attn_apply(ctx, blk["self"], carry)
        ekv = _cross_kv(ctx.cfg, blk["cross"], enc_out)
        y = _cross_attn(ctx, blk["cross"], y, ekv)
        y = mlp_apply(ctx, blk["mlp"], y)
        out = None
        if collect:
            out = {"self": self_cache, "cross_k": ekv[0], "cross_v": ekv[1]}
        return y, out

    if not collect:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    return x, caches


def run_decoder_decode(ctx: Ctx, params, x, cache):
    """One-token decode. cache: stacked per-layer {self:{k,v}, cross_k/v}."""
    def body(carry, blk_and_cache):
        blk, c = blk_and_cache
        y, new_self = attn_apply(ctx, blk["self"], carry, cache=c["self"])
        B = y.shape[0]
        kv_len = jnp.full((B,), c["cross_k"].shape[1], jnp.int32)
        y, _ = attn_apply(ctx, blk["cross"], y,
                          kv_override=(c["cross_k"], c["cross_v"], kv_len))
        y = mlp_apply(ctx, blk["mlp"], y)
        return y, {"self": new_self, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    return x, new_cache
