"""Dense decoder-only transformer (qwen2-*, minitron, granite, internvl2 LM).

Sharding recipes (decided per-arch at build time, see ``recipe_for``):

* ``tp``  — Megatron-style tensor parallel with sequence-parallel residual:
  the scan carry (residual stream) is sharded ("batch", "seq"->model); inside
  a block the hidden is gathered over model (GSPMD all-gather), attention
  runs with q/k/v heads sharded over model (KV expanded to Hq heads first so
  every shard is fully local), and the output projections are reduce-scattered
  back to the seq-sharded residual. Requires n_heads % tp == 0.
* ``cp``  — context parallel for archs whose head counts don't divide the
  model axis (minitron 24H, qwen2-7b 28H, internvl2 14H, whisper 8H): the
  residual stays seq-sharded, attention runs under shard_map with KV
  all-gathered over the model axis, and weights are ZeRO-3-gathered by GSPMD.

Both recipes keep parameters sharded identically (embed dim -> data/FSDP,
heads/mlp/vocab dims -> model), so checkpoints are recipe-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import layers as L
from repro.sharding.partition import Rules, constrain


# --------------------------------------------------------------------------
# Context threaded through block application
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    parallel: ParallelConfig
    rules: Rules                      # activation rules
    mesh: Any                         # jax Mesh or None
    mode: str                         # train | prefill | decode
    positions: Any = None             # (B, S) int32 or (B,) for decode
    recipe: str = "tp"                # tp | cp
    q_block: int = 512
    kv_block: int = 1024

    @property
    def model_axis_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.parallel.model_axis, 1)

    def batch_axes(self) -> Tuple[str, ...]:
        axes = []
        if self.mesh is not None:
            if self.parallel.pod_axis and self.parallel.pod_axis in self.mesh.shape:
                axes.append(self.parallel.pod_axis)
            if self.parallel.data_axis in self.mesh.shape:
                axes.append(self.parallel.data_axis)
        return tuple(axes)


def recipe_for(cfg: ModelConfig, tp_size: int) -> str:
    if cfg.n_heads and cfg.n_heads % max(tp_size, 1) == 0:
        return "tp"
    return "cp"


def _sp_in_project(ctx: "Ctx", x, ws):
    """Fused Megatron-SP input projection: all-gather the seq-sharded
    residual and apply K output-dim-sharded weights in ONE shard_map, so the
    backward x-grad is a single psum_scatter instead of GSPMD's grouped
    all-reduce of full activations. x: (B, S/n, D); ws: list of (D, K_i)
    sharded on K_i. Returns [(B, S, K_i/n) heads-sharded]."""
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    if ctx.mesh is None or n == 1 or x.shape[1] % n != 0:
        return [x @ w for w in ws]
    baxes = ctx.batch_axes()
    bspec = baxes if baxes else None

    def local(xl, *wl):
        h = jax.lax.all_gather(xl, model_axis, axis=1, tiled=True)
        return tuple(h @ w for w in wl)

    outs = shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(bspec, model_axis, None),)
        + tuple(P(None, model_axis) for _ in ws),
        out_specs=tuple(P(bspec, None, model_axis) for _ in ws),
        check_rep=False)(x, *ws)
    return list(outs)


def _rs_project(ctx: "Ctx", h, w):
    """Megatron-SP output projection: local partial matmul + psum_scatter
    over the sequence dim (half the bytes of GSPMD's all-reduce and lands
    directly in the seq-sharded residual layout). h: (B, S, K) with K
    sharded over model; w: (K, D) sharded on K. Returns (B, S, D) with S
    sharded over model."""
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    if ctx.mesh is None or n == 1 or h.shape[1] % n != 0:
        return h @ w
    baxes = ctx.batch_axes()
    bspec = baxes if baxes else None

    def local(h_loc, w_loc):
        part = h_loc @ w_loc
        return jax.lax.psum_scatter(part, model_axis, scatter_dimension=1,
                                    tiled=True)

    return shard_map(local, mesh=ctx.mesh,
                     in_specs=(P(bspec, None, model_axis),
                               P(model_axis, None)),
                     out_specs=P(bspec, model_axis, None),
                     check_rep=False)(h, w)


# --------------------------------------------------------------------------
# Dense attention block
# --------------------------------------------------------------------------
def attn_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, L.ParamDef]:
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    d = {
        "ln": L.ParamDef((D,), ("embed",), "ones"),
        "wq": L.ParamDef((D, Q), ("embed", "heads")),
        "wk": L.ParamDef((D, KV), ("embed", "kv")),
        "wv": L.ParamDef((D, KV), ("embed", "kv")),
        "wo": L.ParamDef((Q, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = L.ParamDef((Q,), ("heads",), "zeros")
        d["bk"] = L.ParamDef((KV,), ("kv",), "zeros")
        d["bv"] = L.ParamDef((KV,), ("kv",), "zeros")
    if cfg.norm_style() == "layernorm":
        d["ln_b"] = L.ParamDef((D,), ("embed",), "zeros")
    return d


def mlp_defs(cfg: ModelConfig) -> Dict[str, L.ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    d = {"ln": L.ParamDef((D,), ("embed",), "ones")}
    if cfg.act == "swiglu":
        d["wg"] = L.ParamDef((D, F), ("embed", "mlp"))
        d["wu"] = L.ParamDef((D, F), ("embed", "mlp"))
        d["wd"] = L.ParamDef((F, D), ("mlp", "embed"))
    else:
        d["wi"] = L.ParamDef((D, F), ("embed", "mlp"))
        d["wo_mlp"] = L.ParamDef((F, D), ("mlp", "embed"))
        d["bi"] = L.ParamDef((F,), ("mlp",), "zeros")
        d["bo"] = L.ParamDef((D,), ("embed",), "zeros")
    if cfg.norm_style() == "layernorm":
        d["ln_b"] = L.ParamDef((D,), ("embed",), "zeros")
    return d


def dense_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"attn": attn_defs(cfg), "mlp": mlp_defs(cfg)}


def _norm(cfg, p, x, prefix=""):
    if cfg.norm_style() == "layernorm":
        return L.layer_norm(x, p["ln"], p["ln_b"], cfg.norm_eps)
    return L.rms_norm(x, p["ln"], cfg.norm_eps)


def _qkv(cfg: ModelConfig, p, h):
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    B, S = h.shape[0], h.shape[1]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _cp_attention(ctx: Ctx, q, k, v, *, causal=True, window=0):
    """Context-parallel attention: q/k/v seq-sharded over the model axis;
    KV all-gathered inside shard_map; causal mask offset by the shard index."""
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    if ctx.mesh is None or n == 1 or q.shape[1] % n != 0:
        return L.attention(q, k, v, causal=causal, window=window,
                           softcap=ctx.cfg.logit_softcap,
                           q_block=ctx.q_block, kv_block=ctx.kv_block)
    baxes = ctx.batch_axes()
    spec = P(baxes if baxes else None, model_axis, None, None)

    def local(qx, kx, vx):
        kf = jax.lax.all_gather(kx, model_axis, axis=1, tiled=True)
        vf = jax.lax.all_gather(vx, model_axis, axis=1, tiled=True)
        s_loc = qx.shape[1]
        offset = jax.lax.axis_index(model_axis) * s_loc
        return L.flash_attention_cp(
            qx, kf, vf, q_offset=offset, causal=causal, window=window,
            softcap=ctx.cfg.logit_softcap,
            q_block=min(ctx.q_block, s_loc), kv_block=ctx.kv_block)

    return shard_map(local, mesh=ctx.mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_rep=False)(q, k, v)


def attn_apply(ctx: Ctx, p, x, cache: Optional[dict] = None,
               kv_override: Optional[Tuple] = None):
    """Self-attention sub-block. Returns (x + attn_out, new_cache_or_None).

    kv_override: (k, v, kv_positions) for cross-attention (whisper decoder).
    """
    cfg = ctx.cfg
    # gather seq -> replicated hidden for projections (tp recipe); in cp mode
    # the residual stays seq-sharded and projections run on local rows.
    h = _norm(cfg, p, x)
    use_sp_fused = (ctx.parallel.explicit_rs and ctx.recipe == "tp"
                    and ctx.mode != "decode")
    if ctx.recipe == "tp" and not use_sp_fused:
        h = constrain(h, ctx.rules, ("batch", None, None))

    if ctx.mode == "decode":
        q = (h @ p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        B = h.shape[0]
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        if kv_override is None:
            knew = (h @ p["wk"])
            vnew = (h @ p["wv"])
            if cfg.qkv_bias:
                knew, vnew = knew + p["bk"], vnew + p["bv"]
            knew = knew.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
            vnew = vnew.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
            pos = ctx.positions  # (B,)
            if cfg.rope_theta > 0:
                q = L.rope(q, pos[:, None], cfg.rope_theta)
                knew = L.rope(knew, pos[:, None], cfg.rope_theta)
            kc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (i, 0, 0)))(cache["k"], knew, pos)
            vc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                c, u, (i, 0, 0)))(cache["v"], vnew, pos)
            kc = constrain(kc, ctx.rules, ("batch", "kv_seq", None, None))
            vc = constrain(vc, ctx.rules, ("batch", "kv_seq", None, None))
            out = L.decode_attention(q, kc, vc, pos, window=cfg.window,
                                     softcap=cfg.logit_softcap)
            new_cache = {"k": kc, "v": vc}
        else:
            kf, vf, kv_len = kv_override
            out = L.decode_attention(
                q, kf, vf, jnp.maximum(kv_len - 1, 0), window=0,
                softcap=cfg.logit_softcap)
            new_cache = None
        attn_out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
        return x + attn_out, new_cache

    # train / prefill
    if use_sp_fused:
        qf, kf, vf = _sp_in_project(ctx, h, [p["wq"], p["wk"], p["wv"]])
        if cfg.qkv_bias:
            qf = qf + p["bq"]
            kf = kf + p["bk"]
            vf = vf + p["bv"]
        # kv heads usually don't divide the model axis: gather kv acts
        # (small) back to replicated; q stays head-sharded.
        kf = constrain(kf, ctx.rules, ("batch", None, None))
        vf = constrain(vf, ctx.rules, ("batch", None, None))
        B, Sg = qf.shape[0], qf.shape[1]
        q = qf.reshape(B, Sg, cfg.n_heads, cfg.head_dim)
        k = kf.reshape(B, Sg, cfg.n_kv_heads, cfg.head_dim)
        v = vf.reshape(B, Sg, cfg.n_kv_heads, cfg.head_dim)
    else:
        q, k, v = _qkv(cfg, p, h)
    if cfg.rope_theta > 0:
        q = L.rope(q, ctx.positions, cfg.rope_theta)
        k = L.rope(k, ctx.positions, cfg.rope_theta)
    new_cache = None
    if ctx.mode == "prefill":
        kc = constrain(k, ctx.rules, ("batch", "kv_seq", None, None))
        vc = constrain(v, ctx.rules, ("batch", "kv_seq", None, None))
        new_cache = {"k": kc, "v": vc}

    causal = True
    if ctx.recipe == "tp":
        # expand KV to Hq heads so each model shard is fully local
        G = cfg.n_heads // cfg.n_kv_heads
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        q = constrain(q, ctx.rules, ("batch", None, "heads", None))
        k = constrain(k, ctx.rules, ("batch", None, "heads", None))
        v = constrain(v, ctx.rules, ("batch", None, "heads", None))
        out = L.attention(q, k, v, causal=causal, window=cfg.window,
                          softcap=cfg.logit_softcap,
                          q_block=ctx.q_block, kv_block=ctx.kv_block)
    else:
        out = _cp_attention(ctx, q, k, v, causal=causal, window=cfg.window)

    B, S = x.shape[0], x.shape[1]
    flat = out.reshape(B, S, cfg.q_dim)
    if ctx.parallel.explicit_rs and ctx.recipe == "tp":
        attn_out = _rs_project(ctx, flat, p["wo"])
    else:
        attn_out = flat @ p["wo"]
    attn_out = constrain(attn_out, ctx.rules, ("batch", "seq", None))
    return x + attn_out, new_cache


def mlp_apply(ctx: Ctx, p, x):
    cfg = ctx.cfg
    h = _norm(cfg, p, x)
    use_rs = (ctx.parallel.explicit_rs and ctx.recipe == "tp"
              and ctx.mode != "decode")
    if ctx.recipe == "tp" and ctx.mode != "decode" and not use_rs:
        h = constrain(h, ctx.rules, ("batch", None, None))
    if cfg.act == "swiglu":
        if use_rs:
            g, u = _sp_in_project(ctx, h, [p["wg"], p["wu"]])
        else:
            g = h @ p["wg"]
            u = h @ p["wu"]
        g = constrain(g, ctx.rules, ("batch", None, "mlp"))
        hidden = L.swiglu(g, u)
        out = _rs_project(ctx, hidden, p["wd"]) if use_rs else hidden @ p["wd"]
    else:
        if use_rs:
            (hi,) = _sp_in_project(ctx, h, [p["wi"]])
        else:
            hi = h @ p["wi"]
        hh = L.gelu(hi + p["bi"])
        out = (_rs_project(ctx, hh, p["wo_mlp"]) if use_rs
               else hh @ p["wo_mlp"]) + p["bo"]
    out = constrain(out, ctx.rules, ("batch", "seq", None))
    return x + out


def dense_block_apply(ctx: Ctx, p, x, cache=None):
    x, new_cache = attn_apply(ctx, p["attn"], x, cache)
    x = mlp_apply(ctx, p["mlp"], x)
    return x, new_cache


# --------------------------------------------------------------------------
# Full LM assembly (shared by dense / moe / ssm / hybrid via block registry)
# --------------------------------------------------------------------------
def lm_defs(cfg: ModelConfig, block_defs_fn) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {
        # untied: input table (vocab->fsdp, embed->model); head (embed->fsdp,
        # vocab->model). tied: single table (vocab->model, embed->fsdp).
        "final_ln": L.ParamDef((D,), ("embed",), "ones"),
    }
    if cfg.tie_embeddings:
        defs["embed"] = L.ParamDef((V, D), ("vocab", "embed"), scale=1.0)
    else:
        defs["embed"] = L.ParamDef((V, D), ("vocab_in", "embed_in"), scale=1.0)
        defs["lm_head"] = L.ParamDef((D, V), ("embed", "vocab"))
    if cfg.norm_style() == "layernorm":
        defs["final_ln_b"] = L.ParamDef((D,), ("embed",), "zeros")
    defs["blocks"] = L.stack_defs(block_defs_fn(cfg), cfg.n_layers)
    return defs


def embed_tokens(cfg: ModelConfig, params, tokens, rules: Rules,
                 compute_dtype=jnp.bfloat16):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x.astype(compute_dtype)
    return constrain(x, rules, ("batch", "seq", None))


def lm_logits(cfg: ModelConfig, params, x, rules: Rules):
    xf = x.astype(jnp.float32)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", xf,
                            params["embed"].astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,dv->bsv", xf,
                            params["lm_head"].astype(jnp.float32))
    return constrain(logits, rules, ("batch", None, "vocab"))


def final_norm(cfg, params, x):
    if cfg.norm_style() == "layernorm":
        return L.layer_norm(x, params["final_ln"], params["final_ln_b"],
                            cfg.norm_eps)
    return L.rms_norm(x, params["final_ln"], cfg.norm_eps)
