"""DAVE-2 CNN (DeepPicar) — the paper's real-time control workload.

5 conv + 3 fc + steering output, 200x66 RGB input (Bojarski et al. 2016,
as used by DeepPicar and RT-Gang §II/§V-C). Pure JAX; used by the Fig.1 and
Fig.6 benchmarks as the RT gang workload on the executor.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.deeppicar import Dave2Config
from repro.models import layers as L


def dave2_defs(cfg: Dave2Config) -> Dict[str, L.ParamDef]:
    defs: Dict[str, L.ParamDef] = {}
    h, w = cfg.input_hw
    c_in = cfg.in_channels
    for i, (c_out, k, s) in enumerate(cfg.conv):
        defs[f"conv{i}_w"] = L.ParamDef((k, k, c_in, c_out),
                                        (None, None, None, None))
        defs[f"conv{i}_b"] = L.ParamDef((c_out,), (None,), "zeros")
        h = (h - k) // s + 1
        w = (w - k) // s + 1
        c_in = c_out
    flat = h * w * c_in
    dims = (flat,) + cfg.fc + (cfg.n_outputs,)
    for i in range(len(dims) - 1):
        defs[f"fc{i}_w"] = L.ParamDef((dims[i], dims[i + 1]), (None, None))
        defs[f"fc{i}_b"] = L.ParamDef((dims[i + 1],), (None,), "zeros")
    return defs


def dave2_apply(cfg: Dave2Config, params, images):
    """images: (B, H, W, 3) -> steering angle (B, 1)."""
    x = images
    for i, (c_out, k, s) in enumerate(cfg.conv):
        x = jax.lax.conv_general_dilated(
            x, params[f"conv{i}_w"], window_strides=(s, s), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jnp.tanh(x + params[f"conv{i}_b"])
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.fc) + 1
    for i in range(n_fc):
        x = x @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_fc - 1:
            x = jnp.tanh(x)
    return x


def make_dave2(cfg: Dave2Config = Dave2Config(), rng=None):
    defs = dave2_defs(cfg)
    rng = rng if rng is not None else jax.random.key(0)
    params = L.init_params(rng, defs, jnp.float32)
    fn = jax.jit(lambda p, x: dave2_apply(cfg, p, x))
    return params, fn
