"""Mixture-of-Experts layer with expert parallelism (kimi-k2, olmoe).

Design (see DESIGN.md §5):
* Experts are sharded over the ``model`` mesh axis (EP): kimi 384/16 = 24,
  olmoe 64/16 = 4 experts per shard. Expert weights are additionally
  FSDP-sharded over ``data``(+``pod``) on the d_model dim; the gather back to
  full d_model happens at the shard_map boundary (GSPMD all-gather).
* Train/prefill ("sp" path): the residual stream is sequence-sharded over
  ``model``; each model rank routes its local tokens and exchanges them with
  the expert-owning ranks via a capacity-bounded ``all_to_all`` (GShard
  style), computes its local experts' GEMMs, and reverses the exchange.
  No dispatch one-hot einsums — routing is sorts/gathers/scatters, so HLO
  FLOPs stay honest (the GShard (T,E,C) dispatch einsum would dwarf the
  expert GEMMs by ~100x in compiled FLOPs).
* Decode ("replicated" path): tokens are replicated over ``model``; each rank
  computes only its local experts' contributions and psums. For the tiny
  per-step token counts of decoding this costs one small all-reduce.

The per-expert batched GEMM is the Pallas ``moe_gmm`` kernel's target shape;
the XLA path uses a plain batched einsum over the capacity buffer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import Ctx, attn_defs, attn_apply, _norm
from repro.sharding.partition import constrain


def moe_mlp_defs(cfg: ModelConfig,
                 weight_stationary: bool = False) -> Dict[str, L.ParamDef]:
    assert cfg.moe is not None
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    # weight-stationary (inference): shard the per-expert d_ff over `data`
    # ("expert_mlp" rule) instead of FSDP-sharding d_model — weights never
    # move; activations (tiny at decode) do.
    d_lg = None if weight_stationary else "embed"
    f_lg = "expert_mlp" if weight_stationary else None
    return {
        "ln": L.ParamDef((D,), ("embed",), "ones"),
        "router": L.ParamDef((D, E), (None, None)),
        "wg": L.ParamDef((E, D, F), ("experts", d_lg, f_lg)),
        "wu": L.ParamDef((E, D, F), ("experts", d_lg, f_lg)),
        "wd": L.ParamDef((E, F, D), ("experts", f_lg, d_lg)),
    }


def moe_block_defs(cfg: ModelConfig, weight_stationary: bool = False
                   ) -> Dict[str, Any]:
    return {"attn": attn_defs(cfg),
            "moe": moe_mlp_defs(cfg, weight_stationary)}


# --------------------------------------------------------------------------
# Routing helpers (local, static shapes)
# --------------------------------------------------------------------------
def _topk_route(x, w_router, top_k: int):
    """x: (T, D) -> (weights (T,k) f32, experts (T,k) i32, probs (T,E) f32)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, tope.astype(jnp.int32), probs


def _positions_in_expert(flat_e, n_experts: int):
    """Rank of each (token,k) pair within its expert (by stable sort)."""
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    ranks_sorted = jnp.arange(tk, dtype=jnp.int32) - run_start.astype(jnp.int32)
    ranks = jnp.zeros((tk,), jnp.int32).at[order].set(ranks_sorted)
    return ranks


def aux_losses(probs, tope, n_experts: int) -> Dict[str, jnp.ndarray]:
    """Switch-style load-balancing loss + router z-loss (local shard values)."""
    T = probs.shape[0]
    k = tope.shape[-1]
    counts = jnp.zeros((n_experts,), jnp.float32).at[tope.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(T * k, 1)
    frac_probs = probs.mean(axis=0)
    lb = n_experts * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(
        jnp.log(jnp.maximum(probs, 1e-20)), axis=-1)))
    return {"load_balance": lb, "router_z": z}


def _expert_ffn(wg, wu, wd, xs):
    """xs: (E_loc, C, D) -> (E_loc, C, D); SwiGLU per expert (gmm target)."""
    g = jnp.einsum("ecd,edf->ecf", xs, wg)
    u = jnp.einsum("ecd,edf->ecf", xs, wu)
    h = L.swiglu(g, u)
    return jnp.einsum("ecf,efd->ecd", h, wd)


# --------------------------------------------------------------------------
# SP + all-to-all path (train / prefill)
# --------------------------------------------------------------------------
def _moe_local_a2a(cfg: ModelConfig, model_axis: str, n_ranks: int,
                   x_loc, w_router, wg, wu, wd):
    """Per-device body under shard_map. x_loc: (B_loc, S_loc, D)."""
    moe = cfg.moe
    E = moe.n_experts
    e_loc = E // n_ranks
    B, S, D = x_loc.shape
    T = B * S
    xt = x_loc.reshape(T, D)

    topw, tope, probs = _topk_route(xt, w_router, moe.top_k)
    aux = aux_losses(probs, tope, E)

    flat_e = tope.reshape(-1)                     # (T*k,)
    flat_w = topw.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), moe.top_k)
    ranks = _positions_in_expert(flat_e, E)

    cap = int(np.ceil(T * moe.top_k / E * moe.capacity_factor))
    cap = max(8, int(np.ceil(cap / 8) * 8))       # pad for lane alignment
    valid = ranks < cap
    slot = flat_e * cap + jnp.where(valid, ranks, 0)

    # dispatch into (E, cap, D) send buffer
    src = jnp.where(valid[:, None], xt[flat_t], 0).astype(xt.dtype)
    buf = jnp.zeros((E * cap, D), xt.dtype).at[slot].add(
        jnp.where(valid[:, None], src, 0))
    buf = buf.reshape(n_ranks, e_loc * cap, D)

    # exchange: axis0 becomes source-rank after all_to_all
    recv = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(n_ranks, e_loc, cap, D).transpose(1, 0, 2, 3)
    recv = recv.reshape(e_loc, n_ranks * cap, D)

    out = _expert_ffn(wg, wu, wd, recv)

    out = out.reshape(e_loc, n_ranks, cap, D).transpose(1, 0, 2, 3)
    out = out.reshape(n_ranks, e_loc * cap, D)
    back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(E * cap, D)

    # combine: weighted sum of each token's surviving expert outputs
    gathered = back[slot] * jnp.where(valid, flat_w, 0.0)[:, None].astype(
        back.dtype)
    y = jnp.zeros((T, D), back.dtype).at[flat_t].add(gathered)
    return y.reshape(B, S, D), aux["load_balance"], aux["router_z"]


def _moe_sp(ctx: Ctx, p, x):
    """x: (B, S, D) with batch->data(+pod), S->model (SP residual)."""
    cfg = ctx.cfg
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    if ctx.mesh is None or n == 1:
        y, lb, rz = _moe_dense_fallback(cfg, p, x)
        return y, {"load_balance": lb, "router_z": rz}
    baxes = ctx.batch_axes()
    bspec = baxes if baxes else None
    x_spec = P(bspec, model_axis, None)
    w_full = P(None, None)
    e_spec = P(model_axis, None, None)

    def body(x_loc, w_router, wg, wu, wd):
        return _moe_local_a2a(cfg, model_axis, n, x_loc, w_router, wg, wu, wd)

    y, lb, rz = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(x_spec, w_full, e_spec, e_spec, e_spec),
        out_specs=(x_spec, P(), P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, {"load_balance": lb, "router_z": rz}


# --------------------------------------------------------------------------
# Replicated-token path (decode; also single-device fallback)
# --------------------------------------------------------------------------
def _moe_dense_fallback(cfg: ModelConfig, p, x):
    """No-mesh reference: every expert computed locally via capacity buffer."""
    moe = cfg.moe
    E = moe.n_experts
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    topw, tope, probs = _topk_route(xt, p["router"], moe.top_k)
    aux = aux_losses(probs, tope, E)
    flat_e = tope.reshape(-1)
    flat_w = topw.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), moe.top_k)
    ranks = _positions_in_expert(flat_e, E)
    cap = int(np.ceil(T * moe.top_k / E * moe.capacity_factor))
    cap = max(8, int(np.ceil(cap / 8) * 8))
    valid = ranks < cap
    slot = flat_e * cap + jnp.where(valid, ranks, 0)
    buf = jnp.zeros((E * cap, D), xt.dtype).at[slot].add(
        jnp.where(valid[:, None], xt[flat_t], 0))
    out = _expert_ffn(p["wg"], p["wu"], p["wd"], buf.reshape(E, cap, D))
    out = out.reshape(E * cap, D)
    gathered = out[slot] * jnp.where(valid, flat_w, 0.0)[:, None].astype(out.dtype)
    y = jnp.zeros((T, D), out.dtype).at[flat_t].add(gathered)
    return y.reshape(B, S, D), aux["load_balance"], aux["router_z"]


def _moe_replicated(ctx: Ctx, p, x):
    """Decode path: x replicated over model; each rank computes local experts
    and psums. x: (B, S=1, D)."""
    cfg = ctx.cfg
    moe = cfg.moe
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    if ctx.mesh is None or n == 1:
        y, lb, rz = _moe_dense_fallback(cfg, p, x)
        return y, {"load_balance": lb, "router_z": rz}
    E = moe.n_experts
    e_loc = E // n
    baxes = ctx.batch_axes()
    bspec = baxes if baxes else None
    x_spec = P(bspec, None, None)
    e_spec = P(model_axis, None, None)

    cap_mult = ctx.parallel.moe_decode_cap_mult

    def body(x_loc, w_router, wg, wu, wd):
        B, S, D = x_loc.shape
        T = B * S
        xt = x_loc.reshape(T, D)
        topw, tope, probs = _topk_route(xt, w_router, moe.top_k)
        my0 = jax.lax.axis_index(model_axis) * e_loc
        local_e = tope - my0                      # (T,k) in [0, e_loc) if mine
        mine = (local_e >= 0) & (local_e < e_loc)
        flat_e = jnp.where(mine, local_e, 0).reshape(-1)
        flat_w = jnp.where(mine, topw, 0.0).reshape(-1)
        flat_m = mine.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), moe.top_k)
        ranks = _positions_in_expert(
            jnp.where(flat_m, flat_e, e_loc), e_loc + 1)
        if cap_mult == 4.0:   # baseline formula (recorded in the sweep)
            cap = int(np.ceil(T * moe.top_k / max(E, 1) * 4)) + 8
            cap = int(np.ceil(cap / 8) * 8)
        else:                 # hillclimb: tight capacity, 8-lane aligned
            cap = max(8, int(np.ceil(
                np.ceil(T * moe.top_k / max(E, 1) * cap_mult) / 8) * 8))
        valid = flat_m & (ranks < cap)
        slot = flat_e * cap + jnp.where(valid, ranks, 0)
        buf = jnp.zeros((e_loc * cap, D), xt.dtype).at[slot].add(
            jnp.where(valid[:, None], xt[flat_t], 0))
        out = _expert_ffn(wg, wu, wd, buf.reshape(e_loc, cap, D))
        out = out.reshape(e_loc * cap, D)
        gathered = out[slot] * jnp.where(valid, flat_w, 0.0)[:, None].astype(
            out.dtype)
        y = jnp.zeros((T, D), out.dtype).at[flat_t].add(gathered)
        y = jax.lax.psum(y, model_axis)
        aux = aux_losses(probs, tope, E)
        return (y.reshape(B, S, D), aux["load_balance"], aux["router_z"])

    y, lb, rz = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(x_spec, P(None, None), e_spec, e_spec, e_spec),
        out_specs=(x_spec, P(), P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, {"load_balance": lb, "router_z": rz}


def _moe_weight_stationary(ctx: Ctx, p, x):
    """Decode MoE without moving weights: expert d_ff sharded over `data`,
    experts over `model`; the (tiny) decode activations are all-gathered over
    the batch axes, every device computes its expert x d_ff-slice partials,
    and one small psum over (data+model) combines. Weight traffic per step:
    zero collectives (weights stay resident)."""
    cfg = ctx.cfg
    moe = cfg.moe
    model_axis = ctx.parallel.model_axis
    n = ctx.model_axis_size
    baxes = ctx.batch_axes()
    if ctx.mesh is None or n == 1 or not baxes:
        y, lb, rz = _moe_dense_fallback(cfg, p, x)
        return y, {"load_balance": lb, "router_z": rz}
    E = moe.n_experts
    e_loc = E // n
    x_spec = P(baxes, None, None)
    wg_spec = P(model_axis, None, baxes)
    wd_spec = P(model_axis, baxes, None)
    cap_mult = ctx.parallel.moe_decode_cap_mult

    def body(x_loc, w_router, wg, wu, wd):
        B_loc, S, D = x_loc.shape
        x_all = jax.lax.all_gather(x_loc, baxes, axis=0, tiled=True)
        B = x_all.shape[0]
        T = B * S
        xt = x_all.reshape(T, D)
        topw, tope, probs = _topk_route(xt, w_router, moe.top_k)
        my0 = jax.lax.axis_index(model_axis) * e_loc
        local_e = tope - my0
        mine = (local_e >= 0) & (local_e < e_loc)
        flat_e = jnp.where(mine, local_e, 0).reshape(-1)
        flat_w = jnp.where(mine, topw, 0.0).reshape(-1)
        flat_m = mine.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), moe.top_k)
        ranks = _positions_in_expert(
            jnp.where(flat_m, flat_e, e_loc), e_loc + 1)
        cap = max(8, int(np.ceil(
            np.ceil(T * moe.top_k / max(E, 1) * cap_mult) / 8) * 8))
        valid = flat_m & (ranks < cap)
        slot = flat_e * cap + jnp.where(valid, ranks, 0)
        buf = jnp.zeros((e_loc * cap, D), xt.dtype).at[slot].add(
            jnp.where(valid[:, None], xt[flat_t], 0))
        out = _expert_ffn(wg, wu, wd, buf.reshape(e_loc, cap, D))
        out = out.reshape(e_loc * cap, D)
        gathered = out[slot] * jnp.where(valid, flat_w, 0.0)[:, None].astype(
            out.dtype)
        y = jnp.zeros((T, D), out.dtype).at[flat_t].add(gathered)
        y = jax.lax.psum(y, (model_axis,) + baxes)
        aux = aux_losses(probs, tope, E)
        # return only this data-rank's batch slice
        d_idx = jax.lax.axis_index(baxes[-1])
        if len(baxes) > 1:
            d_idx = jax.lax.axis_index(baxes[0]) * ctx.mesh.shape[baxes[-1]] \
                + d_idx
        y = jax.lax.dynamic_slice_in_dim(y.reshape(B, S, D),
                                         d_idx * B_loc, B_loc, axis=0)
        return y, aux["load_balance"], aux["router_z"]

    y, lb, rz = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(x_spec, P(None, None), wg_spec, wg_spec, wd_spec),
        out_specs=(x_spec, P(), P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, {"load_balance": lb, "router_z": rz}


def moe_mlp_apply(ctx: Ctx, p, x) -> Tuple[jnp.ndarray, Dict]:
    h = _norm(ctx.cfg, p, x)
    if ctx.mode == "decode":
        if ctx.parallel.moe_weight_stationary:
            y, aux = _moe_weight_stationary(ctx, p, h)
        else:
            y, aux = _moe_replicated(ctx, p, h)
    else:
        h = constrain(h, ctx.rules, ("batch", "seq", None))
        y, aux = _moe_sp(ctx, p, h)
    y = constrain(y, ctx.rules, ("batch", "seq", None))
    return x + y, aux


def moe_block_apply(ctx: Ctx, p, x, cache=None):
    x, new_cache = attn_apply(ctx, p["attn"], x, cache)
    x, aux = moe_mlp_apply(ctx, p["moe"], x)
    return x, new_cache, aux
