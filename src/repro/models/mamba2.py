"""Mamba2 / SSD (state-space duality) blocks. [arXiv:2405.21060]

Per head h (H = d_inner/P heads, state size N):
    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t (x) x_t        (N x P outer)
    y_t = C_t . h_t + D * x_t
with scalar A<0 per head, B_t/C_t shared across heads (n_groups=1), gated
RMSNorm on the output and a causal depthwise conv on (x, B, C) inputs.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk state scan) — the same decomposition the ``ssd_scan`` Pallas
kernel implements on TPU. Decode is the O(1) recurrent update.

TP sharding: d_inner and heads over ``model``; B/C (state dim) replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.partition import constrain


def ssm_block_defs(cfg: ModelConfig) -> Dict[str, L.ParamDef]:
    assert cfg.ssm is not None
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.n_heads(D)
    N, W = s.state_dim, s.conv_width
    return {
        "ln": L.ParamDef((D,), ("embed",), "ones"),
        "in_x": L.ParamDef((D, di), ("embed", "ssm_inner")),
        "in_z": L.ParamDef((D, di), ("embed", "ssm_inner")),
        "in_B": L.ParamDef((D, N), ("embed", None)),
        "in_C": L.ParamDef((D, N), ("embed", None)),
        "in_dt": L.ParamDef((D, H), ("embed", "ssm_heads")),
        "conv_x": L.ParamDef((W, di), (None, "ssm_inner"), "normal", 0.5),
        "conv_B": L.ParamDef((W, N), (None, None), "normal", 0.5),
        "conv_C": L.ParamDef((W, N), (None, None), "normal", 0.5),
        "dt_bias": L.ParamDef((H,), ("ssm_heads",), "zeros"),
        "A_log": L.ParamDef((H,), ("ssm_heads",), "zeros"),
        "D_skip": L.ParamDef((H,), ("ssm_heads",), "ones"),
        "gn": L.ParamDef((di,), ("ssm_inner",), "ones"),
        "out": L.ParamDef((di, D), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C). state: (B,W-1,C) tail of
    previous tokens (decode). Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return y, new_state


def _ssd_chunked(xh, dt, Bm, Cm, A, h0=None, chunk=256):
    """Chunked SSD scan.

    xh: (B,S,H,P); dt: (B,S,H) (post-softplus); Bm, Cm: (B,S,N); A: (H,) < 0.
    h0: optional (B,H,P,N) initial state.
    Returns y: (B,S,H,P), h_final: (B,H,P,N). All math fp32.
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt=0 tokens: decay exp(0)=1 and zero input contribution,
        # so state and earlier outputs are unaffected.
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32
    xh = xh.astype(f32)
    dt = dt.astype(f32)
    Bm = Bm.astype(f32)
    Cm = Cm.astype(f32)

    xb = xh.reshape(Bsz, nc, Q, H, P)
    db = dt.reshape(Bsz, nc, Q, H)
    Bb = Bm.reshape(Bsz, nc, Q, N)
    Cb = Cm.reshape(Bsz, nc, Q, N)

    # log-decay within chunk: L[t] = sum_{u<=t} A*dt_u   (B,nc,Q,H)
    logd = db * A[None, None, None, :]
    Lc = jnp.cumsum(logd, axis=2)
    Ltot = Lc[:, :, -1, :]                       # (B,nc,H)

    # intra-chunk quadratic form
    CB = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)   # (B,nc,Q,Q)
    # decay(i,j) = exp(L_i - L_j) for j<=i
    diff = Lc[:, :, :, None, :] - Lc[:, :, None, :, :]      # (B,nc,Q,Q,H)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    M = jnp.where(causal, jnp.exp(diff), 0.0)
    M = M * CB[..., None] * db[:, :, None, :, :]            # j-index dt
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xb)

    # per-chunk end-state contribution: sum_j exp(Ltot - L_j) dt_j B_j x_j
    decay_end = jnp.exp(Ltot[:, :, None, :] - Lc)           # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                         decay_end * db, Bb, xb)            # (B,nc,H,P,N)

    # inter-chunk scan
    h_init = (jnp.zeros((Bsz, H, P, N), f32) if h0 is None
              else h0.astype(f32))

    def step(h, inp):
        s_c, ltot, c_blk, l_blk = inp
        # y_inter[i] = C_i . (exp(L_i) * h)
        y_in = jnp.einsum("bqn,bqh,bhpn->bqhp", c_blk, jnp.exp(l_blk), h)
        h_new = jnp.exp(ltot)[:, :, None, None] * h + s_c
        return h_new, y_in

    xs = (S_chunk.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2),
          Cb.transpose(1, 0, 2, 3), Lc.transpose(1, 0, 2, 3))
    h_final, y_inter = jax.lax.scan(step, h_init, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)              # (B,nc,Q,H,P)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y[:, :S_orig], h_final


def ssm_block_apply(ctx, p, x, cache: Optional[dict] = None):
    """x: (B,S,D). Returns (x_out, new_cache)."""
    cfg = ctx.cfg
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.n_heads(D)
    N, P, W = s.state_dim, s.head_dim, s.conv_width
    Bsz, S, _ = x.shape

    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.recipe == "tp" and ctx.mode != "decode":
        h = constrain(h, ctx.rules, ("batch", None, None))

    z = h @ p["in_z"]
    xin = h @ p["in_x"]
    Bm = h @ p["in_B"]
    Cm = h @ p["in_C"]
    dt = h @ p["in_dt"]

    conv_cache = cache if cache is not None else {}
    xin, cx = _causal_conv(xin, p["conv_x"], conv_cache.get("conv_x"))
    Bm, cB = _causal_conv(Bm, p["conv_B"], conv_cache.get("conv_B"))
    Cm, cC = _causal_conv(Cm, p["conv_C"], conv_cache.get("conv_C"))
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(xin.dtype)
    Bm = jax.nn.silu(Bm.astype(jnp.float32)).astype(Bm.dtype)
    Cm = jax.nn.silu(Cm.astype(jnp.float32)).astype(Cm.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xin.reshape(Bsz, S, H, P)
    xh = constrain(xh, ctx.rules, ("batch", None, "ssm_heads", None))

    if ctx.mode == "decode":
        assert cache is not None and S == 1
        h0 = cache["h"].astype(jnp.float32)                 # (B,H,P,N)
        da = jnp.exp(dt[:, 0] * A[None, :])                 # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0],
                         xh[:, 0].astype(jnp.float32))
        h_new = da[:, :, None, None] * h0 + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h_new)[:, None]
        new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC,
                     "h": h_new}
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_fin = _ssd_chunked(xh, dt, Bm, Cm, A, h0=h0, chunk=s.chunk)
        y = y.reshape(Bsz, S, H, P)
        new_cache = None
        if ctx.mode == "prefill":
            new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC, "h": h_fin}

    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32)
    y = y.reshape(Bsz, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["gn"], cfg.norm_eps)
    out = y @ p["out"]
    out = constrain(out, ctx.rules, ("batch", "seq", None))
    return x + out, new_cache
