"""RTA-margin accounting (DESIGN.md §12.3).

Soundness as a *measured* property: every completed job's response time
is compared against its policy's analytic bound (vgang RTA,
RTG-throttle duty-cycle bound, reclaim pricing, enforced-equivalent
WCET — whichever priced the run), the slack ``bound - response`` is
observed into a per-task histogram, and a worst-observed-margin summary
flows into ``SimResult.rta_margins``, the vgang grid rows and the three
BENCH JSON files. A negative margin is an analysis-soundness violation
caught at observation time, not rediscovered at the next grid run.

Quantum-engine callers add their O(dt) discretization slop to the
bounds *before* handing them in (a completion is stamped at the end of
the quantum that drained it, up to one dt late); the event engine's
exact responses take the bounds as-is.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

# slack-histogram buckets (ms of margin; one negative bucket so a
# soundness violation is visible in the distribution, not only in min)
MARGIN_BOUNDS = (-1e-9, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                 500.0)


def margin_summary(response_times: Dict[str, List[float]],
                   bounds: Dict[str, float],
                   metrics: Optional[MetricsRegistry] = None,
                   eps: float = 1e-9) -> Dict[str, Dict]:
    """Per-task margin summary for every task with a declared bound.

    Returns ``{task: {bound, jobs, worst_margin, mean_margin,
    negative}}`` where margin = bound - measured response (ms).
    ``negative`` counts responses beyond the bound by more than
    ``eps``. Tasks with a bound but no completions report
    ``jobs=0`` with null margins (not an error: a horizon shorter than
    one period is legitimate). When ``metrics`` is given, each margin
    is also observed into the ``rta.margin{gang=...}`` histogram and
    the worst margin into the ``rta.worst_margin{gang=...}`` gauge."""
    out: Dict[str, Dict] = {}
    for name in sorted(bounds):
        bound = bounds[name]
        rs = response_times.get(name) or []
        margins = [bound - r for r in rs]
        hist = None
        if metrics is not None and metrics.enabled:
            hist = metrics.histogram("rta.margin", bounds=MARGIN_BOUNDS,
                                     gang=name)
            for m in margins:
                hist.observe(m)
        worst = min(margins) if margins else None
        if metrics is not None and metrics.enabled and worst is not None:
            g = metrics.gauge("rta.worst_margin", gang=name)
            if g.value == 0.0 or worst < g.value:
                g.set(worst)
        out[name] = {
            "bound": bound,
            "jobs": len(margins),
            "worst_margin": worst,
            "mean_margin": (sum(margins) / len(margins)) if margins
            else None,
            "negative": sum(1 for m in margins if m < -eps),
        }
    return out


def merge_margins(into: Dict[str, Dict],
                  add: Dict[str, Dict]) -> Dict[str, Dict]:
    """Aggregate per-task summaries across runs (the grid merges every
    sim-checked taskset's margins into one per-cell record). Tasks are
    pooled: the merged record keys stay per-task-name, with job counts
    summed and worst margins min-ed."""
    for name, rec in add.items():
        cur = into.get(name)
        if cur is None:
            into[name] = dict(rec)
            continue
        jobs = cur["jobs"] + rec["jobs"]
        worsts = [w for w in (cur["worst_margin"], rec["worst_margin"])
                  if w is not None]
        means = [(cur["mean_margin"], cur["jobs"]),
                 (rec["mean_margin"], rec["jobs"])]
        tot = sum(m * n for m, n in means if m is not None)
        cur.update({
            "jobs": jobs,
            "worst_margin": min(worsts) if worsts else None,
            "mean_margin": (tot / jobs) if jobs else None,
            "negative": cur["negative"] + rec["negative"],
        })
    return into


def overall(summaries: Dict[str, Dict]) -> Dict:
    """Roll one margin-summary dict up to a single record (the BENCH
    files carry both the per-task table and this headline)."""
    worsts = [r["worst_margin"] for r in summaries.values()
              if r["worst_margin"] is not None]
    return {"tasks": len(summaries),
            "jobs": sum(r["jobs"] for r in summaries.values()),
            "worst_margin": min(worsts) if worsts else None,
            "negative": sum(r["negative"] for r in summaries.values())}
