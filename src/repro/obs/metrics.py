"""Metrics registry: labeled counter/gauge/histogram series (DESIGN.md §12.1).

The instruments are deliberately slotted objects with plain attribute
arithmetic — the engines pre-resolve them once (at construction or run
start) and the hot paths do ``c.value += 1``, so instrumenting the event
loop costs about what the old ad-hoc ``self.x += 1`` fields cost
(bench_sim.py's ``obs_overhead`` entries measure exactly this and
assert < 5% on the 16-core workload).

Series naming: ``name`` plus sorted ``key=value`` labels, rendered as
``name{k=v,k2=v2}`` in snapshots (``name`` alone when unlabeled).
``counter(...)`` is get-or-create: two components asking for the same
(name, labels) share one Counter object — that is how the engines and
the FaultManager co-own ``task.misses{gang=...}`` without double
bookkeeping.

Parity contract: instruments created with ``parity=True`` must be
integers that both simulator engines reproduce *exactly* (lock
acquisitions, preemptions, IPIs, per-core throttle trips, per-task
releases/completions/misses, fault counts). ``parity_snapshot()``
returns only those; tests/test_obs.py asserts byte-identical snapshots
across engines on the fig4/fig5 workloads. Float accumulations
(total traffic, slack, BE progress) carry O(dt) discretization bias by
design and are excluded.

``MetricsRegistry(enabled=False)`` is the bare mode: instruments are
handed out (the callers' accounting still works — several counters
back compatibility properties like ``GLock.acquisitions``) but nothing
is indexed, so there are no snapshots and no per-series dict churn.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class Counter:
    """Monotonic counter. Hot paths may use ``c.value += n`` directly."""
    __slots__ = ("value",)

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.value})"


class Gauge:
    """Last-written value, plus a ``peak``-style max helper."""
    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v

    def update_max(self, v: float) -> None:
        if v > self.value:
            self.value = v

    def __repr__(self) -> str:
        return f"Gauge({self.value})"


# default bucket upper bounds for margin/latency histograms (ms)
DEFAULT_BOUNDS = (0.0, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                  500.0)


class Histogram:
    """Fixed-bound histogram with count/total/min/max summary stats.
    ``bounds`` are bucket upper edges; one overflow bucket is implied."""
    __slots__ = ("bounds", "buckets", "count", "total", "min", "max")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BOUNDS):
        self.bounds = tuple(bounds)
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        i = 0
        for i, b in enumerate(self.bounds):
            if v <= b:
                break
        else:
            i = len(self.bounds)
        self.buckets[i] += 1
        self.count += 1
        self.total += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v

    def summary(self) -> Dict:
        return {"count": self.count,
                "mean": (self.total / self.count) if self.count else None,
                "min": self.min, "max": self.max,
                "buckets": dict(zip([*map(str, self.bounds), "+inf"],
                                    self.buckets))}

    def __repr__(self) -> str:
        return f"Histogram(n={self.count}, min={self.min}, max={self.max})"


def series_key(name: str, labels: Dict[str, object]) -> str:
    """Canonical series key: ``name{k=v,...}`` with sorted label keys."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create store of labeled metric series.

    ``common_labels`` are folded into every series (e.g. the vgang grid
    stamps ``policy=rtgT`` on a per-cell registry). ``enabled=False``
    hands out detached instruments and indexes nothing — the bare mode
    the instrumentation-overhead benchmark compares against."""

    def __init__(self, enabled: bool = True,
                 common_labels: Optional[Dict[str, object]] = None):
        self.enabled = enabled
        self.common_labels = dict(common_labels or {})
        self._series: Dict[str, object] = {}
        self._parity: Dict[str, Counter] = {}

    # ---- get-or-create ----------------------------------------------
    def _get(self, name: str, labels: Dict[str, object], factory,
             parity: bool = False):
        if not self.enabled:
            return factory()
        key = series_key(name, {**self.common_labels, **labels})
        inst = self._series.get(key)
        if inst is None:
            inst = factory()
            self._series[key] = inst
            if parity:
                self._parity[key] = inst
        return inst

    def counter(self, name: str, parity: bool = False,
                **labels) -> Counter:
        return self._get(name, labels, Counter, parity=parity)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BOUNDS,
                  **labels) -> Histogram:
        return self._get(name, labels, lambda: Histogram(bounds))

    # ---- snapshots --------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """All series: counters/gauges as numbers, histograms as their
        summary dicts. Keys are canonical ``name{k=v}`` strings."""
        out: Dict[str, object] = {}
        for key in sorted(self._series):
            inst = self._series[key]
            out[key] = inst.summary() if isinstance(inst, Histogram) \
                else inst.value
        return out

    def parity_snapshot(self) -> Dict[str, int]:
        """Only the parity-contract counters, coerced to int — the
        engine-parity assertion compares these byte-for-byte."""
        out: Dict[str, int] = {}
        for key in sorted(self._parity):
            v = self._parity[key].value
            iv = int(v)
            if iv != v:
                raise ValueError(
                    f"parity counter {key} holds non-integer {v!r}")
            out[key] = iv
        return out

    def series(self) -> List[Tuple[str, object]]:
        return sorted(self._series.items())
