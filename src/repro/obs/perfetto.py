"""Perfetto / Chrome-trace JSON export of ``core.tracing.Trace``
timelines (DESIGN.md §12.2) — the reproduction's answer to the paper's
KernelShark figures: open any sim, grid cell or executor bench run in
ui.perfetto.dev (or chrome://tracing).

Format: the stable Chrome "JSON Array"/"traceEvents" flavor —
``{"traceEvents": [...], "displayTimeUnit": "ms"}``. Trace timestamps
are milliseconds of simulated (or wall-clock) time; Chrome trace
``ts``/``dur`` are microseconds, so everything is scaled by 1e3 on the
way out and back.

Track layout:

* pid ``PID_CORES``   — one thread per core ("core 0" ... "core N-1"),
  "X" complete events per segment. ``cat``/``cname`` classify spans:
  gang execution (an ``rt_names`` member), best-effort, throttled
  (``throttled:<task>``), DEM-demoted (``dem:<task>``) and
  watchdog-aborted (``aborted:<key>``) windows color differently.
* pid ``PID_COUNTERS`` — "C" counter events: per-window bandwidth
  budget vs. used per core, donation-pool level under reclaim, and
  cumulative glock hold time (built by ``export_sim`` from the
  regulator's window history and the engines' gang-change log).

``segments_from_json`` inverts the core tracks exactly (the round-trip
test in tests/test_obs.py relies on it), and ``validate_chrome_trace``
is a dependency-free structural validator used by CI's smoke job.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

PID_CORES = 1
PID_COUNTERS = 2
MS = 1000.0      # trace unit (ms) -> chrome unit (us)

# Perfetto's fixed color-name palette (cname); picked for contrast:
# gangs cycle through strong colors, BE is muted, pathology is loud.
GANG_CNAMES = ("thread_state_running", "rail_response", "rail_animation",
               "thread_state_runnable", "rail_load", "heap_dump_stack_frame")
CNAME_BE = "grey"
CNAME_THROTTLED = "terrible"          # red — the regulator stalled a core
CNAME_DEM = "bad"                     # orange — DEM-demoted execution
CNAME_ABORTED = "black"               # watchdog kill


def _classify(label: str, rt_names: Sequence[str]) -> Tuple[str, str]:
    """(cat, cname) for a segment label."""
    if label.startswith("throttled:"):
        return "throttle", CNAME_THROTTLED
    if label.startswith("dem:"):
        return "dem", CNAME_DEM
    if label.startswith("aborted:"):
        return "aborted", CNAME_ABORTED
    if label in rt_names:
        i = list(rt_names).index(label)
        return "gang", GANG_CNAMES[i % len(GANG_CNAMES)]
    return "be", CNAME_BE


def export_trace(trace, rt_names: Sequence[str] = (),
                 counters: Optional[Dict[str, List[Tuple[float, Dict]]]]
                 = None,
                 title: str = "repro") -> Dict:
    """Chrome-trace dict for a ``core.tracing.Trace``.

    ``counters`` maps track name -> [(t_ms, {series: value}), ...];
    each becomes one "C" counter track (Perfetto stacks the series).
    """
    trace.finish_view()
    ev: List[Dict] = [
        {"ph": "M", "pid": PID_CORES, "tid": 0, "name": "process_name",
         "args": {"name": f"{title}: cores"}},
        {"ph": "M", "pid": PID_CORES, "tid": 0, "name": "process_sort_index",
         "args": {"sort_index": 0}},
    ]
    for c in range(trace.n_cores):
        ev.append({"ph": "M", "pid": PID_CORES, "tid": c,
                   "name": "thread_name", "args": {"name": f"core {c}"}})
    for s in trace.segments:
        if s.label is None:
            continue
        cat, cname = _classify(s.label, rt_names)
        # args carry the exact ms endpoints: the us-scaled ts/dur lose
        # the last float ulp, and the round-trip (segments_from_json)
        # must reconstruct Trace.segments exactly
        ev.append({"ph": "X", "pid": PID_CORES, "tid": s.core,
                   "name": s.label, "cat": cat, "cname": cname,
                   "ts": s.t0 * MS, "dur": (s.t1 - s.t0) * MS,
                   "args": {"t0_ms": s.t0, "t1_ms": s.t1}})
    if counters:
        ev.append({"ph": "M", "pid": PID_COUNTERS, "tid": 0,
                   "name": "process_name",
                   "args": {"name": f"{title}: counters"}})
        ev.append({"ph": "M", "pid": PID_COUNTERS, "tid": 0,
                   "name": "process_sort_index", "args": {"sort_index": 1}})
        for track in sorted(counters):
            for t, values in counters[track]:
                ev.append({"ph": "C", "pid": PID_COUNTERS, "tid": 0,
                           "name": track, "ts": t * MS,
                           "args": dict(values)})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


# ---- counter-track builders (regulator history + gang-change log) ----

def bandwidth_tracks(history: Iterable[Tuple]) -> Dict[
        str, List[Tuple[float, Dict]]]:
    """Counter tracks from ``BandwidthRegulator.history`` samples.

    ``("window", t_end, core, used, limit)`` samples — one closed
    regulation window per core — become per-core ``bw core N`` tracks
    (used vs. budget, stepped at window ends); ``("draw", t, total)``
    samples become one cumulative ``reclaim drawn`` track.
    """
    out: Dict[str, List[Tuple[float, Dict]]] = {}
    for rec in history:
        if rec[0] == "window":
            _, t_end, core, used, limit = rec
            out.setdefault(f"bw core {core}", []).append(
                (t_end, {"used": used, "budget": limit}))
        elif rec[0] == "draw":
            _, t, total = rec
            out.setdefault("reclaim drawn", []).append(
                (t, {"bytes": total}))
    return out


def glock_track(gang_events: Iterable[Tuple[float, str, Optional[str]]]
                ) -> List[Tuple[float, Dict]]:
    """Cumulative glock-hold-time counter from the engines' gang-change
    log ``(t, event, leader_name)``. Hold time accrues from the acquire
    that made the lock held to the release/preempt that freed it;
    join/leave membership churn does not restart the clock."""
    out: List[Tuple[float, Dict]] = []
    held_ms = 0.0
    t_acq: Optional[float] = None
    for t, event, _leader in gang_events:
        if event == "acquire":
            if t_acq is None:
                t_acq = t
                out.append((t, {"held_ms": held_ms}))
        elif event in ("release", "preempt"):
            if t_acq is not None:
                held_ms += t - t_acq
                t_acq = None
                out.append((t, {"held_ms": held_ms}))
            if event == "preempt":   # successor acquires in the same pick
                t_acq = t
    return out


def export_sim(sim, result, title: str = "sim") -> Dict:
    """Export a finished Simulator run: core tracks from
    ``result.trace`` plus whatever counter history the run recorded
    (``record_counters=True`` at construction)."""
    counters = bandwidth_tracks(getattr(sim.reg, "history", None) or ())
    gl = glock_track(getattr(sim, "gang_events", None) or ())
    if gl:
        counters["glock held"] = gl
    return export_trace(result.trace,
                        rt_names=[t.name for t in sim.rt_tasks],
                        counters=counters, title=title)


# ---- validation / round-trip -----------------------------------------

def validate_chrome_trace(data) -> List[str]:
    """Structural validation of the traceEvents flavor; returns a list
    of problems (empty = valid). Dependency-free on purpose — CI runs
    this without jsonschema."""
    probs: List[str] = []
    if not isinstance(data, dict) or "traceEvents" not in data:
        return ["top level must be an object with a traceEvents array"]
    evs = data["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents must be an array"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            probs.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M", "C"):
            probs.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(e.get("pid"), int):
            probs.append(f"{where}: pid must be an int")
        if not isinstance(e.get("name"), str) or not e.get("name"):
            probs.append(f"{where}: name must be a non-empty string")
        if ph == "M":
            if e.get("name") not in ("process_name", "thread_name",
                                     "process_sort_index"):
                probs.append(f"{where}: unknown metadata {e.get('name')!r}")
            if not isinstance(e.get("args"), dict):
                probs.append(f"{where}: metadata needs args")
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            probs.append(f"{where}: ts must be a non-negative number")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                probs.append(f"{where}: dur must be a non-negative number")
            if not isinstance(e.get("tid"), int):
                probs.append(f"{where}: tid must be an int")
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float))
                    for v in args.values()):
                probs.append(f"{where}: counter args must be a non-empty "
                             f"dict of numbers")
    return probs


def segments_from_json(data) -> List[Tuple[int, str, float, float]]:
    """Invert the core tracks: (core, label, t0_ms, t1_ms) tuples in
    (core, t0) order — comparable against ``Trace.segments`` (idle
    segments are never exported, so compare against the labeled
    ones)."""
    out = []
    for e in data["traceEvents"]:
        if e.get("ph") == "X" and e.get("pid") == PID_CORES:
            args = e.get("args") or {}
            if "t0_ms" in args and "t1_ms" in args:
                t0, t1 = args["t0_ms"], args["t1_ms"]
            else:          # foreign trace: fall back to the us scale
                t0 = e["ts"] / MS
                t1 = t0 + e["dur"] / MS
            out.append((e["tid"], e["name"], t0, t1))
    out.sort(key=lambda r: (r[0], r[2]))
    return out


def write_chrome_trace(path: str, data: Dict) -> None:
    """Validate then write (CI's smoke job goes through this)."""
    probs = validate_chrome_trace(data)
    if probs:
        raise ValueError("invalid chrome trace: " + "; ".join(probs[:5]))
    with open(path, "w") as f:
        json.dump(data, f)
        f.write("\n")
