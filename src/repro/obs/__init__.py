"""Unified observability layer (DESIGN.md §12).

Three pieces, built to be shared by both simulator engines, the
wall-clock executor and the vgang grid:

* ``obs.metrics``  — a MetricsRegistry of counters/gauges/histograms
  with labeled series; the engines' ad-hoc counter fields now live
  here, and the integer counters marked ``parity=True`` form the
  engine-parity contract (both engines must produce byte-identical
  ``parity_snapshot()`` values on the fig4/fig5 workloads).
* ``obs.perfetto`` — Chrome-trace/Perfetto JSON export of
  ``core.tracing.Trace`` timelines plus counter tracks (per-window
  bandwidth, donation pool, glock hold time), viewable in
  ui.perfetto.dev — the reproduction's answer to the paper's
  KernelShark figures.
* ``obs.margins``  — per-job RTA-margin accounting: measured response
  vs the policy's analytic bound, with slack histograms and
  worst-observed-margin summaries (soundness as a measured property).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.margins import margin_summary, merge_margins  # noqa: F401
from repro.obs.perfetto import (export_trace, export_sim,  # noqa: F401
                                segments_from_json, validate_chrome_trace,
                                write_chrome_trace)
