"""Batched, vectorized response-time analysis (DESIGN.md §13).

The acceptance grid's inner loop — the Audsley fixed point of
``core/rta.response_time`` — is embarrassingly parallel across tasksets:
each (taskset, task) lane only ever reads its own iterate plus the static
(C, P, prio) vectors of its taskset.  This module pads a shard of tasksets
into dense ``(n_tasksets, max_tasks)`` float64 arrays and steps every lane
of the fixed point together until all lanes have converged or diverged.

Exactness contract: for every lane the returned WCRT is bit-for-bit equal
to the scalar ``core/rta.response_time`` result — same 1e-12 convergence
tolerance, same ``1000 * period`` divergence cutoff, same max_iter, same
convergence-before-divergence check order, and the same left-to-right
``(C + blocking) + interference`` summation with interference accumulated
in taskset order.  Padded lanes never contribute: the interference sum is
a *masked* accumulation (non-hp terms are not added at all, mirroring the
scalar generator expression), so padding cannot perturb a single ulp.

Two backends share the same iteration structure:

- ``numpy`` (default): no import or compile latency, which matters because
  the grid fans the shards out to short-lived multiprocessing workers.
- ``jax``: a ``jax.vmap``-ed per-taskset ``lax.while_loop`` under an x64
  scope, for large offline shards where jit compile time amortizes.
  Select with ``backend="jax"`` or ``REPRO_RTA_BACKEND=jax``.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rta import gang_wcet

TOL = 1e-12
DIVERGENCE_FACTOR = 1000.0
MAX_ITER = 10_000

_PAD_PERIOD = 1.0  # padded lanes divide by this; value is masked out anyway


@dataclasses.dataclass
class PaddedBatch:
    """A shard of tasksets padded to dense ``(n_tasksets, max_tasks)``.

    ``valid`` masks real lanes; padded lanes carry C=0, P=1, prio=0 and are
    excluded from both analysis and interference.  ``names`` keeps the
    original per-taskset task names so results can be re-keyed.
    """

    C: np.ndarray       # (S, T) gang WCETs, float64 (may contain +inf)
    P: np.ndarray       # (S, T) periods, float64
    prio: np.ndarray    # (S, T) priorities, float64
    valid: np.ndarray   # (S, T) bool, real-lane mask
    names: List[List[str]]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.C.shape


def pad_rows(rows: Sequence[Sequence[Tuple[str, float, float, float]]]
             ) -> PaddedBatch:
    """Pad ``(name, C, P, prio)`` rows, one inner sequence per taskset."""
    S = len(rows)
    T = max((len(r) for r in rows), default=0)
    C = np.zeros((S, T))
    P = np.full((S, T), _PAD_PERIOD)
    prio = np.zeros((S, T))
    valid = np.zeros((S, T), dtype=bool)
    names: List[List[str]] = []
    for s, row in enumerate(rows):
        n = len(row)
        if not n:
            names.append([])
            continue
        nm, c, p, pr = zip(*row)
        names.append(list(nm))
        C[s, :n] = c
        P[s, :n] = p
        prio[s, :n] = pr
        valid[s, :n] = True
    return PaddedBatch(C=C, P=P, prio=prio, valid=valid, names=names)


def pad_tasksets(tasksets: Sequence[Sequence]) -> PaddedBatch:
    """Pad a shard of ``RTTask`` tasksets (uses ``gang_wcet`` like scalar)."""
    return pad_rows([[(t.name, gang_wcet(t), t.period, t.prio) for t in ts]
                     for ts in tasksets])


def accept_bits(batch: PaddedBatch, R: np.ndarray) -> np.ndarray:
    """Vectorized per-set admission bits from a ``fixed_point`` result:
    accepted iff every real lane converged and met its deadline
    (``R <= P + TOL``).  NaN lanes (divergent, or skipped inf-WCET) fail
    their set, exactly like the scalar ``ok=False``."""
    with np.errstate(invalid="ignore"):
        ok = R <= batch.P + TOL      # NaN compares False
    return np.all(ok | ~batch.valid, axis=1)


def default_backend() -> str:
    env = os.environ.get("REPRO_RTA_BACKEND", "").strip().lower()
    if env in ("numpy", "jax"):
        return env
    return "numpy"


def _as_blocking(blocking, S: int) -> np.ndarray:
    arr = np.asarray(blocking, dtype=np.float64)
    if arr.ndim == 0:
        arr = np.full(S, float(arr))
    if arr.shape != (S,):
        raise ValueError(f"blocking must be scalar or shape ({S},)")
    return arr


def _as_crpd(crpd, shape: Tuple[int, int]) -> np.ndarray:
    arr = np.asarray(crpd, dtype=np.float64)
    if arr.ndim == 0:
        arr = np.full(shape, float(arr))
    if arr.shape != shape:
        raise ValueError(f"crpd must be scalar or shape {shape}")
    return arr


def fixed_point(batch: PaddedBatch, blocking=0.0, crpd=0.0,
                analyze: Optional[np.ndarray] = None,
                max_iter: int = MAX_ITER, backend: str = "auto") -> np.ndarray:
    """Run the masked batched Audsley fixed point on a padded shard.

    ``crpd`` is scalar or ``(S, T)`` keyed by the *analyzed* lane: lane
    (s, i) solves ``R = (C_i + crpd_si) + blocking_s +
    sum_{j in hp(i)} ceil(R / P_j) * (C_j + crpd_si)`` — the per-analyzed-
    task CRPD inflates every term, exactly as the scalar path does.

    ``analyze`` (default: all valid lanes) restricts which lanes are
    solved; excluded lanes still interfere with the lanes that are.
    Returns an ``(S, T)`` float64 array of WCRTs with NaN where the lane
    diverged (scalar ``None``) or was not analyzed.
    """
    if backend == "auto":
        backend = default_backend()
    S, T = batch.shape
    if T == 0 or S == 0:
        return np.full((S, T), np.nan)
    blocking_arr = _as_blocking(blocking, S)
    crpd_arr = _as_crpd(crpd, (S, T))
    if analyze is None:
        analyze = batch.valid
    # Scalar callers never analyze an infinite-WCET task (they pre-skip it);
    # keep such lanes as interferers only.
    active0 = analyze & batch.valid & np.isfinite(batch.C)
    if backend == "jax":
        return _fixed_point_jax(batch, blocking_arr, crpd_arr, active0,
                                max_iter)
    if backend != "numpy":
        raise ValueError(f"unknown RTA backend {backend!r}")
    return _fixed_point_numpy(batch, blocking_arr, crpd_arr, active0,
                              max_iter)


# Below this many live lanes the per-iteration numpy dispatch overhead
# exceeds the scalar recurrence; hand the stragglers to a Python tail
# that uses the *same* ops as core/rta.response_time, so bit-exactness
# holds by construction.
_TAIL_LANES = 8


def _fixed_point_numpy(batch: PaddedBatch, blocking: np.ndarray,
                       crpd: np.ndarray, active0: np.ndarray,
                       max_iter: int) -> np.ndarray:
    """Lane-compacted batched iteration with guarded fast classification.

    Every analyzed (taskset, task) lane is an independent recurrence, so
    the batch is flattened to one row per lane, full-width with an hp
    mask (masked terms carry C=0 in taskset order).  The interference
    sum uses a sequential ``cumsum``: numpy's pairwise ``sum`` would
    re-associate the addends, but cumulative sums are left-to-right and
    adding an exact 0.0 to a non-negative partial sum cannot change a
    bit, so each lane reproduces the scalar ``sum(...)`` partial-sum
    sequence exactly.  Settled lanes are compacted away so late
    iterations only pay for live lanes.

    The scalar recurrence's cost is dominated by a long tail of lanes
    near utilization 1 that march for hundreds-to-thousands of
    iterations.  Those lanes are classifiable without marching, and
    *provably bit-exactly* under the numerical guard below:

    * **Instant divergence.**  With hp utilization ``U = sum c_j/P_j``,
      ``ceil(x) >= x`` gives ``f(R) - R >= base + (U-1)*R >= base`` for
      ``U >= 1``.  Under the guard (``base`` well above the accumulated
      rounding error and the 1e-12 tolerance), the computed increment can
      never fall inside the convergence band, so the scalar always
      returns None (cutoff or ``max_iter`` exhaustion).  Verdict: None,
      zero iterations.
    * **Jump-start.**  For ``U < 1`` every (exact or computed) fixed
      point R' satisfies ``f(R') <~ R'`` hence ``R' >= (base - err)/(1-U)``,
      so iterating from ``L = 0.99 * base/(1-U)`` visits no fixed point
      the from-``base`` trajectory would have stopped at earlier, and the
      monotone computed map lands on the *same* plateau value
      (identical ceil vector => identical bits).  Under the guard, a
      computed increment is either exactly 0 (plateau: unchanged ceil
      vector => bitwise-identical sum) or exceeds the 1e-12 tolerance,
      so "converged" means "exact plateau" for both paths.  A
      crossing-count bound then confirms the scalar would reach that
      plateau within its own ``max_iter`` (each scalar iteration except
      the last crosses at least one ``P_j`` multiple); lanes failing the
      bound are re-run faithfully from ``base``.

    The guard requires every hp term and ``base`` to clear both an
    absolute floor (1e-9) and 1e4x the worst-case float summation error
    along the trajectory; lanes with ``|U - 1| <= 1e-9`` or failing the
    guard take the faithful from-``base`` path unchanged.
    """
    C, P, prio, valid = batch.C, batch.P, batch.prio, batch.valid
    S, T = C.shape
    result = np.full((S, T), np.nan)
    lanes = np.argwhere(active0)
    if lanes.size == 0:
        return result
    s_idx, i_idx = lanes[:, 0], lanes[:, 1]
    # hp[s, i, j]: lane j interferes with analyzed lane i (strictly higher
    # prio, both real) — never self, duplicate prios never interfere.
    hp = (prio[:, None, :] > prio[:, :, None]) \
        & valid[:, None, :] & valid[:, :, None]
    hp_mask = hp[s_idx, i_idx, :]                   # (L, T), taskset j-order
    crpd_l = crpd[s_idx, i_idx]
    # Full-width term layout: masked (non-hp) columns carry C=0, which a
    # left-to-right cumsum cannot observe.  No gather/argsort needed.
    C_hp = np.where(hp_mask, C[s_idx] + crpd_l[:, None], 0.0)
    P_hp = P[s_idx]
    n_hp = hp_mask.sum(axis=1)
    H = T
    base = (C[s_idx, i_idx] + crpd_l) + blocking[s_idx]
    cutoff = DIVERGENCE_FACTOR * P[s_idx, i_idx]
    flat_result = result.reshape(-1)
    lane_flat = s_idx * T + i_idx

    # --- guarded fast classification -----------------------------------
    with np.errstate(invalid="ignore", over="ignore"):
        U = np.sum(C_hp / P_hp, axis=1)     # masked cols: 0 / P == 0
        sum_c = np.sum(C_hp, axis=1)
        # Worst-case float error of one interference evaluation below the
        # cutoff: n terms, each bounded by the evaluation's own magnitude
        # f(R) <= base + sum_c + U*cutoff.
        err = n_hp * 2.3e-16 * (base + sum_c + U * cutoff)
        min_c = np.min(np.where(hp_mask, C_hp, np.inf), axis=1)
        floor = np.maximum(1e4 * err, 1e-9)
        guard = np.isfinite(err) & (base >= floor) \
            & ((min_c >= floor) | (n_hp == 0))
        # U >= 1 (or an inf hp term): the scalar can never converge.
        instant = (U >= 1.0 + 1e-9) & (guard | np.isinf(U)) & (base > 0)
        jumped = guard & (U <= 1.0 - 1e-9) & ~instant
        R = np.where(jumped & (n_hp > 0),
                     np.maximum(base, 0.99 * (base / (1.0 - U))), base)
    if instant.any():
        keep = ~instant
        R, base, cutoff = R[keep], base[keep], cutoff[keep]
        P_hp, C_hp, hp_mask = P_hp[keep], C_hp[keep], hp_mask[keep]
        lane_flat, n_hp, jumped = lane_flat[keep], n_hp[keep], jumped[keep]

    # Jumped lanes that converge must also pass the scalar-iteration
    # bound; failing rows are re-run faithfully from base at the end.
    refit: list = []
    iters = 0
    while lane_flat.size > _TAIL_LANES and iters < max_iter:
        if H:
            D = np.ceil(R[:, None] / P_hp)
            acc = np.cumsum(D * C_hp, axis=1)[:, -1]
        else:
            acc = np.zeros_like(R)
        R_new = base + acc
        conv = np.abs(R_new - R) < TOL
        if conv.any():
            ok = conv
            jc = conv & jumped
            if jc.any():
                rows = np.where(jc)[0]
                steps = np.floor((R_new[rows, None] - base[rows, None])
                                 / P_hp[rows])
                bound = np.sum(steps * hp_mask[rows], axis=1) \
                    + n_hp[rows] + 4
                bad = rows[bound > max_iter]
                if bad.size:
                    ok = conv.copy()
                    ok[bad] = False
                    for r in bad:
                        refit.append((P_hp[r].copy(), C_hp[r].copy(),
                                      hp_mask[r].copy(), float(base[r]),
                                      float(cutoff[r]), int(lane_flat[r])))
            flat_result[lane_flat[ok]] = R_new[ok]
        # Convergence wins over divergence, in scalar check order.
        still = ~conv & ~(R_new > cutoff)
        iters += 1
        if still.all():
            R = R_new
        else:
            R = R_new[still]
            base, cutoff = base[still], cutoff[still]
            P_hp, C_hp, hp_mask = P_hp[still], C_hp[still], hp_mask[still]
            lane_flat, n_hp = lane_flat[still], n_hp[still]
            jumped = jumped[still]
    _scalar_tail(P_hp, C_hp, hp_mask, R, base, cutoff, lane_flat,
                 flat_result, max_iter - iters, jumped=jumped,
                 bound_iter=max_iter, refit=refit)
    for P_r, C_r, m_r, base_l, cutoff_l, lf in refit:
        _scalar_tail(P_r[None, :], C_r[None, :], m_r[None, :],
                     np.array([base_l]), np.array([base_l]),
                     np.array([cutoff_l]), np.array([lf]), flat_result,
                     max_iter)
    return result


def _scalar_tail(P_hp, C_hp, hp_mask, R, base, cutoff, lane_flat,
                 flat_result, iter_budget: int, jumped=None,
                 bound_iter: int = 0, refit=None) -> None:
    """Finish straggler lanes with the scalar recurrence, resuming from
    the batched iterate.  Mirrors ``response_time``'s loop body exactly
    (``sum`` over hp terms in taskset order, ``math.ceil``).

    Jump-started lanes (``jumped``) converge to the same plateau as the
    faithful trajectory but need the scalar-iteration bound confirmed
    before their value counts (see ``_fixed_point_numpy``); a lane
    failing the bound — or exhausting the budget without resolving — is
    queued on ``refit`` for a faithful from-``base`` re-run."""
    for idx in range(lane_flat.size):
        hp_terms = [(float(P_hp[idx, j]), float(C_hp[idx, j]))
                    for j in np.flatnonzero(hp_mask[idx])]
        h = len(hp_terms)
        R_cur = float(R[idx])
        base_l = float(base[idx])
        cutoff_l = float(cutoff[idx])
        is_jumped = jumped is not None and bool(jumped[idx])
        for _ in range(iter_budget):
            interference = sum(math.ceil(R_cur / p) * c for p, c in hp_terms)
            R_new = base_l + interference
            if abs(R_new - R_cur) < TOL:
                if is_jumped:
                    bound = sum(math.floor((R_new - base_l) / p)
                                for p, _ in hp_terms) + h + 4
                    if bound > bound_iter:
                        refit.append((P_hp[idx].copy(), C_hp[idx].copy(),
                                      hp_mask[idx].copy(), base_l, cutoff_l,
                                      int(lane_flat[idx])))
                        break
                flat_result[lane_flat[idx]] = R_new
                break
            if R_new > cutoff_l:
                break
            R_cur = R_new
        else:
            if is_jumped:
                # Budget ran out mid-march from the jump start: no claim
                # about the faithful trajectory is possible — redo it.
                refit.append((P_hp[idx].copy(), C_hp[idx].copy(),
                              hp_mask[idx].copy(), base_l, cutoff_l,
                              int(lane_flat[idx])))


_JAX_KERNEL = None


def _fixed_point_jax(batch: PaddedBatch, blocking: np.ndarray,
                     crpd: np.ndarray, active0: np.ndarray,
                     max_iter: int) -> np.ndarray:
    global _JAX_KERNEL
    import jax

    if _JAX_KERNEL is None:
        import jax.numpy as jnp
        from functools import partial

        def _one(C, P, prio, valid, blocking, crpd, active0, max_iter):
            T = C.shape[0]
            hp = (prio[None, :] > prio[:, None]) & valid[None, :] \
                & valid[:, None]
            base = (C + crpd) + blocking
            cutoff = DIVERGENCE_FACTOR * P

            def body(state):
                R, active, result, it = state

                def jterm(j, acc):
                    term = jnp.ceil(R / P[j]) * (C[j] + crpd)
                    return acc + jnp.where(hp[:, j], term, 0.0)

                acc = jax.lax.fori_loop(0, T, jterm, jnp.zeros_like(R))
                R_new = base + acc
                conv = jnp.abs(R_new - R) < TOL
                result = jnp.where(active & conv, R_new, result)
                active = active & ~conv & ~(R_new > cutoff)
                R = jnp.where(active, R_new, R)
                return R, active, result, it + 1

            def cond(state):
                _, active, _, it = state
                return active.any() & (it < max_iter)

            init = (base, active0, jnp.full_like(C, jnp.nan), 0)
            return jax.lax.while_loop(cond, body, init)[2]

        _JAX_KERNEL = jax.jit(
            jax.vmap(partial(_one), in_axes=(0, 0, 0, 0, 0, 0, 0, None)),
            static_argnums=(7,))

    from jax.experimental import enable_x64
    with enable_x64():
        out = _JAX_KERNEL(batch.C, batch.P, batch.prio, batch.valid,
                          blocking, crpd, active0, max_iter)
        return np.asarray(out, dtype=np.float64)


def batched_response_times(tasksets: Sequence[Sequence], blocking=0.0,
                           crpd=0.0, max_iter: int = MAX_ITER,
                           backend: str = "auto"
                           ) -> List[List[Optional[float]]]:
    """Per-taskset lists of WCRTs (``None`` where scalar RTA diverges)."""
    batch = pad_tasksets(tasksets)
    R = fixed_point(batch, blocking=blocking, crpd=crpd, max_iter=max_iter,
                    backend=backend)
    out: List[List[Optional[float]]] = []
    for s, ts in enumerate(tasksets):
        out.append([None if math.isnan(R[s, i]) else float(R[s, i])
                    for i in range(len(ts))])
    return out


def batched_schedulable(tasksets: Sequence[Sequence], blocking=0.0,
                        crpd=0.0, backend: str = "auto"
                        ) -> List[Dict[str, Dict]]:
    """Batched drop-in for ``core/rta.schedulable`` over a shard.

    Returns one ``{name: {"wcrt", "deadline", "ok"}}`` dict per taskset,
    bit-identical to calling the scalar path taskset by taskset.
    """
    wcrts = batched_response_times(tasksets, blocking=blocking, crpd=crpd,
                                   backend=backend)
    out = []
    for ts, Rs in zip(tasksets, wcrts):
        res = {}
        for t, R in zip(ts, Rs):
            res[t.name] = {"wcrt": R, "deadline": t.period,
                           "ok": R is not None and R <= t.period + TOL}
        out.append(res)
    return out


def batched_accepts(tasksets: Sequence[Sequence], blocking=0.0, crpd=0.0,
                    backend: str = "auto") -> List[bool]:
    """Accept bit per taskset: every task meets its deadline."""
    results = batched_schedulable(tasksets, blocking=blocking, crpd=crpd,
                                  backend=backend)
    return [all(r["ok"] for r in res.values()) for res in results]


# ---------------------------------------------------------------------
# Vectorized closed-form window evaluation — the verdict-phase scalar
# hot spot of the rtgT / rtgT+dr grid columns (vgang/rta). The scalar
# bounds walk a tiny piecewise (seg_len, slowdown) profile per member
# per vgang; here the profiles of every lane in a shard are padded to a
# dense (L, K) pair and the whole closed form (work per window, number
# of full windows, finish offset in the last window) evaluates as a
# handful of array ops. Pads carry d=0, s=1 so d/s contributes an exact
# 0.0 and every lane stays bit-identical to its scalar walk.
# ---------------------------------------------------------------------


def pad_profiles(profiles: Sequence[Sequence[Tuple[float, float]]]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad piecewise ``(seg_len, slowdown)`` profiles to dense ``(L, K)``
    arrays ``(D, S, valid)``; pads carry d=0, s=1."""
    L = len(profiles)
    K = max((len(p) for p in profiles), default=0) or 1
    D = np.zeros((L, K))
    S = np.ones((L, K))
    valid = np.zeros((L, K), dtype=bool)
    for i, prof in enumerate(profiles):
        for j, (d, s) in enumerate(prof):
            D[i, j] = d
            S[i, j] = s
            valid[i, j] = True
    return D, S, valid


def window_eval(D: np.ndarray, S: np.ndarray, valid: np.ndarray,
                needs: Sequence[float]
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized closed form of the scalar per-member window loop in
    ``vgang/rta.rtg_throttle_wcet`` / ``reclaim_wcet``, lane by lane:

        work   = sum(d / s)                       (left-to-right)
        full   = trunc((need - 1e-12) / work)     (= scalar int())
        rem    = need - full * work
        offset = walk the profile: first segment with
                 rem <= d/s + 1e-15 closes at offset += rem * s,
                 earlier segments add d and consume d/s of rem.

    Returns ``(work, full, offset, feasible)``; lanes with
    ``work <= 1e-12`` are infeasible (the scalar paths return/skip inf)
    and their full/offset are meaningless. ``np.trunc`` (not floor)
    matches Python ``int()`` truncation-toward-zero when ``need`` is
    below the 1e-12 slack.
    """
    L, K = D.shape
    needs_a = np.asarray(needs, dtype=np.float64)
    seg = D / S                          # pads: 0/1 == exact 0.0
    work = np.cumsum(seg, axis=1)[:, -1]
    feasible = work > 1e-12
    safe = np.where(feasible, work, 1.0)
    full = np.trunc((needs_a - 1e-12) / safe)
    rem = needs_a - full * safe
    offset = np.zeros(L)
    done = ~feasible
    for j in range(K):
        live = ~done & valid[:, j]
        hit = live & (rem <= seg[:, j] + 1e-15)
        step = live & ~hit
        offset = np.where(hit, offset + rem * S[:, j], offset)
        offset = np.where(step, offset + D[:, j], offset)
        rem = np.where(step, rem - seg[:, j], rem)
        done = done | hit
    return work, full, offset, feasible


def _intf_list(interferences, n: int) -> list:
    return [interferences] * n if callable(interferences) \
        else list(interferences)


def batched_rtg_throttle_wcet(vgangs: Sequence, interferences,
                              interval: float = 1.0) -> List[float]:
    """One ``rtg_throttle_wcet`` per vgang, the closed-form window
    evaluation vectorized across every member lane of the shard.
    ``interferences`` is one shared callable or one per vgang.
    Bit-identical to the scalar bound (shared profile builder, same
    float ops in the same order)."""
    from repro.vgang.rta import _throttle_profile, _window_runtimes
    intfs = _intf_list(interferences, len(vgangs))
    out: List[Optional[float]] = [None] * len(vgangs)
    profiles, needs, owner = [], [], []
    for idx, (vg, intf) in enumerate(zip(vgangs, intfs)):
        if len(vg.members) == 1:
            out[idx] = vg.inflated_wcet(intf)
            continue
        run = _window_runtimes(vg, intf, interval)
        if any(run[m.name] <= 0.0 for m in vg.members):
            out[idx] = float("inf")
            continue
        out[idx] = 0.0                   # scalar worst starts at 0.0
        for m in vg.members:
            profiles.append(_throttle_profile(vg, m, run, intf))
            needs.append(gang_wcet(m))
            owner.append(idx)
    if profiles:
        D, S, valid = pad_profiles(profiles)
        work, full, offset, feasible = window_eval(D, S, valid, needs)
        bounds = np.where(feasible, full * interval + offset, np.inf)
        for b, idx in zip(bounds, owner):
            out[idx] = max(out[idx], float(b))
    return out  # type: ignore[return-value]


def batched_reclaim_wcet(vgangs: Sequence, interferences,
                         interval: float = 1.0) -> List[float]:
    """One ``reclaim_wcet`` per vgang, phase iterations run in lockstep
    so each phase's closed-form window evaluation vectorizes across all
    still-iterating vgangs of the shard. Bit-identical to the scalar
    bound: same profiles, same float ops, same first-wins tie break on
    the (windows, offset) completion order."""
    from repro.vgang.formation import critical_member, rtg_sibling_budget
    from repro.vgang.rta import (_presence_profile, _reclaim_extensions,
                                 _window_runtimes)
    intfs = _intf_list(interferences, len(vgangs))
    out: List[Optional[float]] = [None] * len(vgangs)
    states = []
    for idx, (vg, intf) in enumerate(zip(vgangs, intfs)):
        members = list(vg.members)
        if len(members) == 1:
            out[idx] = vg.inflated_wcet(intf)
            continue
        crit = critical_member(vg, intf)
        Q = rtg_sibling_budget(vg, intf, interval)
        run = _window_runtimes(vg, intf, interval)
        u_sup: Dict[str, float] = {}
        for m in members:
            if run[m.name] >= interval - 1e-12:
                u_sup[m.name] = interval
                continue
            others = [o for o in members if o is not m and o is not crit]
            u_sup[m.name] = _reclaim_extensions(
                vg, intf, interval, Q, run,
                donors=others, drawers=[m], victims=[])[m.name]
        states.append({
            "idx": idx, "vg": vg, "intf": intf, "members": members,
            "crit": crit, "Q": Q, "run": run, "u_sup": u_sup,
            "remaining": {m.name: gang_wcet(m) for m in members},
            "alive": list(members), "completion": {}, "t": 0.0,
        })
    while states:
        profiles, needs = [], []
        for st in states:
            members, crit = st["members"], st["crit"]
            run, u_sup, alive = st["run"], st["u_sup"], st["alive"]
            done = [m for m in members if m.name in st["completion"]]
            u_grt = _reclaim_extensions(
                st["vg"], st["intf"], interval, st["Q"], run,
                donors=[m for m in done if m is not crit],
                drawers=[m for m in alive if m is not crit],
                victims=members)
            st["lanes"] = []
            for m in alive:
                u_m = interval if (m is crit or
                                   run[m.name] >= interval - 1e-12) \
                    else u_grt[m.name]
                present = {o.name: u_sup[o.name]
                           for o in alive if o is not m}
                st["lanes"].append((m, len(needs)))
                profiles.append(
                    _presence_profile(m, present, u_m, st["intf"]))
                needs.append(st["remaining"][m.name])
        D, S, valid = pad_profiles(profiles)
        work, full, offset, feasible = window_eval(D, S, valid, needs)
        next_states = []
        for st in states:
            best = None
            phase_work = {}
            for m, li in st["lanes"]:
                phase_work[m.name] = float(work[li])
                if not feasible[li]:
                    continue
                row = (int(full[li]) + 1, float(offset[li]), m)
                if best is None or (row[0], row[1]) < (best[0], best[1]):
                    best = row
            if best is None:
                out[st["idx"]] = float("inf")
                continue
            k, offv, m = best
            st["completion"][m.name] = st["t"] + (k - 1) * interval + offv
            for o in st["alive"]:
                if o is not m:
                    st["remaining"][o.name] = max(
                        0.0, st["remaining"][o.name]
                        - k * phase_work[o.name])
            st["t"] += k * interval
            st["alive"].remove(m)
            if st["alive"]:
                next_states.append(st)
            else:
                out[st["idx"]] = max(st["completion"].values())
        states = next_states
    return out  # type: ignore[return-value]
