"""Analysis fast path (DESIGN.md §13): batched, vectorized
response-time analysis over shards of independent tasksets."""
from repro.analysis.batched_rta import (PaddedBatch, accept_bits,
                                        batched_accepts,
                                        batched_response_times,
                                        batched_schedulable, fixed_point,
                                        pad_rows, pad_tasksets)

__all__ = [
    "PaddedBatch", "pad_tasksets", "pad_rows", "fixed_point", "accept_bits",
    "batched_response_times", "batched_schedulable", "batched_accepts",
]
