"""Executor-side virtual gangs, end to end (DESIGN.md §2.4): formed
vgangs of jitted JAX step functions driven through the real
GangExecutor in three modes, with measured response times cross-checked
against the vgang RTA bounds.

Workload: four real-time gangs (cam / lidar / dnn / plan — the paper's
DeepPicar-style fleet mix) whose quanta are jitted JAX matmul steps.
WCETs are calibrated on the host (solo max x a safety margin) and the
periods derived from them, so the same script is meaningful on a laptop
and a loaded CI runner.

Modes:
  solo   — singleton vgangs: plain RT-Gang, one real gang at a time;
  vgang  — interference-aware formation (3 virtual gangs), dispatched
           through VirtualGangPolicy.build_executor with
           min-over-live-member lane budgets;
  rtgT   — same formation under RTG-throttle: critical-member lanes
           uncapped, sibling lanes (and BE fillers) admission-capped at
           rtg_sibling_budget, sibling quanta charged bytes_per_quantum;
  rtgT+dr (with --reclaim) — rtgT plus mid-window bandwidth donation
           (DESIGN.md §7.5): a gated sibling quantum that would stall
           draws the unspent window quota of member lanes whose work
           this release already retired. In this workload the steady
           state is stall-free (releases land on window boundaries and
           each lane's worker admits its quantum before any same-lane
           filler can charge), so rt_stalls 0 / reclaimed 0 is the
           expected report — the mode validates that the reclaiming
           dispatch keeps every bound and invariant end to end, while
           the donation path itself is pinned deterministically by
           tests/test_executor_vgang.py.

Checks (the script exits nonzero if any fails):
  * gang invariant: at no sampled instant do two distinct gang
    priorities hold lanes (`check_invariant` + in-flight snapshots);
  * budget ordering: while a vgang is fully in flight and leads the
    glock, the free lane's enforced budget equals that vgang's floor —
    a barrier-waiting lane of another gang can no longer clobber it;
  * RTA soundness: every member's measured response time <= its
    vgang/rta.py bound (wcrt with blocking B_i) plus one quantum; the
    rtgT bound adds the admission-quantization window slop. The
    blocking term also carries an explicit dispatch-jitter allowance
    (--jitter, default 60 ms): the task model prices gang behavior,
    not the OS wakeup latency of worker threads on a contended CI
    container, and ~100 ms scheduling spikes are routine there.

    PYTHONPATH=src python benchmarks/bench_executor_vgang.py
        [--smoke] [--out PATH] [--duration S] [--margin M] [--jitter MS]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.gang import RTTask
from repro.vgang.family import get_family
from repro.vgang.formation import (intensity_interference,
                                   rtg_sibling_budget)
from repro.core.executor import BEJob
from repro.obs.metrics import MetricsRegistry

try:
    from benchmarks.run import write_bench_json
except ImportError:    # run as `python benchmarks/bench_executor_vgang.py`
    from run import write_bench_json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_LANES = 4
INTERVAL_S = 0.010            # regulation window (wall seconds)
INTERVAL_MS = INTERVAL_S * 1e3   # task-time ms (time_scale = 1e-3)
GAMMA = 0.5

# name -> (matrix size, width, memory intensity, budget bytes/window).
# cam/lidar/imu pack into one low-intensity virtual gang: lidar (largest
# inflated WCET) is its critical member, cam and imu its regulated
# siblings. lidar's 6e6 cap sits close enough to the sibling quantum
# (3e6) plus the inter-gang best-effort floor (4e6, set by plan) that a
# window pre-consumed by fillers denies a sibling quantum — the stall
# rtgT pays and rtgT+dr recovers by drawing a retired sibling's quota.
# imu is at least as intense as cam, so retired-imu quota may fund cam
# under the reclaim exchange gate (dominance is one-directional:
# intensity(drawer) <= intensity(donor), so cam could not fund imu).
MEMBERS = {
    "cam":   (96, 1, 0.10, 8e6),
    "lidar": (112, 1, 0.15, 6e6),
    "imu":   (64, 1, 0.12, 8e6),
    "dnn":   (160, 3, 0.70, 8e6),
    "plan":  (128, 2, 0.40, 4e6),
}
# rtgT: bytes one quantum of each member charges against its lane cap.
# Releases land on regulation-window boundaries and each lane's worker
# admits its RT quantum before any same-lane filler can charge, so the
# steady state stays stall-free (rt_stalls 0 is expected, not asserted);
# imu's small always-fitting quanta retire early and leave its lane
# quota donatable — the draw path cam takes under rtgT+dr whenever
# jitter does push an admission into a spent window. The deterministic
# donation/stall behavior is pinned by tests/test_executor_vgang.py.
SIBLING_BYTES = {"cam": 4e6, "lidar": 3e6, "imu": 1e6,
                 "dnn": 3e6, "plan": 3e6}
BE_BYTES = 5e5                # filler quantum traffic

# bench mode -> registry policy family (vgang/family.py). The three
# vgang modes share one formed object via the families' common
# "intfaware" form_key, exactly like the grid.
MODE_FAMILY = {"solo": "rtgang", "vgang": "intfaware",
               "rtgT": "rtgT", "rtgT+dr": "rtgT+dr"}
# rtgT+dr deliberately keeps the *static* rtgT pricing: the reclaim
# bound's guaranteed donations assume donor-lane quota is unspent,
# which this workload's BE fillers (charging the same lane caps)
# violate; the static bound stays sound under the reclaiming dispatch
# (exchange gate, DESIGN.md §7.5), so it is the right yardstick with
# fillers present.
PRICING_FAMILY = {"rtgT+dr": "rtgT"}


def make_step(n: int):
    """A jitted JAX quantum: a few matmul+tanh passes, blocking."""
    @jax.jit
    def f(x):
        for _ in range(3):
            x = jnp.tanh(x @ x) * 0.5
        return x
    x0 = jnp.full((n, n), 0.01, jnp.float32)
    f(x0).block_until_ready()             # compile outside timing

    def step(lane, idx):
        f(x0).block_until_ready()
    return step


def calibrate(step, reps: int = 12) -> float:
    """Solo per-quantum wall time (max over reps, seconds)."""
    best = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        step(0, 0)
        best = max(best, time.perf_counter() - t0)
    return best


def build_taskset(margin: float):
    steps, quanta_s = {}, {}
    for name, (n, _, _, _) in MEMBERS.items():
        steps[name] = make_step(n)
        quanta_s[name] = calibrate(steps[name])
    wcet_ms = {name: max(margin * q * 1e3, 2.0)
               for name, q in quanta_s.items()}
    # periods from the calibrated WCETs: total utilization <= ~1/3,
    # every period a multiple of the regulation window (rtgT RTA needs
    # window-aligned releases), plan at the double period. The 160 ms
    # floor keeps the five-singleton solo RTA feasible even on a fast
    # host, where tiny calibrated WCETs would otherwise leave the
    # period smaller than the blocking + dispatch-jitter term alone.
    S = sum(wcet_ms.values())
    p1 = math.ceil(max(160.0, 3.0 * S) / INTERVAL_MS) * INTERVAL_MS
    periods = {"cam": p1, "lidar": p1, "imu": p1, "dnn": p1,
               "plan": 2 * p1}
    tasks = [RTTask(name, wcet=wcet_ms[name], period=periods[name],
                    cores=tuple(range(w)), prio=0,
                    mem_intensity=s, mem_budget=b)
             for name, (_, w, s, b) in MEMBERS.items()]
    return tasks, steps, quanta_s, wcet_ms


def instrumented(name, step, ctx):
    """Wrap a member quantum with the gang-invariant and
    budget-ordering probes (reads executor state from ``ctx``, which is
    filled in after build_executor)."""
    def fn(lane, idx):
        ex = ctx["ex"]
        inflight = dict(ex._inflight)
        if len(set(inflight.values())) > 1:
            ctx["invariant_violations"] += 1
        g = ex.sched.g
        # budget writes happen inside the gang-change hook under g.lock,
        # so sampling leader + enforced budget under the same lock is a
        # consistent snapshot (no false violation when a preemption
        # lands between the leader check and the budget read)
        with g.lock:
            leader_prio = g.leader.prio if g.leader is not None else None
            live = sum(1 for t in g.gthreads if t is not None)
            enforced = ex.reg.cores[ctx["free_lane"]].budget
        my_prio, width, floor = ctx["gang_of"][name]
        if leader_prio == my_prio and live == width:
            if enforced > floor + 1e-6:
                ctx["budget_violations"] += 1
        step(lane, idx)
    return fn


def run_mode(mode, vgangs, steps, intf, duration_s, be_bytes=BE_BYTES):
    fam = get_family(MODE_FAMILY[mode])
    policy = fam.make_policy(vgangs, N_LANES, intf)
    ctx = {"ex": None, "invariant_violations": 0,
           "budget_violations": 0, "free_lane": N_LANES - 1,
           "gang_of": {}}
    for vg in policy.vgangs:
        floor = min(m.mem_budget for m in vg.members)
        if fam.throttled:
            floor = min(floor, rtg_sibling_budget(vg, intf, INTERVAL_S))
        for m in vg.members:
            ctx["gang_of"][m.name] = (vg.prio, vg.width, floor)
    fns = {name: instrumented(name, step, ctx)
           for name, step in steps.items()}
    bpq = dict(SIBLING_BYTES) if fam.throttled else None
    ex = policy.build_executor(fns, regulation_interval_s=INTERVAL_S,
                               bytes_per_quantum=bpq,
                               metrics=MetricsRegistry())
    assert all(max(m.cores) < ctx["free_lane"]
               for m in policy.taskset()), "free lane must stay BE-only"
    ex.submit_be(BEJob("be_fill", lambda lane: time.sleep(3e-4),
                       lanes=tuple(range(N_LANES)),
                       bytes_per_quantum=be_bytes))
    ctx["ex"] = ex
    stats = ex.run(duration_s)
    stats["invariant_ok"] = ex.sched.check_invariant()
    return policy, ctx, stats


def bounds_for(mode, policy, intf, b_ms):
    # the family whose analytic bound prices this mode — PRICING_FAMILY
    # redirects rtgT+dr to the static rtgT bound (see the comment at
    # its definition)
    fam = get_family(PRICING_FAMILY.get(mode, MODE_FAMILY[mode]))
    rta = fam.bounds(policy.vgangs, intf, interval=INTERVAL_MS,
                     blocking=b_ms)
    if fam.throttled:
        # executor admission is quantum-grained and the wall-clock
        # regulator's windows are not phase-locked to releases: one
        # window of quantization (a partially-fitting quantum the
        # continuous duty-cycle model would admit is denied whole) plus
        # one window of release-vs-window phase misalignment
        slop = 2.0 * INTERVAL_MS
    else:
        slop = 0.0
    out = {}
    for vg in policy.vgangs:
        wcrt = rta[vg.name]["wcrt"]
        for m in vg.members:
            out[m.name] = {
                "vgang": vg.name, "ok": rta[vg.name]["ok"],
                "bound_ms": None if wcrt is None else wcrt + slop}
    return out


# config fields this surface exposes as flags (DESIGN.md §14.2); the
# aliases preserve the legacy spellings
BENCH_EXEC_FLAG_PATHS = ("smoke", "duration_s", "margin", "jitter_ms",
                         "policy.reclaim", "output.out")
BENCH_EXEC_FLAG_ALIASES = {"duration_s": "--duration",
                           "jitter_ms": "--jitter"}
BENCH_EXEC_FLAG_HELPS = {
    "smoke": "short CI run (~1.2 s per mode)",
    "duration_s": "seconds per mode (default: 12 plan periods)",
    "margin": "WCET safety factor over the calibrated quantum",
    "jitter_ms": "dispatch-jitter allowance folded into the blocking "
                 "term (ms of OS thread-wakeup latency outside the task "
                 "model)",
    "policy.reclaim": "add the rtgT+dr mode: RTG-throttle with "
                      "mid-window bandwidth donation (DESIGN.md §7.5)",
    "output.out": "output JSON path (default BENCH_executor_vgang.json)",
}


def resolve_bench_executor_config(argv=None):
    from repro.experiment import (ExperimentConfig, add_flags, cli_main,
                                  default_bench_executor_config,
                                  derive_flags)
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    base = default_bench_executor_config()
    flags = derive_flags(ExperimentConfig, BENCH_EXEC_FLAG_PATHS,
                         aliases=BENCH_EXEC_FLAG_ALIASES,
                         helps=BENCH_EXEC_FLAG_HELPS)
    add_flags(ap, flags, base)
    return cli_main(ap, flags, base, argv,
                    expected_kind="bench_executor")


def main():
    cfg = resolve_bench_executor_config()
    out_path = cfg.output.out or os.path.join(
        ROOT, "BENCH_executor_vgang.json")

    tasks, steps, quanta_s, wcet_ms = build_taskset(cfg.margin)
    intf = intensity_interference(tasks, gamma=GAMMA)
    # blocking B_i: one non-preemptible quantum of any other gang (we
    # use the declared WCET, which upper-bounds the measured quantum)
    # plus one best-effort filler quantum, plus the dispatch-jitter
    # allowance (OS wakeup latency is outside the task model)
    b_ms = max(wcet_ms.values()) + 5.0 + cfg.jitter_ms

    mode_names = ["solo", "vgang", "rtgT"]
    if cfg.policy.reclaim:
        mode_names.append("rtgT+dr")
    # one formation per form_key: vgang/rtgT/rtgT+dr all analyze and
    # dispatch the *identical* intfaware formed object
    formed_of_key, modes = {}, {}
    for mode in mode_names:
        fam = get_family(MODE_FAMILY[mode])
        if fam.form_key not in formed_of_key:
            formed_of_key[fam.form_key] = fam.assign(
                fam.form(tasks, N_LANES, intf))
        modes[mode] = formed_of_key[fam.form_key]
    assert len(modes["vgang"]) == 3, [vg.name for vg in modes["vgang"]]
    plan_period_s = max(t.period for t in tasks) * 1e-3
    duration = cfg.duration_s or max(
        (1.2 if cfg.smoke else 2.5), (6 if cfg.smoke else 12)
        * plan_period_s)

    report = {"n_lanes": N_LANES, "interval_s": INTERVAL_S,
              "margin": cfg.margin, "duration_s": duration,
              "quanta_ms": {n: q * 1e3 for n, q in quanta_s.items()},
              "wcet_ms": wcet_ms, "blocking_ms": b_ms,
              "periods_ms": {t.name: t.period for t in tasks},
              "modes": {}}
    failures = []
    for mode, vgangs in modes.items():
        policy, ctx, stats = run_mode(mode, vgangs, steps, intf,
                                      duration)
        bnd = bounds_for(mode, policy, intf, b_ms)
        members = {}
        for name in steps:
            rts = stats["response_times"].get(name, [])
            bound_ms = bnd[name]["bound_ms"]
            max_s = max(rts) if rts else None
            entry = {
                "vgang": bnd[name]["vgang"], "jobs": len(rts),
                "max_response_ms": None if max_s is None
                else max_s * 1e3,
                "rta_bound_ms": bound_ms, "rta_ok": bnd[name]["ok"],
                # measured-margin accounting (DESIGN.md §12.3): slack
                # of the worst observed job against the analytic bound
                "worst_margin_ms": (None if bound_ms is None
                                    or max_s is None
                                    else bound_ms - max_s * 1e3),
                "negative": (0 if bound_ms is None else sum(
                    1 for r in rts if r * 1e3 > bound_ms + 1e-9)),
            }
            if not bnd[name]["ok"] or bound_ms is None:
                failures.append(f"{mode}:{name} RTA verdict not ok")
            elif not rts:
                failures.append(f"{mode}:{name} recorded no responses")
            elif max_s * 1e3 > bound_ms:
                failures.append(
                    f"{mode}:{name} response {max_s * 1e3:.2f} ms "
                    f"exceeds bound {bound_ms:.2f} ms")
            members[name] = entry
        if ctx["invariant_violations"] or not stats["invariant_ok"]:
            failures.append(
                f"{mode}: {ctx['invariant_violations']} gang-invariant "
                f"violations")
        if ctx["budget_violations"]:
            failures.append(
                f"{mode}: {ctx['budget_violations']} budget-ordering "
                f"violations")
        worsts = [e["worst_margin_ms"] for e in members.values()
                  if e["worst_margin_ms"] is not None]
        report["modes"][mode] = {
            "vgangs": [vg.name for vg in policy.vgangs],
            "members": members,
            "rta_margin": {
                "jobs": sum(e["jobs"] for e in members.values()),
                "worst_margin_ms": min(worsts) if worsts else None,
                "negative": sum(e["negative"]
                                for e in members.values()),
            },
            "metrics": stats.get("metrics"),
            "invariant_violations": ctx["invariant_violations"],
            "budget_violations": ctx["budget_violations"],
            "rt_stalls": stats["rt_stalls"],
            "be_quanta": stats["be_quanta"],
            "acquisitions": stats["acquisitions"],
            "preemptions": stats["preemptions"],
            "ipis": stats["ipis"],
            "reclaimed_bytes": stats["reclaimed_bytes"],
        }
        print(f"[{mode:7s}] vgangs={[vg.name for vg in policy.vgangs]} "
              f"inv={ctx['invariant_violations']} "
              f"budget={ctx['budget_violations']} "
              f"stalls={stats['rt_stalls']} "
              f"reclaimed={stats['reclaimed_bytes']:.3g}")
        for name, e in members.items():
            print(f"    {name:6s} jobs={e['jobs']:3d} "
                  f"max={e['max_response_ms'] and round(e['max_response_ms'], 2)} ms "
                  f"bound={e['rta_bound_ms'] and round(e['rta_bound_ms'], 2)} ms")

    report["ok"] = not failures
    mode_margins = [m["rta_margin"] for m in report["modes"].values()]
    worsts = [m["worst_margin_ms"] for m in mode_margins
              if m["worst_margin_ms"] is not None]
    report["rta_margin"] = {
        "jobs": sum(m["jobs"] for m in mode_margins),
        "worst_margin_ms": min(worsts) if worsts else None,
        "negative": sum(m["negative"] for m in mode_margins),
    }
    write_bench_json(out_path, report, config=cfg)
    print(f"wrote {out_path}")
    if failures:
        print("FAILURES:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("all modes: 0 violations, every response within its RTA bound")
    return 0


if __name__ == "__main__":
    sys.exit(main())
