"""Paper Fig.5: synthetic taskset execution traces (tau1, tau2 RT + memory/
cpu best-effort tasks) without and with RT-Gang, including throttling of the
memory-intensive BE task. Prints trace renders + job-time statistics."""
import numpy as np

from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference


def taskset():
    # tau1: C=3.5 P=20 2 threads; tau2: C=6.5 P=30 2 threads (paper Fig.5)
    t1 = RTTask("tau1", wcet=3.5, period=20, cores=(0, 1), prio=2,
                mem_budget=0.1)
    t2 = RTTask("tau2", wcet=6.5, period=30, cores=(2, 3), prio=1,
                mem_budget=0.1)
    bem = BETask("be_mem", cores=(0, 1, 2, 3), mem_rate=1.0)
    bec = BETask("be_cpu", cores=(0, 1, 2, 3), mem_rate=0.01)
    # shared-L2 thrash when tau1/tau2 overlap; be_mem hurts RT tasks too
    intf = matrix_interference({
        ("tau1", "tau2"): 2.0, ("tau2", "tau1"): 2.0,
        ("tau1", "be_mem"): 1.5, ("tau2", "be_mem"): 1.5,
    })
    return [t1, t2], [bem, bec], intf


def run(horizon=120.0):
    out = []
    for enabled in (False, True):
        rts, bes, intf = taskset()
        sim = Simulator(4, rts, be_tasks=bes, interference=intf,
                        rt_gang_enabled=enabled, dt=0.05,
                        throttle_mode="reactive")
        r = sim.run(horizon)
        out.append({
            "rt_gang": enabled,
            "tau1_wcrt": round(max(r.response_times["tau1"]), 3),
            "tau1_var": round(float(np.var(r.response_times["tau1"])), 4),
            "tau2_wcrt": round(max(r.response_times["tau2"]), 3),
            "tau2_var": round(float(np.var(r.response_times["tau2"])), 4),
            "misses": dict(r.deadline_misses),
            "be_mem_ms": round(r.be_progress["be_mem"], 1),
            "be_cpu_ms": round(r.be_progress["be_cpu"], 1),
            "throttle_events": r.throttle_events,
            "trace": r.trace,
        })
    return out


if __name__ == "__main__":
    for row in run():
        trace = row.pop("trace")
        print(row)
        print(trace.render_ascii(t_end=60.0))
