"""Paper Fig.4 / §III-E illustrative example: co-scheduling vs RT-Gang,
with and without interference. Emits the exact paper numbers."""
from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator, matrix_interference


def run():
    rows = []
    t1 = RTTask("tau1", wcet=2, period=10, cores=(0, 1), prio=2,
                mem_budget=1e9)
    t2 = RTTask("tau2", wcet=4, period=10, cores=(2, 3), prio=1,
                mem_budget=1e9)
    be = [BETask("tau3", cores=(0, 1, 2, 3))]
    intf = matrix_interference({("tau1", "tau2"): 10.0})

    cases = [
        ("fig4a_cosched_ideal", False, None),
        ("fig4b_rtgang", True, None),
        ("fig4c_cosched_interference", False, intf),
        ("fig4b_rtgang_interference", True, intf),
    ]
    for name, enabled, interference in cases:
        sim = Simulator(4, [t1, t2], be_tasks=be,
                        interference=interference or (lambda v, a: 1.0),
                        rt_gang_enabled=enabled, dt=0.05)
        r = sim.run(10.0)
        rows.append({
            "case": name,
            "tau1_finish_ms": r.response_times["tau1"][0],
            "tau2_finish_ms": r.response_times["tau2"][0],
            "slack_core_ms": round(r.slack_time, 2),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
