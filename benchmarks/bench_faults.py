"""Fault-containment benchmark (ISSUE 6 tentpole, DESIGN.md §11).

Demonstrates that runtime overrun enforcement (core/faults.py) contains
misbehaving gangs: on a 3-gang + best-effort workload over 8 cores, a
seeded fault plan (WCET overruns on one gang, one hung member thread)
is run three ways per engine —

* ``baseline``:   no faults, no enforcement — the fault-free reference;
* ``unenforced``: faults injected, no enforcement — the overrunning
  gang starves every lower-priority gang (jobs that never complete
  show up as lost completions);
* ``enforced``:   the same faults under ``abort`` enforcement with a
  wall-clock watchdog — every non-faulty gang's deadline misses and
  completion count must equal the baseline, with zero lock leaks.

A fourth section drives the wall-clock executor (core/executor.py)
with a genuinely hung member function and records the watchdog abort.

The containment criteria are *asserted*: the benchmark exits nonzero
if enforcement fails to contain the faults, so CI can run it as a
smoke job. Results go to BENCH_faults.json at the repo root.

    PYTHONPATH=src python benchmarks/bench_faults.py [--smoke] [--out PATH]
    PYTHONPATH=src python benchmarks/bench_faults.py \
        --config configs/experiments/bench_faults_smoke.json

--smoke shortens the simulated horizon and the executor run (CI). The
enforcement stack (action / factor / watchdog factor) comes from the
resolved ExperimentConfig's policy block (DESIGN.md §14), so a config
file can vary it; the resolved config + digest are stamped into the
output JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.core import rta as core_rta
from repro.core.executor import GangExecutor, RTJob
from repro.core.faults import (Enforcement, FaultPlan, HungThread,
                               WcetOverrun)
from repro.core.gang import BETask, RTTask
from repro.core.sim import Simulator
from repro.obs.margins import merge_margins, overall
from repro.vgang.formation import singleton_vgangs
from repro.vgang.rta import schedulable_vgangs_enforced

try:
    from benchmarks.run import write_bench_json
except ImportError:          # run as `python benchmarks/bench_faults.py`
    from run import write_bench_json

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAULTY = "gB"
NONFAULTY = ("gA", "gC")


def taskset():
    """Three RT gangs + two best-effort tasks on 8 cores (~62% RT
    utilization fault-free; the 4x overrun pushes it past 1 so the
    un-enforced run visibly starves the victim gangs)."""
    rts = [
        RTTask("gA", wcet=2.5, period=12.0, cores=(0, 1, 2), prio=6,
               mem_budget=10.0, criticality=2),
        RTTask(FAULTY, wcet=4.0, period=18.0, cores=(3, 4, 5), prio=5,
               mem_budget=10.0, criticality=1),
        RTTask("gC", wcet=5.0, period=25.0, cores=(0, 1, 2, 3, 4, 5, 6, 7),
               prio=4, mem_budget=10.0, criticality=0),
    ]
    bes = [BETask("be_mem", cores=(6, 7), mem_rate=1.0),
           BETask("be_cpu", cores=(6, 7), mem_rate=0.01)]
    return rts, bes


PLAN = FaultPlan(faults=(
    WcetOverrun(FAULTY, factor=4.0, prob=0.5),
    HungThread(FAULTY, job=7, thread=1),
), seed=42)

def enforcement_from(policy) -> Enforcement:
    """Build the runtime Enforcement stack from a PolicyStackConfig."""
    return Enforcement(action=policy.enforcement or "abort",
                       factor=policy.enforcement_factor,
                       watchdog_factor=policy.watchdog_factor)


def simulate(dt, horizon, fault_plan=None, enforcement=None,
             rta_bounds=None):
    rts, bes = taskset()
    sim = Simulator(8, rts, be_tasks=bes, dt=dt,
                    fault_plan=fault_plan, enforcement=enforcement,
                    rta_bounds=rta_bounds)
    t0 = time.time()
    res = sim.run(horizon)
    return res, time.time() - t0


def summarize(res, wall):
    out = {
        "misses": dict(res.deadline_misses),
        "completions": {n: len(rs) for n, rs in
                        res.response_times.items()},
        "wcrt": {n: (max(rs) if rs else None)
                 for n, rs in res.response_times.items()},
        "faults": res.faults,
        "wall_s": round(wall, 4),
    }
    if res.rta_margins is not None:
        out["rta_margins"] = res.rta_margins
    return out


def margin_bounds(enforcement):
    """Analytic bounds for the margin-instrumented runs: the fault-free
    baseline is priced by plain gang RTA over the declared WCETs; the
    enforced run by the enforcement-aware RTA
    (``schedulable_vgangs_enforced`` over the singleton set), whose
    equivalent WCET prices ``factor x C`` occupancy — sound even while
    the faulty gang misbehaves, which is the point of enforcement. The
    un-enforced faulty run has no sound bound (a 4x overrun with no
    backstop prices nothing), so it carries no margins."""
    rts, _ = taskset()
    base = {n: v["wcrt"] for n, v in core_rta.schedulable(rts).items()}
    enf = {n: v["wcrt"] for n, v in schedulable_vgangs_enforced(
        singleton_vgangs(rts), enforcement=enforcement).items()}
    assert all(b is not None for b in base.values())
    assert all(b is not None for b in enf.values())
    return base, enf


def run_engines(horizon, enforcement):
    out = {}
    violations = []
    margins = {}
    base_bounds, enf_bounds = margin_bounds(enforcement)
    for engine, dt in (("quantum", 0.05), ("event", None)):
        # quantum completions are stamped up to one dt late: add the
        # discretization slop to the bounds (obs/margins.py)
        slop = dt or 0.0
        bb = {n: b + slop for n, b in base_bounds.items()}
        eb = {n: b + slop for n, b in enf_bounds.items()}
        base, wb = simulate(dt, horizon, rta_bounds=bb)
        loose, wl = simulate(dt, horizon, fault_plan=PLAN)
        hard, wh = simulate(dt, horizon, fault_plan=PLAN,
                            enforcement=enforcement, rta_bounds=eb)
        merge_margins(margins, base.rta_margins)
        merge_margins(margins, hard.rta_margins)
        for phase, res in (("baseline", base), ("enforced", hard)):
            neg = sum(r["negative"] for r in res.rta_margins.values())
            if neg:
                violations.append(
                    f"{engine}/{phase}: {neg} responses beyond the "
                    f"RTA bound (negative margin)")
        out[engine] = {"baseline": summarize(base, wb),
                       "unenforced": summarize(loose, wl),
                       "enforced": summarize(hard, wh)}
        # ---- containment criteria (hard failures) -------------------
        for n in NONFAULTY:
            if hard.deadline_misses[n] != base.deadline_misses[n]:
                violations.append(
                    f"{engine}: {n} misses {hard.deadline_misses[n]} "
                    f"!= baseline {base.deadline_misses[n]}")
            if len(hard.response_times[n]) != len(base.response_times[n]):
                violations.append(
                    f"{engine}: {n} completions "
                    f"{len(hard.response_times[n])} != baseline "
                    f"{len(base.response_times[n])}")
        if hard.faults["lock_leaks"] != 0:
            violations.append(
                f"{engine}: {hard.faults['lock_leaks']} lock leaks")
        if not (hard.faults["enforced"]["abort"] > 0
                or hard.faults["watchdog_fires"] > 0):
            violations.append(f"{engine}: enforcement never fired")
        # the un-enforced run must actually demonstrate the cascade,
        # otherwise the enforced comparison is vacuous
        lost = sum(len(base.response_times[n]) - len(loose.response_times[n])
                   for n in NONFAULTY)
        if lost <= 0:
            violations.append(
                f"{engine}: un-enforced faults cost no completions "
                f"— workload too lax to demonstrate containment")
        out[engine]["victim_completions_lost_unenforced"] = lost
    return out, violations, overall(margins)


def run_executor(duration):
    """Wall-clock executor: one member of ``hog`` hangs; the lane
    watchdog must abort the gang instead of deadlocking the barrier."""
    def hang(lane, idx):
        if idx == 1 and lane == 0:
            # far past the watchdog bound (2 x 0.06 s), but bounded so
            # the final worker join doesn't dominate the benchmark
            time.sleep(2.0 + duration)
        else:
            time.sleep(0.002)

    def quick(lane, idx):
        time.sleep(0.002)

    ex = GangExecutor(2, watchdog_factor=2.0)
    ex.submit_rt(RTJob("hog", hang, lanes=(0, 1), prio=2,
                       period_s=0.06, wcet_s=0.01, n_jobs=3))
    ex.submit_rt(RTJob("ok", quick, lanes=(0, 1), prio=1,
                       period_s=0.1, wcet_s=0.01))
    t0 = time.time()
    res = ex.run(duration)
    wall = time.time() - t0
    out = {
        "watchdog_aborts": [list(a) for a in res["watchdog_aborts"]],
        "aborted": dict(res["aborted"]),
        "ok_completions": len(res["response_times"].get("ok", [])),
        "wall_s": round(wall, 4),
    }
    violations = []
    if res["aborted"].get("hog", 0) < 1:
        violations.append("executor: hung gang was never aborted")
    if out["ok_completions"] < 1:
        violations.append("executor: victim gang made no progress")
    if wall > 5 * duration + 5.0:
        violations.append("executor: run wedged past the watchdog")
    return out, violations


# config fields this surface exposes as flags (DESIGN.md §14.2)
BENCH_FAULTS_FLAG_PATHS = ("smoke", "policy.enforcement",
                           "policy.enforcement_factor",
                           "policy.watchdog_factor", "output.out")
BENCH_FAULTS_FLAG_HELPS = {
    "smoke": "short horizon / executor run (CI)",
    "policy.enforcement": "enforcement action (abort / throttle)",
    "policy.enforcement_factor": "budget factor over declared WCET",
    "policy.watchdog_factor": "wall-clock watchdog factor (0 disables)",
    "output.out": "output JSON path (default BENCH_faults.json)",
}


def resolve_bench_faults_config(argv=None):
    from repro.experiment import (ExperimentConfig, add_flags, cli_main,
                                  default_bench_faults_config,
                                  derive_flags)
    ap = argparse.ArgumentParser()
    base = default_bench_faults_config()
    flags = derive_flags(ExperimentConfig, BENCH_FAULTS_FLAG_PATHS,
                         helps=BENCH_FAULTS_FLAG_HELPS)
    add_flags(ap, flags, base)
    return cli_main(ap, flags, base, argv, expected_kind="bench_faults")


def main():
    cfg = resolve_bench_faults_config()
    out_path = cfg.output.out or os.path.join(ROOT, "BENCH_faults.json")
    enf = enforcement_from(cfg.policy)

    horizon = 400.0 if cfg.smoke else 2000.0
    engines, violations, rta_margin = run_engines(horizon, enf)
    exec_out, exec_violations = run_executor(0.4 if cfg.smoke else 1.0)
    violations += exec_violations

    out = {
        "horizon_ms": horizon,
        "plan": {"seed": PLAN.seed,
                 "faults": [repr(f) for f in PLAN.faults]},
        "enforcement": {"action": enf.action, "factor": enf.factor,
                        "watchdog_factor": enf.watchdog_factor},
        "engines": engines,
        "executor": exec_out,
        "rta_margin": rta_margin,
        "contained": not violations,
        "violations": violations,
    }
    write_bench_json(out_path, out, config=cfg)
    for engine in ("quantum", "event"):
        e = engines[engine]
        print(json.dumps({
            "engine": engine,
            "victim_completions_lost_unenforced":
                e["victim_completions_lost_unenforced"],
            "enforced": e["enforced"]["faults"]["enforced"],
            "watchdog_fires": e["enforced"]["faults"]["watchdog_fires"],
            "lock_leaks": e["enforced"]["faults"]["lock_leaks"],
        }))
    print(json.dumps({"executor": exec_out}))
    if violations:
        print("CONTAINMENT FAILED:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        sys.exit(1)
    print(f"containment held; wrote {out_path} "
          f"(config {cfg.content_digest()[:12]})")


if __name__ == "__main__":
    main()
